"""Tests for the composable federated engine (repro.fl): strategies,
executors (sequential vs batched equivalence), device profiles, and
round callbacks."""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.configs import get_config, get_fl_config
from repro.data import load_corpus
from repro.fl import (CAFLL, CheckpointCallback, ClientInfo, DeviceProfile,
                      FedAvg, FederatedEngine, FleetClass,
                      HistoryWriterCallback, LoggingCallback, RoundCallback,
                      ServerOpt, TimingCallback, make_executor, make_fleet,
                      make_strategy, uniform_fleet)
from repro.models import build


@pytest.fixture(scope="module")
def tiny_setup():
    ds = load_corpus(target_bytes=60_000)
    cfg = get_config("charlm-shakespeare").replace(
        vocab_size=max(ds.vocab_size, 64), num_layers=3, d_model=48,
        num_heads=4, num_kv_heads=4, head_dim=12, d_ff=96)
    fl = get_fl_config().replace(
        rounds=2, num_clients=4, clients_per_round=2, s_base=3, b_base=8,
        seq_len=16, eval_batches=1, eval_batch_size=8)
    fl = fl.replace(duals=dataclasses.replace(fl.duals, s_min=2, b_min=4))
    return ds, cfg, fl


@pytest.fixture(scope="module")
def tiny_model(tiny_setup):
    _, cfg, _ = tiny_setup
    return build(cfg)


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------


def test_make_strategy_resolution():
    fl = get_fl_config()
    assert isinstance(make_strategy("fedavg", fl), FedAvg)
    assert isinstance(make_strategy("cafl", fl), CAFLL)
    for name, inner in (("fedadam", FedAvg), ("fedavgm", FedAvg),
                        ("cafl+adam", CAFLL)):
        st = make_strategy(name, fl)
        assert isinstance(st, ServerOpt) and isinstance(st.inner, inner)
    # fl.server_opt composes onto a plain method name
    st = make_strategy("cafl", fl.replace(server_opt="momentum"))
    assert isinstance(st, ServerOpt) and st.name == "cafl+momentum"
    with pytest.raises(ValueError):
        make_strategy("nope", fl)


def test_cafl_strategy_keeps_per_profile_duals():
    fl = get_fl_config()
    st = make_strategy("cafl", fl)
    profiles = {
        "a": DeviceProfile("a", fl.budgets),
        "b": DeviceProfile("b", fl.budgets.scaled(0.5)),
    }
    clients = [ClientInfo(0, profiles["a"], 10),
               ClientInfo(1, profiles["b"], 10)]
    knobs = st.configure_round(1, clients)
    assert len(knobs) == 2
    # both start at zero duals -> identical baseline knobs
    assert knobs[0] == knobs[1]
    heavy = {"energy": 9e6, "comm": 9.0, "memory": 9.0, "temp": 9.0}
    snap = st.update_state([heavy, heavy], clients)
    assert set(snap) == {"a", "b"}
    # the tighter-budget profile accumulates larger duals
    assert snap["b"]["comm"] > snap["a"]["comm"]
    kn2 = st.configure_round(2, clients)
    assert kn2[1].s <= kn2[0].s and kn2[1].k <= kn2[0].k


def test_fedavg_weighted_aggregate():
    import jax.numpy as jnp
    fl = get_fl_config()
    deltas = [{"w": jnp.ones(3)}, {"w": jnp.full(3, 3.0)}]
    plain = FedAvg(fl).aggregate(deltas, [1.0, 3.0])
    assert np.allclose(np.asarray(plain["w"]), 2.0)     # weights ignored
    weighted = FedAvg(fl, weighted=True).aggregate(deltas, [1.0, 3.0])
    assert np.allclose(np.asarray(weighted["w"]), 2.5)


def test_server_opt_first_step_direction():
    import jax.numpy as jnp
    fl = get_fl_config()
    st = ServerOpt(FedAvg(fl), "momentum", lr=1.0)
    delta = [{"w": jnp.full(4, 0.5)}]
    out = st.aggregate(delta)
    # momentum step moves WITH the client delta
    assert np.all(np.asarray(out["w"]) > 0)


# ---------------------------------------------------------------------------
# executors
# ---------------------------------------------------------------------------


def test_sequential_and_batched_histories_match(tiny_setup, tiny_model):
    ds, cfg, fl = tiny_setup
    for method in ("fedavg", "cafl"):
        runs = {}
        for ex in ("sequential", "batched"):
            res = FederatedEngine(tiny_model, fl, ds, strategy=method,
                                  executor=ex).run()
            runs[ex] = res
        for a, b in zip(runs["sequential"].history, runs["batched"].history):
            assert a.knobs == b.knobs
            assert a.val_loss == pytest.approx(b.val_loss, abs=2e-3)
            assert a.train_loss == pytest.approx(b.train_loss, abs=2e-3)
            assert a.usage == pytest.approx(b.usage)
            assert a.wire_mb_actual == pytest.approx(b.wire_mb_actual,
                                                     rel=1e-4)


def test_batched_groups_mixed_knobs(tiny_setup, tiny_model):
    """Clients with different knobs land in different vmap groups but the
    result order still matches the assignment order."""
    from repro.core.client import ClientRunner
    from repro.core.freezing import count_params
    from repro.core.policy import Knobs
    from repro.core.resources import calibrate
    from repro.data.federated import FederatedData
    import jax

    ds, cfg, fl = tiny_setup
    params = tiny_model.init(jax.random.PRNGKey(0))
    resources = calibrate(count_params(params), fl)
    data = FederatedData(ds.train, fl.num_clients, seed=fl.seed)
    runner = ClientRunner(tiny_model, fl, data, resources)
    ex = make_executor("batched", runner)
    profile = DeviceProfile("default", fl.budgets, resources=resources)
    kn_a = Knobs(k=2, s=2, b=4, q=0, grad_accum=1)
    kn_b = Knobs(k=1, s=2, b=4, q=2, grad_accum=2)
    assignments = [(ClientInfo(0, profile, 1), kn_a),
                   (ClientInfo(1, profile, 1), kn_b),
                   (ClientInfo(2, profile, 1), kn_a)]
    outs = ex.run_round(params, assignments)
    assert [o.client_id for o in outs] == [0, 1, 2]
    assert outs[0].params_active == outs[2].params_active
    assert outs[1].params_active < outs[0].params_active   # k=1 < k=2
    assert all(np.isfinite(o.train_loss) for o in outs)


def test_make_executor_unknown():
    with pytest.raises(ValueError):
        make_executor("warp", None)


# ---------------------------------------------------------------------------
# device profiles / fleets
# ---------------------------------------------------------------------------


def test_uniform_and_heterogeneous_fleet_specs():
    fl = get_fl_config()
    profiles, assignment = uniform_fleet(fl)
    assert set(assignment) == {"default"} and len(assignment) == fl.num_clients
    profiles, assignment = make_fleet(fl, [
        FleetClass("hi", 0.25, budget_scale=2.0),
        FleetClass("lo", 0.75, budget_scale=0.5, compute_scale=2.0)])
    assert len(assignment) == fl.num_clients
    assert assignment.count("hi") == round(0.25 * fl.num_clients)
    assert profiles["hi"].budgets.energy == pytest.approx(
        2.0 * fl.budgets.energy)
    assert profiles["lo"].budgets.comm_mb == pytest.approx(
        0.5 * fl.budgets.comm_mb)


def test_device_profile_resource_scaling():
    from repro.core.policy import fedavg_knobs
    from repro.core.resources import calibrate
    fl = get_fl_config()
    base = calibrate(1.9e6, fl)
    prof = DeviceProfile("lo", fl.budgets, compute_scale=1.5)
    prof = prof.with_resources(base)
    kn = fedavg_knobs(fl)
    u_base = base.usage(1.9e6, kn)
    u_lo = prof.resources.usage(1.9e6, kn)
    assert u_lo["energy"] == pytest.approx(1.5 * u_base["energy"])
    assert u_lo["temp"] == pytest.approx(1.5 * u_base["temp"])
    assert u_lo["comm"] == pytest.approx(u_base["comm"])   # wire unchanged
    # explicit resources are kept as-is
    assert prof.with_resources(base) is prof


def test_heterogeneous_run_records_per_profile(tiny_setup, tiny_model):
    ds, cfg, fl = tiny_setup
    fl4 = fl.replace(rounds=3, clients_per_round=4)
    profiles, assignment = make_fleet(fl4, [
        FleetClass("hi", 0.5, budget_scale=1.5),
        FleetClass("lo", 0.5, budget_scale=0.25, compute_scale=1.5)])
    res = FederatedEngine(tiny_model, fl4, ds, strategy="cafl",
                          profiles=profiles, client_profiles=assignment).run()
    last = res.history[-1]
    assert set(last.per_profile) == {"hi", "lo"}
    # the tight-budget tier must be driven to a cheaper operating point
    hi, lo = last.per_profile["hi"], last.per_profile["lo"]
    assert lo["duals"]["energy"] >= hi["duals"]["energy"]
    assert (lo["knobs"]["s"] < hi["knobs"]["s"]
            or lo["knobs"]["k"] < hi["knobs"]["k"]
            or lo["knobs"]["q"] > hi["knobs"]["q"])


# ---------------------------------------------------------------------------
# callbacks + wrapper compat
# ---------------------------------------------------------------------------


def test_callbacks_fire_and_write(tiny_setup, tiny_model, tmp_path):
    ds, cfg, fl = tiny_setup
    lines = []
    hist_path = str(tmp_path / "hist.json")
    ckpt_path = str(tmp_path / "final.ckpt")
    timing = TimingCallback()

    class Counter(RoundCallback):
        def __init__(self):
            self.starts = self.ends = 0
            self.train_started = self.train_ended = False

        def on_train_start(self, engine):
            self.train_started = True

        def on_round_start(self, engine, rnd):
            self.starts += 1

        def on_round_end(self, engine, record):
            self.ends += 1

        def on_train_end(self, engine, result):
            self.train_ended = True

    counter = Counter()
    res = FederatedEngine(
        tiny_model, fl, ds, strategy="fedavg",
        callbacks=[LoggingCallback(lines.append),
                   HistoryWriterCallback(hist_path),
                   CheckpointCallback(ckpt_path), timing, counter]).run()
    assert counter.train_started and counter.train_ended
    assert counter.starts == fl.rounds and counter.ends == fl.rounds
    assert len(lines) == fl.rounds and "round" in lines[0]
    assert len(timing.round_seconds) == fl.rounds
    assert timing.total_seconds is not None
    assert os.path.exists(ckpt_path)
    with open(hist_path) as f:
        payload = json.load(f)
    assert payload["method"] == "fedavg"
    assert len(payload["history"]) == fl.rounds
    assert payload["summary"]["val_loss"] == pytest.approx(
        res.summary()["val_loss"])


def test_run_federated_wrapper_unchanged(tiny_setup, tiny_model):
    """The seed entry point still works, including custom strategies via
    the method string."""
    from repro.core import run_federated
    ds, cfg, fl = tiny_setup
    res = run_federated(tiny_model, fl, ds, method="fedadam", rounds=2,
                        log=None)
    assert res.method == "fedavg+adam"
    assert len(res.history) == 2
    assert all(np.isfinite(r.val_loss) for r in res.history)
    assert res.history[0].per_profile == {}      # homogeneous fleet
