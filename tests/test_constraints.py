"""Tests for the first-class Constraint API (``repro.constraints``):

- stream equivalence: ``DeadzoneSubgradient`` is bit-for-bit the seed's
  ``dual_update``, and the explicitly-constructed default stack
  (``paper_constraints`` x ``DeadzoneSubgradient`` x ``PaperKnobPolicy``)
  reproduces the committed CAFLL golden trajectory,
- the constraint registry (a fifth constraint drives its own dual
  without touching ``core/duals.py``),
- knob policies incl. ``DeadlineAwareKnobPolicy`` deadline control,
- engine wiring: ``on_dual_update`` callback, ``RoundRecord.constraints``
  per-constraint fields, and the ``fl.constraints`` / ``fl.dual_controller``
  / ``fl.knob_policy`` config surface.

The hypothesis property suite for the controller invariants lives in
``tests/test_constraints_properties.py`` (skipped when hypothesis is
not installed, like the compression properties).
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.configs import get_config, get_fl_config
from repro.configs.base import Budgets, DualConfig
from repro.constraints import (
    AdaptiveStep, Constraint, ConstraintSet, DeadlineAwareKnobPolicy,
    DeadzoneSubgradient, PIController, PaperKnobPolicy, make_constraints,
    make_controller, make_knob_policy, paper_constraints,
    register_constraint,
)
from repro.core.duals import RESOURCES, DualState, dual_update
from repro.core.policy import policy
from repro.data import load_corpus
from repro.fl import (
    CAFLL, ClientInfo, DeadlineStragglers, DeviceProfile, FederatedEngine,
    FleetDynamics, NoStragglers, RoundCallback, RoundPlan, UniformSampler,
    make_strategy,
)
from repro.models import build

CFG = DualConfig()          # eta=0.35, deadzone=0.05, lambda_max=10.0


# ---------------------------------------------------------------------------
# stream equivalence with the seed dual update
# ---------------------------------------------------------------------------


def test_deadzone_controller_is_dual_update_bit_for_bit(rng):
    """Deterministic seeded-stream version of the hypothesis test in
    test_constraints_properties.py (which needs hypothesis installed)."""
    budgets = Budgets(energy=1.3, comm_mb=0.7, memory=0.9, temp=1.1)
    bmap = {"energy": 1.3, "comm": 0.7, "memory": 0.9, "temp": 1.1}
    ctrl = DeadzoneSubgradient()
    state = DualState()
    lam = {r: 0.0 for r in RESOURCES}
    for _ in range(200):
        usage = {r: float(u) for r, u in
                 zip(RESOURCES, rng.uniform(0.0, 10.0, size=4))}
        state = dual_update(state, usage, budgets, CFG)
        lam = {r: ctrl.step(r, lam[r], usage[r] / bmap[r], CFG)
               for r in RESOURCES}
        assert lam == state.lam                  # exact float equality


def test_paper_knob_policy_is_policy_bit_for_bit():
    cset = paper_constraints()
    fl = get_fl_config()
    pol = PaperKnobPolicy(constraints=cset)
    for lam in (0.0, 0.17, 0.5, 1.3, 4.0, 10.0):
        duals = DualState(lam={"energy": lam, "comm": lam / 3,
                               "memory": lam / 7, "temp": lam / 2})
        assert pol.knobs(duals, fl) == policy(duals, fl)


# ---------------------------------------------------------------------------
# the registry / constraint set
# ---------------------------------------------------------------------------


def test_make_constraints_specs():
    assert make_constraints().names == RESOURCES
    assert make_constraints("paper").names == RESOURCES
    five = make_constraints("paper+wire_mb")
    assert five.names == RESOURCES + ("wire_mb",)
    assert make_constraints(["energy", "comm"]).names == ("energy", "comm")
    custom = Constraint("fuel", measure=lambda rep: 1.0,
                        budget_of=lambda b: 2.0)
    assert make_constraints(["paper", custom]).names == \
        RESOURCES + ("fuel",)
    assert make_constraints(custom).names == ("fuel",)
    got = make_constraints(five)
    assert got is five                           # passthrough
    with pytest.raises(ValueError):
        make_constraints("paper+unobtainium")
    with pytest.raises(ValueError):
        ConstraintSet(list(paper_constraints()) + [make_constraints(
            "energy").constraints[0]])           # duplicate name
    with pytest.raises(ValueError):
        Constraint("x", measure=lambda r: 0.0, budget_of=lambda b: 1.0,
                   knob_group="turbo")


def test_grouped_lam_identity_on_paper_set():
    cset = paper_constraints()
    lam = {"energy": 0.3, "comm": 1.7, "memory": 0.0, "temp": 9.9}
    assert cset.grouped_lam(lam) == lam
    # a comm-grouped fifth constraint folds into the comm pressure;
    # a group-less one is observational
    five = make_constraints("paper+wire_mb+latency")
    lam5 = dict(lam, wire_mb=0.5, latency=3.0)
    grouped = five.grouped_lam(lam5)
    assert grouped["comm"] == pytest.approx(lam["comm"] + 0.5)
    assert set(grouped) == {"energy", "comm", "memory", "temp"}


def test_fifth_constraint_drives_own_dual_without_touching_duals_py():
    """Acceptance: a registered wire-MB constraint gets its own dual,
    moved by the controller, with core.duals untouched (RESOURCES is
    still the paper 4-tuple)."""
    assert RESOURCES == ("energy", "comm", "memory", "temp")
    fl = get_fl_config().replace(constraints="paper+wire_mb")
    strat = CAFLL(fl)
    prof = DeviceProfile("default", fl.budgets)
    clients = [ClientInfo(0, prof, 10)]
    # wire measurement blows the comm budget 5x; proxies stay in budget
    ok = {"energy": fl.budgets.energy, "comm": fl.budgets.comm_mb,
          "memory": fl.budgets.memory, "temp": fl.budgets.temp,
          "wire_mb": 5.0 * fl.budgets.comm_mb}
    snap = strat.update_state([ok], clients)
    assert snap["default"]["wire_mb"] > 0.0
    assert all(snap["default"][r] == 0.0 for r in RESOURCES)
    reps = {r.name: r for r in strat.constraint_reports()["default"]}
    assert reps["wire_mb"].violated and not reps["comm"].violated
    # its comm-group dual engages compression once pressure builds
    for _ in range(6):
        strat.update_state([ok], clients)
    kn = strat.configure_round(2, clients)[0]
    assert kn.q > 0


def test_register_constraint_custom():
    register_constraint("half_energy", lambda: Constraint(
        "half_energy", measure=lambda rep: rep.usage["energy"],
        budget_of=lambda b: b.energy / 2, knob_group="energy"))
    cset = make_constraints("paper+half_energy")
    assert cset.budgets_dict(Budgets())["half_energy"] == \
        pytest.approx(Budgets().energy / 2)


def test_constraint_set_measure_and_ratios():
    class Rep:
        usage = {"energy": 2.0, "comm": 0.3, "memory": 0.1, "temp": 0.5}
        wire_mb_actual = 1.2

    cset = make_constraints("paper+wire_mb")
    m = cset.measure(Rep())
    assert m["energy"] == 2.0 and m["wire_mb"] == 1.2
    b = Budgets(energy=1.0, comm_mb=0.6, memory=1.0, temp=1.0)
    r = cset.ratios(m, b)
    assert r["wire_mb"] == pytest.approx(2.0)
    assert cset.zero_usage() == {n: 0.0 for n in cset.names}


# ---------------------------------------------------------------------------
# resolution helpers
# ---------------------------------------------------------------------------


def test_pi_controller_holds_warm_start():
    """A warm-started dual (init_duals) must be held by the positional
    PI law: the integral seeds from the incoming lambda, so in-band
    ratios keep it stationary instead of snapping it to zero."""
    ctrl = PIController()
    lam = 5.0
    for _ in range(4):
        nxt = ctrl.step("k", lam, 1.0, CFG)       # in-band ratio
        assert nxt == pytest.approx(5.0)
        lam = nxt
    # and sustained violation still builds from the warm level
    assert ctrl.step("k", lam, 2.0, CFG) > 5.0


def test_proxy_control_loop_helper():
    from repro.constraints import (proxy_control_loop, rounds_to_band,
                                   tail_worst_ratio)
    fl = get_fl_config()
    band = 1.0 + fl.duals.deadzone
    hist = proxy_control_loop(fl, controller="deadzone", rounds=60)
    assert len(hist) == 60
    kn0, r0 = hist[0]
    assert kn0.k == fl.k_base and r0["comm"] > 5.0   # FedAvg start point
    hit_dz = rounds_to_band(hist, band)
    hit_ad = rounds_to_band(proxy_control_loop(fl, controller="adaptive",
                                               rounds=60), band)
    assert hit_dz is not None and hit_ad is not None and hit_ad < hit_dz
    assert tail_worst_ratio(hist) > 0.0
    assert rounds_to_band(hist, 0.0) is None


def test_make_controller_resolution():
    assert isinstance(make_controller(), DeadzoneSubgradient)
    assert isinstance(make_controller("adaptive"), AdaptiveStep)
    pi = PIController()
    assert make_controller(pi) is pi
    with pytest.raises(ValueError):
        make_controller("bang-bang")


def test_make_knob_policy_resolution():
    cset = paper_constraints()
    pol = make_knob_policy("paper", constraints=cset)
    assert isinstance(pol, PaperKnobPolicy) and pol.constraints is cset
    da = make_knob_policy("deadline_aware", constraints=cset)
    assert isinstance(da, DeadlineAwareKnobPolicy)
    assert isinstance(da.base, PaperKnobPolicy)
    inst = DeadlineAwareKnobPolicy()
    assert make_knob_policy(inst) is inst
    with pytest.raises(ValueError):
        make_knob_policy("vibes")


def test_instance_policy_gets_constraints_threaded():
    """An instance-passed policy with an unspecified constraint fold
    behaves like the equivalent string spec: the strategy's set is
    threaded in (through wrappers), while an explicit fold is kept."""
    five = make_constraints("paper+wire_mb")
    inst = DeadlineAwareKnobPolicy()
    assert make_knob_policy(inst, constraints=five) is inst
    assert inst.base.constraints is five
    # the wire_mb dual now folds into the comm group -> q engages
    fl = get_fl_config()
    duals = DualState(lam={**{r: 0.0 for r in RESOURCES}, "wire_mb": 2.0})
    assert inst.knobs(duals, fl).q == 2
    # explicit folds are not overwritten
    four = paper_constraints()
    explicit = PaperKnobPolicy(constraints=four)
    make_knob_policy(explicit, constraints=five)
    assert explicit.constraints is four
    # the CAFLL constructor path threads the same way
    strat = CAFLL(fl.replace(constraints="paper+wire_mb"),
                  knob_policy=DeadlineAwareKnobPolicy())
    assert strat.knob_policy.base.constraints is strat.constraints


def test_strategy_reset_restores_deadline_and_transients():
    """engine.run() resets control transients: a second run must not
    inherit the previous run's widened deadline (or ratchet its base),
    while duals keep their warm-continuation semantics."""
    dyn = FleetDynamics(sampler=UniformSampler(2),
                        stragglers=DeadlineStragglers(deadline=1.0))
    pol = DeadlineAwareKnobPolicy()
    fl = get_fl_config().replace(knob_policy=pol)
    strat = CAFLL(fl)
    assert strat.knob_policy is pol
    pol.observe(_plan((0, 1), (), (3.0, 3.0)), [], dyn)
    assert dyn.stragglers.deadline > 1.0
    strat.reset()                         # what engine.run() calls
    assert dyn.stragglers.deadline == 1.0
    assert pol.scale == 1.0 and pol._base_deadline is None


def test_make_strategy_threads_constraint_stack():
    fl = get_fl_config().replace(dual_controller="pi",
                                 constraints="paper+wire_mb")
    strat = make_strategy("cafl", fl)
    assert isinstance(strat.controller, PIController)
    assert strat.constraints.names == RESOURCES + ("wire_mb",)
    # explicit kwargs override the config
    strat2 = make_strategy("cafl", fl, controller="adaptive")
    assert isinstance(strat2.controller, AdaptiveStep)
    # wrapped strategies expose the inner constraint set
    wrapped = make_strategy("cafl+adam", fl)
    assert wrapped.constraints.names == strat.constraints.names


# ---------------------------------------------------------------------------
# deadline-aware knob policy (unit)
# ---------------------------------------------------------------------------


def _plan(sampled, survivors, times, rnd=1):
    sampled = tuple(sampled)
    survivors = tuple(survivors)
    return RoundPlan(round=rnd, available=sampled, sampled=sampled,
                     survivors=survivors,
                     dropped=tuple(c for c in sampled
                                   if c not in survivors),
                     times=tuple(times))


def test_deadline_aware_widens_on_starvation_and_relaxes():
    dyn = FleetDynamics(sampler=UniformSampler(4),
                        stragglers=DeadlineStragglers(deadline=1.0))
    pol = DeadlineAwareKnobPolicy(min_report_frac=0.5, widen=1.3,
                                  max_scale=4.0, relax=0.9, headroom=1.05)
    # 1/4 reported < 0.5 target -> widen to the time the median client
    # would have needed (quantile targeting), plus headroom
    pol.observe(_plan((0, 1, 2, 3), (0,), (0.9, 1.8, 2.0, 2.2)), [], dyn)
    assert dyn.stragglers.deadline == pytest.approx(1.8 * 1.05)
    # full report with fast arrivals -> relax back toward the base
    pol.observe(_plan((0, 1), (0, 1), (0.5, 0.6), rnd=2), [], dyn)
    assert dyn.stragglers.deadline == pytest.approx(1.8 * 1.05 * 0.9)
    # never relaxes below the original deadline
    for rnd in range(3, 30):
        pol.observe(_plan((0, 1), (0, 1), (0.5, 0.6), rnd=rnd), [], dyn)
    assert dyn.stragglers.deadline == pytest.approx(1.0)
    # ...and never below what the slowest reporter demonstrably needed
    pol2 = DeadlineAwareKnobPolicy(relax=0.5, headroom=1.05)
    dyn2 = FleetDynamics(sampler=UniformSampler(2),
                         stragglers=DeadlineStragglers(deadline=1.0))
    pol2.observe(_plan((0, 1), (), (3.0, 3.0)), [], dyn2)
    widened = dyn2.stragglers.deadline
    pol2.observe(_plan((0, 1), (0, 1), (3.0, 3.0), rnd=2), [], dyn2)
    assert dyn2.stragglers.deadline == pytest.approx(min(widened, 3.0 * 1.05))


def test_deadline_aware_caps_at_max_scale():
    dyn = FleetDynamics(sampler=UniformSampler(2),
                        stragglers=DeadlineStragglers(deadline=1.0))
    pol = DeadlineAwareKnobPolicy(max_scale=2.0)
    for rnd in range(1, 10):
        pol.observe(_plan((0, 1), (), (50.0, 60.0), rnd=rnd), [], dyn)
    assert dyn.stragglers.deadline == pytest.approx(2.0)


def test_deadline_aware_noop_without_deadline_model():
    dyn = FleetDynamics(sampler=UniformSampler(2), stragglers=NoStragglers())
    pol = DeadlineAwareKnobPolicy()
    pol.observe(_plan((0, 1), (), ()), [], dyn)      # must not raise
    assert pol.scale == 1.0
    # knobs pass through to the base policy
    fl = get_fl_config()
    assert pol.knobs(DualState(), fl) == policy(DualState(), fl)
    pol.reset()
    assert pol._base_deadline is None


# ---------------------------------------------------------------------------
# engine wiring (tiny runs)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_setup():
    ds = load_corpus(target_bytes=60_000)
    cfg = get_config("charlm-shakespeare").replace(
        vocab_size=max(ds.vocab_size, 64), num_layers=3, d_model=48,
        num_heads=4, num_kv_heads=4, head_dim=12, d_ff=96)
    fl = get_fl_config().replace(
        rounds=3, num_clients=4, clients_per_round=2, s_base=3, b_base=8,
        seq_len=16, eval_batches=1, eval_batch_size=8)
    fl = fl.replace(duals=dataclasses.replace(fl.duals, s_min=2, b_min=4))
    return ds, cfg, fl


@pytest.fixture(scope="module")
def tiny_model(tiny_setup):
    _, cfg, _ = tiny_setup
    return build(cfg)


def test_explicit_default_stack_matches_committed_golden(tiny_setup,
                                                         tiny_model):
    """Acceptance: the explicitly-constructed default stack reproduces
    the pre-refactor CAFLL golden trajectory (duals and knobs exactly,
    not just approximately) — the implicit stack is pinned by
    test_golden_trajectories; this pins the *explicit* construction
    path (instance passthrough included)."""
    golden_path = os.path.join(os.path.dirname(__file__), "golden",
                               "cafl.json")
    with open(golden_path) as f:
        want = json.load(f)
    ds, cfg, fl = tiny_setup
    strategy = CAFLL(fl, constraints=paper_constraints(),
                     controller=DeadzoneSubgradient(),
                     knob_policy=PaperKnobPolicy(paper_constraints()))
    res = FederatedEngine(tiny_model, fl, ds, strategy=strategy).run()
    assert len(res.history) == len(want["rounds"])
    for got, w in zip(res.history, want["rounds"]):
        assert got.knobs == w["knobs"]
        assert got.participants == w["participants"]
        for r, lam in w["duals"].items():
            assert got.duals[r] == pytest.approx(lam, abs=1e-9)
        for r, u in w["usage"].items():
            assert got.usage[r] == pytest.approx(u, rel=1e-6)


def test_engine_emits_dual_updates_and_constraint_records(tiny_setup,
                                                          tiny_model):
    ds, cfg, fl = tiny_setup
    fl5 = fl.replace(constraints="paper+wire_mb", dual_controller="adaptive")

    class Capture(RoundCallback):
        def __init__(self):
            self.calls = []

        def on_dual_update(self, engine, rnd, reports):
            self.calls.append((rnd, reports))

    cap = Capture()
    res = FederatedEngine(tiny_model, fl5, ds, strategy="cafl",
                          callbacks=[cap]).run()
    assert len(cap.calls) == fl5.rounds
    names = RESOURCES + ("wire_mb",)
    for rnd, reports in cap.calls:
        assert set(reports) == {"default"}
        per = {r.name: r for r in reports["default"]}
        assert tuple(per) == names
        for r in per.values():
            assert r.ratio == pytest.approx(r.usage / r.budget)
            assert r.violated == (r.ratio > 1.0)
            assert 0.0 <= r.lam <= fl5.duals.lambda_max
    for rec in res.history:
        assert tuple(rec.constraints) == names
        for n, slot in rec.constraints.items():
            assert set(slot) == {"ratio", "lam", "violated"}
            assert slot["lam"] == pytest.approx(rec.duals[n])
        assert "wire_mb" in rec.usage and "wire_mb" in rec.ratios


def test_engine_runs_pi_controller(tiny_setup, tiny_model):
    ds, cfg, fl = tiny_setup
    res = FederatedEngine(tiny_model, fl.replace(dual_controller="pi"),
                          ds, strategy="cafl").run()
    for rec in res.history:
        for lam in rec.duals.values():
            assert np.isfinite(lam) and 0.0 <= lam <= fl.duals.lambda_max


def test_deadline_aware_policy_recovers_dual_updates(tiny_setup, tiny_model):
    """Dual-aware deadline control end-to-end: with a deadline no
    baseline round can meet (jitter 0, deadline < 1 round), the paper
    stack starves — every client drops, no report arrives, duals stay
    frozen at zero. The deadline-aware policy widens the deadline from
    the observed arrival times and the dual update resumes."""
    ds, cfg, fl = tiny_setup
    fl_t = fl.replace(rounds=4)

    def dyn():
        # carry-over off so every client's wall clock is exactly its
        # knob time (the debt boost would entangle this test with the
        # async_fleet death-spiral scenario)
        return FleetDynamics(
            sampler=UniformSampler(fl_t.clients_per_round),
            stragglers=DeadlineStragglers.for_config(fl_t, deadline=0.7,
                                                     jitter=0.0),
            carryover_tokens=False)

    starved = FederatedEngine(tiny_model, fl_t, ds, strategy="cafl",
                              dynamics=dyn()).run()
    assert all(not r.participants for r in starved.history)
    assert all(lam == 0.0 for r in starved.history
               for lam in r.duals.values())

    d = dyn()
    recovered = FederatedEngine(
        tiny_model, fl_t.replace(knob_policy="deadline_aware"), ds,
        strategy="cafl", dynamics=d).run()
    assert d.stragglers.deadline > 0.7            # the server widened it
    assert any(r.participants for r in recovered.history)
    assert any(lam > 0.0 for r in recovered.history
               for lam in r.duals.values())
