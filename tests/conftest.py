import numpy as np
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="regenerate the committed golden trajectory files "
             "(tests/golden/*.json) instead of comparing against them")


@pytest.fixture(scope="session")
def update_golden(request):
    return request.config.getoption("--update-golden")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
