"""Virtual wall-clock simulation (repro.fl.clock) + the latency-dual
closed loop.

Covers the PR's contract three ways:

  * unit + hypothesis invariants for the clock primitives — monotone
    time, no event loss, deterministic tie-breaking;
  * stream equivalence — ``time_mode="rounds"`` is the default and
    bit-identical to the pre-clock engine (the golden trajectories pin
    that independently), and a no-straggler wall-clock run reproduces
    the rounds-mode stream exactly;
  * wall-clock semantics — late reports land at their simulated
    arrival time (never later than the rounds-mode round-delay
    quantization implies), FedBuff rounds end at their buffer events,
    ``horizon_seconds`` bounds the run, and the latency constraint's
    dual tightens the straggler deadline.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.configs import get_config, get_fl_config
from repro.configs.base import DualConfig
from repro.constraints import dual_config_for
from repro.data import load_corpus
from repro.fl import (CAFLL, DeadlineAwareKnobPolicy, DeadlineStragglers,
                      EventQueue, FedBuffAggregator, FederatedEngine,
                      FleetClass, FleetDynamics, KnobRoundTime,
                      RoundCallback, SimClock, UniformSampler, make_fleet,
                      make_round_time, uniform_fleet)
from repro.fl.device import ClientInfo, DeviceProfile
from repro.models import build

try:        # hypothesis variants run where installed (CI); the seeded
    from hypothesis import given, settings  # grid twins below always run
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# clock primitives
# ---------------------------------------------------------------------------


def test_sim_clock_monotone_and_logged():
    clk = SimClock()
    assert clk.now == 0.0
    assert clk.advance_to(2.5, "a") == 2.5
    # a past event is processed *now*: time never reverses
    assert clk.advance_to(1.0, "b") == 2.5
    assert clk.advance(0.5, "c") == 3.0
    assert [e[0] for e in clk.events] == ["a", "b", "c"]
    assert clk.events[1] == ("b", 1.0, 2.5)
    with pytest.raises(AssertionError):
        clk.advance(-0.1)


def _check_clock_monotone(ts):
    clk = SimClock()
    readings = [clk.advance_to(t) for t in ts]
    assert readings == sorted(readings)
    assert len(clk.events) == len(ts)          # every event logged
    if readings:
        assert clk.now == max(ts)


def _check_queue_partition(arrivals, cutoff):
    q = EventQueue()
    for i, a in enumerate(arrivals):
        q.push(a, f"r{i}")
    due = q.pop_until(cutoff)
    rest = q.drain()
    # partition: every event exactly once, on the right side of the cut
    assert len(due) + len(rest) == len(arrivals)
    assert all(e.arrival <= cutoff for e in due)
    assert all(e.arrival > cutoff for e in rest)
    got = sorted([e.report for e in due] + [e.report for e in rest])
    assert got == sorted(f"r{i}" for i in range(len(arrivals)))
    # delivery order: arrival time, then stamping order (ties resolve
    # to push order, which keeps homogeneous cohorts in cohort order)
    keys = [(e.arrival, e.seq) for e in due]
    assert keys == sorted(keys)
    assert len(q) == 0


def test_sim_clock_monotone_seeded_sweep():
    rng = np.random.default_rng(0)
    for n in (0, 1, 7, 50):
        _check_clock_monotone(list(rng.uniform(0.0, 1e6, size=n)))
    _check_clock_monotone([5.0, 5.0, 1.0, 9.0, 0.0])       # ties + reversals


def test_event_queue_partition_seeded_sweep():
    rng = np.random.default_rng(1)
    for n in (0, 1, 5, 40):
        arrivals = list(rng.uniform(0.0, 100.0, size=n))
        for cutoff in (0.0, 50.0, 100.0):
            _check_queue_partition(arrivals, cutoff)
    _check_queue_partition([2.0, 2.0, 2.0], 2.0)           # all-tie cut


def test_event_queue_rejects_illegal_arrivals():
    q = EventQueue()
    with pytest.raises(ValueError, match=">= 0"):
        q.push(-0.5, "r")
    for bad in (math.nan, math.inf, -math.inf):
        with pytest.raises(ValueError, match="finite"):
            q.stamp(bad, "r")
    for bad in (math.nan, math.inf):       # -inf trips the >= 0 check
        with pytest.raises(ValueError, match="finite"):
            q.push(bad, "r")
    assert len(q) == 0                     # nothing half-queued
    q.push(0.0, "ok")
    assert len(q) == 1


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=100)
    @given(st.lists(st.floats(min_value=0.0, max_value=1e6,
                              allow_nan=False), max_size=50))
    def test_sim_clock_monotone_under_any_event_order(ts):
        _check_clock_monotone(ts)

    @settings(deadline=None, max_examples=100)
    @given(st.lists(st.floats(min_value=0.0, max_value=100.0,
                              allow_nan=False), max_size=40),
           st.floats(min_value=0.0, max_value=100.0, allow_nan=False))
    def test_event_queue_no_loss_and_ordering(arrivals, cutoff):
        _check_queue_partition(arrivals, cutoff)


def test_knob_round_time_arms():
    fl = get_fl_config()
    rtm = KnobRoundTime.for_config(fl, server_seconds=0.1)
    prof = DeviceProfile("d", fl.budgets, compute_scale=2.0)
    ci = ClientInfo(0, prof)
    from repro.core.policy import fedavg_knobs
    kn = fedavg_knobs(fl)
    # baseline knobs on calibration silicon = 1.0 round; 2x silicon = 2.0
    assert rtm.client_seconds(ci, kn) == pytest.approx(
        2.0 * kn.grad_accum)
    # a missed deadline means the barrier waited it out
    assert rtm.round_seconds([ci], [kn], [0.5, 3.0], [0], 1.5) == \
        pytest.approx(1.5 + 0.1)
    # everyone made it: the slowest survivor sets the pace
    assert rtm.round_seconds([ci, ci], [kn, kn], [0.5, 1.2], [0, 1], 1.5) \
        == pytest.approx(1.2 + 0.1)
    # no straggler clock: knob-derived cohort time
    assert rtm.round_seconds([ci], [kn], [], [0], None) == \
        pytest.approx(2.0 * kn.grad_accum + 0.1)
    # a round nobody could join still takes positive time
    assert rtm.round_seconds([], [], [], [], None) > 0.0


def test_make_round_time_resolution():
    fl = get_fl_config()
    rtm = make_round_time(None, fl)
    assert isinstance(rtm, KnobRoundTime)
    assert rtm.work_unit == fl.s_base * fl.b_base
    inst = KnobRoundTime(work_unit=3.0)
    assert make_round_time(inst, fl) is inst
    with pytest.raises(ValueError):
        make_round_time("sundial", fl)


# ---------------------------------------------------------------------------
# per-constraint DualConfig overrides
# ---------------------------------------------------------------------------


def test_dual_config_for_overrides():
    base = DualConfig()
    assert dual_config_for(base, None, "energy") is base
    assert dual_config_for(base, {}, "energy") is base
    out = dual_config_for(base, {"latency": {"eta": 1.0, "deadzone": 0.0}},
                          "latency")
    assert out.eta == 1.0 and out.deadzone == 0.0
    assert out.lambda_max == base.lambda_max     # untouched fields kept
    assert dual_config_for(base, {"latency": {"eta": 1.0}}, "energy") is base
    full = DualConfig(eta=0.9)
    assert dual_config_for(base, {"comm": full}, "comm") is full
    with pytest.raises(TypeError):
        dual_config_for(base, {"comm": {"not_a_field": 1}}, "comm")


def test_cafll_per_constraint_dual_overrides():
    fl = get_fl_config().replace(
        constraints="paper+latency",
        dual_overrides={"latency": {"eta": 1.0, "deadzone": 0.0}})
    strat = CAFLL(fl)
    profiles, _ = uniform_fleet(fl)
    ci = ClientInfo(0, profiles["default"], shard_size=10)
    # 2x over on comm AND latency: the latency dual must move at its
    # own (faster) eta while comm moves at the shared paper eta
    budgets = fl.budgets
    usage = {"energy": budgets.energy, "comm": 2.0 * budgets.comm_mb,
             "memory": budgets.memory, "temp": budgets.temp,
             "latency": 2.0}
    duals = strat.update_state([usage], [ci])["default"]
    assert duals["comm"] == pytest.approx(fl.duals.eta * 1.0)
    assert duals["latency"] == pytest.approx(1.0 * 1.0)
    assert duals["energy"] == 0.0


def test_cafll_rejects_unknown_override_names():
    fl = get_fl_config().replace(dual_overrides={"latencyy": {"eta": 1.0}})
    with pytest.raises(ValueError, match="latencyy"):
        CAFLL(fl)


# ---------------------------------------------------------------------------
# latency-dual deadline control (unit)
# ---------------------------------------------------------------------------


def _plan(sampled, survivors, times, rnd=1):
    from repro.fl.dynamics import RoundPlan
    sampled, survivors = tuple(sampled), tuple(survivors)
    return RoundPlan(round=rnd, available=sampled, sampled=sampled,
                     survivors=survivors,
                     dropped=tuple(c for c in sampled if c not in survivors),
                     times=tuple(times))


def test_latency_dual_tightens_deadline():
    from repro.core.duals import DualState
    fl = get_fl_config()
    dyn = FleetDynamics(sampler=UniformSampler(2),
                        stragglers=DeadlineStragglers(deadline=2.0))
    pol = DeadlineAwareKnobPolicy(latency_gain=0.5, latency_budget=1.0)
    # strong latency pressure seen at knob time...
    lam = {r: 0.0 for r in ("energy", "comm", "memory", "temp")}
    pol.knobs(DualState(lam={**lam, "latency": 2.0}), fl)
    # ...and a fully reporting fleet: tighten toward budget/base = 0.5
    pol.observe(_plan((0, 1), (0, 1), (0.4, 0.5)), [], dyn)
    assert dyn.stragglers.deadline == pytest.approx(2.0 * 0.5)
    # pressure cleared: a below-base scale drifts back toward the base
    # at the relax rate (no permanent ratchet), converging to 1.0
    pol.knobs(DualState(lam=lam), fl)
    pol.observe(_plan((0, 1), (0, 1), (0.4, 0.5), rnd=2), [], dyn)
    assert dyn.stragglers.deadline == pytest.approx(2.0 * 0.5 / 0.9)
    for rnd in range(3, 30):
        pol.knobs(DualState(lam=lam), fl)
        pol.observe(_plan((0, 1), (0, 1), (0.4, 0.5), rnd=rnd), [], dyn)
    assert dyn.stragglers.deadline == pytest.approx(2.0)
    # re-applied pressure tightens again: the loop works both ways
    pol.knobs(DualState(lam={**lam, "latency": 2.0}), fl)
    pol.observe(_plan((0, 1), (0, 1), (0.4, 0.5), rnd=30), [], dyn)
    assert dyn.stragglers.deadline == pytest.approx(1.0)
    pol.reset()
    assert dyn.stragglers.deadline == 2.0 and pol._latency_lam == 0.0


def test_latency_dual_defers_to_starvation_recovery():
    """Dual tightening must not fight the widening arm: with the fleet
    starved the deadline still widens, pressure or not."""
    from repro.core.duals import DualState
    fl = get_fl_config()
    dyn = FleetDynamics(sampler=UniformSampler(2),
                        stragglers=DeadlineStragglers(deadline=1.0))
    pol = DeadlineAwareKnobPolicy()
    lam = {r: 0.0 for r in ("energy", "comm", "memory", "temp")}
    pol.knobs(DualState(lam={**lam, "latency": 5.0}), fl)
    pol.observe(_plan((0, 1), (), (3.0, 3.0)), [], dyn)
    assert dyn.stragglers.deadline > 1.0


def test_latency_tightening_respects_min_scale():
    from repro.core.duals import DualState
    fl = get_fl_config()
    dyn = FleetDynamics(sampler=UniformSampler(2),
                        stragglers=DeadlineStragglers(deadline=100.0))
    pol = DeadlineAwareKnobPolicy(min_scale=0.25, latency_gain=10.0)
    lam = {r: 0.0 for r in ("energy", "comm", "memory", "temp")}
    for rnd in range(1, 6):
        pol.knobs(DualState(lam={**lam, "latency": 10.0}), fl)
        pol.observe(_plan((0, 1), (0, 1), (0.1, 0.1), rnd=rnd), [], dyn)
    assert dyn.stragglers.deadline == pytest.approx(100.0 * 0.25)


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_setup():
    ds = load_corpus(target_bytes=60_000)
    cfg = get_config("charlm-shakespeare").replace(
        vocab_size=max(ds.vocab_size, 64), num_layers=2, d_model=32,
        num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64)
    fl = get_fl_config().replace(
        rounds=3, num_clients=6, clients_per_round=3, s_base=3, b_base=8,
        seq_len=16, eval_batches=1, eval_batch_size=8)
    fl = fl.replace(duals=dataclasses.replace(fl.duals, s_min=2, b_min=4))
    return ds, cfg, fl


@pytest.fixture(scope="module")
def tiny_model(tiny_setup):
    _, cfg, _ = tiny_setup
    return build(cfg)


def _stream(res):
    return [(r.round, r.participants, r.dropped, r.val_loss, r.duals)
            for r in res.history]


@pytest.mark.parametrize("method", ["fedavg", "cafl"])
def test_rounds_mode_is_the_default_and_explicit(method, tiny_setup,
                                                 tiny_model):
    """run() == run(time_mode="rounds"): the clock refactor left the
    default path untouched (the golden trajectories pin it against the
    pre-clock engine independently)."""
    ds, _, fl = tiny_setup
    a = FederatedEngine(tiny_model, fl, ds, strategy=method).run()
    b = FederatedEngine(tiny_model, fl, ds,
                        strategy=method).run(time_mode="rounds")
    assert _stream(a) == _stream(b)
    # rounds mode still fills the sim accounting fields
    assert all(r.round_seconds > 0 for r in a.history)
    assert [r.sim_time for r in a.history] == \
        sorted(r.sim_time for r in a.history)


@pytest.mark.parametrize("method", ["fedavg", "cafl"])
def test_wall_clock_stream_equals_rounds_without_stragglers(
        method, tiny_setup, tiny_model):
    """With no straggler clock and a sync barrier there is nothing for
    wall-clock mode to reorder: the two modes must produce the same
    stream bit-for-bit (same rng draws, same inbox order, same duals)."""
    ds, _, fl = tiny_setup
    a = FederatedEngine(tiny_model, fl, ds, strategy=method).run()
    b = FederatedEngine(tiny_model, fl, ds,
                        strategy=method).run(time_mode="wall_clock")
    assert _stream(a) == _stream(b)


def _hetero(fl):
    return make_fleet(fl, [FleetClass("fast", 0.5),
                           FleetClass("slow", 0.5, compute_scale=2.0)])


def _straggler_dyn(fl, deadline=1.1):
    return FleetDynamics(
        sampler=UniformSampler(fl.clients_per_round),
        stragglers=DeadlineStragglers.for_config(fl, deadline=deadline,
                                                 jitter=0.2))


def test_wall_clock_barrier_rounds_are_deadline_bounded(tiny_setup,
                                                        tiny_model):
    ds, _, fl = tiny_setup
    profiles, cp = _hetero(fl)
    eng = FederatedEngine(tiny_model, fl, ds, strategy="fedavg",
                          profiles=profiles, client_profiles=cp,
                          dynamics=_straggler_dyn(fl), aggregator="sync")
    res = eng.run(time_mode="wall_clock")
    deadline = 1.1
    for r in res.history:
        assert 0.0 < r.round_seconds <= deadline + 1e-9
    times = [r.sim_time for r in res.history]
    assert times == sorted(times) and times[0] > 0.0
    assert eng.clock is not None and eng.clock.now == times[-1]


class _UpdateCatcher(RoundCallback):
    def __init__(self):
        self.reports = []

    def on_server_update(self, engine, update):
        self.reports.extend(update.reports)


def test_wall_clock_late_delivery_at_arrival_time(tiny_setup, tiny_model):
    """Every report delivered after its training round (deadline
    missers AND survivors whose round ended at an earlier buffer event)
    lands in the round containing its simulated arrival — never later
    (in seconds) than the rounds-mode ``ceil(t/deadline)``
    quantization implies."""
    ds, _, fl = tiny_setup
    fl = fl.replace(rounds=5)
    profiles, cp = _hetero(fl)
    catcher = _UpdateCatcher()
    deadline = 1.1
    res = FederatedEngine(
        tiny_model, fl, ds, strategy="fedavg", profiles=profiles,
        client_profiles=cp, dynamics=_straggler_dyn(fl, deadline),
        aggregator=FedBuffAggregator(buffer_size=2),
        callbacks=[catcher]).run(time_mode="wall_clock")
    starts = {r.round: r.sim_time - r.round_seconds for r in res.history}
    ends = {r.round: r.sim_time for r in res.history}
    late = [rep for rep in catcher.reports
            if rep.round_submitted > rep.round_trained
            and rep.arrival_time > 0.0]
    assert late, "scenario must actually produce late deliveries"
    assert any(len(r.late_arrivals) > 0 for r in res.history)
    for rep in late:
        t0, rnd = rep.round_trained, rep.round_submitted
        abs_arrival = starts[t0] + rep.arrival_time
        # landed in the round whose window contains the arrival
        assert starts[rnd] <= abs_arrival + 1e-9
        assert abs_arrival <= ends[rnd] + 1e-9
        # and no later (in seconds) than the round-delay quantization
        # (the rounds-mode schedule holds a miss for ceil(t/D) full
        # deadline-lengths of simulated time after its round started)
        assert abs_arrival <= starts[t0] + \
            math.ceil(rep.arrival_time / deadline) * deadline + 1e-9


def test_wall_clock_fedbuff_rounds_end_at_buffer_events(tiny_setup,
                                                        tiny_model):
    """A buffered-async round ends at its first mid-round update, so
    FedBuff's simulated time runs ahead of the barrier's."""
    ds, _, fl = tiny_setup
    fl = fl.replace(rounds=5)
    profiles, cp = _hetero(fl)

    def run(agg):
        return FederatedEngine(
            tiny_model, fl, ds, strategy="fedavg", profiles=profiles,
            client_profiles=cp, dynamics=_straggler_dyn(fl),
            aggregator=agg).run(time_mode="wall_clock")

    sync = run("sync")
    buff = run(FedBuffAggregator(buffer_size=2))
    assert buff.history[-1].sim_time < sync.history[-1].sim_time
    # mid-round updates happened (not just final-drain bookkeeping)
    assert sum(r.updates_applied for r in buff.history) >= 1


def test_wall_clock_horizon_bounds_the_run(tiny_setup, tiny_model):
    ds, _, fl = tiny_setup
    profiles, cp = _hetero(fl)
    horizon = 3.0
    res = FederatedEngine(
        tiny_model, fl, ds, strategy="fedavg", profiles=profiles,
        client_profiles=cp, dynamics=_straggler_dyn(fl),
        aggregator="sync").run(horizon_seconds=horizon)
    assert res.history, "a horizon run must execute at least one round"
    # every round except possibly the last STARTED before the horizon
    for r in res.history:
        assert r.sim_time - r.round_seconds < horizon
    # and the run did not stop early: it ran until the budget was spent
    assert res.history[-1].sim_time >= min(horizon, 1.1)
    # horizon runs are not capped by fl.rounds
    assert len(res.history) != fl.rounds or \
        res.history[-1].sim_time >= horizon


def test_unknown_time_mode_rejected(tiny_setup, tiny_model):
    ds, _, fl = tiny_setup
    with pytest.raises(ValueError, match="time_mode"):
        FederatedEngine(tiny_model, fl, ds,
                        strategy="fedavg").run(time_mode="sundial")


def test_explicit_rounds_mode_beats_config_horizon(tiny_setup, tiny_model):
    """Arguments beat the config: an explicit time_mode="rounds" must
    not be silently flipped to wall clock by a leftover
    fl.horizon_seconds, and an explicitly contradictory pair raises."""
    ds, _, fl = tiny_setup
    base = FederatedEngine(tiny_model, fl, ds, strategy="fedavg").run()
    fl_h = fl.replace(horizon_seconds=50.0)
    eng = FederatedEngine(tiny_model, fl_h, ds, strategy="fedavg")
    res = eng.run(time_mode="rounds")
    assert eng.time_mode == "rounds"
    assert len(res.history) == fl.rounds
    assert _stream(res) == _stream(base)
    with pytest.raises(ValueError, match="horizon_seconds"):
        eng.run(time_mode="rounds", horizon_seconds=5.0)
    # an explicit round count caps a horizon run too
    res = eng.run(rounds=2, horizon_seconds=50.0)
    assert len(res.history) == 2


class _ZeroRoundTime(KnobRoundTime):
    def round_seconds(self, *a, **kw):
        return 0.0


def test_wall_clock_rejects_non_positive_round_durations(tiny_setup,
                                                         tiny_model):
    """A custom RoundTimeModel returning 0-length rounds must fail
    loudly, not spin the horizon loop into the backstop."""
    ds, _, fl = tiny_setup
    eng = FederatedEngine(tiny_model, fl, ds, strategy="fedavg",
                          round_time=_ZeroRoundTime.for_config(fl))
    with pytest.raises(ValueError, match="positive"):
        eng.run(time_mode="wall_clock")


def test_wall_clock_misser_never_delivered_in_own_round(tiny_setup,
                                                        tiny_model):
    """A deadline-misser whose arrival falls inside the round's
    server-cost tail (deadline < t <= deadline + server_seconds) must
    still deliver a round late with staleness >= 1 — a miss is never a
    same-round participant."""
    ds, _, fl = tiny_setup
    fl = fl.replace(rounds=3)
    # slow tier finishes at 1.15: past the 1.1 deadline, inside the
    # 1.1 + 0.2 server-cost tail
    profiles, cp = make_fleet(fl, [
        FleetClass("fast", 0.5),
        FleetClass("slow", 0.5, compute_scale=1.15)])
    dyn = FleetDynamics(
        sampler=UniformSampler(fl.clients_per_round),
        stragglers=DeadlineStragglers.for_config(fl, deadline=1.1,
                                                 jitter=0.0))
    catcher = _UpdateCatcher()
    FederatedEngine(
        tiny_model, fl, ds, strategy="fedavg", profiles=profiles,
        client_profiles=cp, dynamics=dyn,
        aggregator=FedBuffAggregator(buffer_size=100),  # drain at finalize
        round_time=KnobRoundTime.for_config(fl, server_seconds=0.2),
        callbacks=[catcher]).run(time_mode="wall_clock")
    missers = [rep for rep in catcher.reports if rep.arrival_time > 1.1]
    assert missers, "scenario must produce tail-window missers"
    for rep in missers:
        assert rep.round_submitted > rep.round_trained
        assert rep.staleness >= 1


def test_latency_closed_loop_tightens_deadline_in_wall_clock(tiny_setup,
                                                             tiny_model):
    """The full ROADMAP loop: latency constraint -> dual -> deadline ->
    simulated round length. A loose deadline lets a slow tier's ~2.0
    arrivals through, so the mean arrival ratio sits over the 1.0
    latency budget and the dual builds; the deadline-aware policy pulls
    the deadline down from that pressure until the slow tier is outside
    it, after which only in-budget arrivals feed the dual and it
    settles. min_report_frac is below the fast tier's share, so the
    starvation arm never fights the tightening."""
    from repro.fl import FullParticipation
    ds, _, fl = tiny_setup
    fl = fl.replace(rounds=6, constraints="paper+latency",
                    dual_overrides={"latency": {"eta": 1.0,
                                                "deadzone": 0.0}})
    dyn = FleetDynamics(
        sampler=FullParticipation(),
        stragglers=DeadlineStragglers.for_config(fl, deadline=4.0,
                                                 jitter=0.0))
    profiles, cp = _hetero(fl)          # fast 1.0x / slow 2.0x tiers
    strat = CAFLL(fl, knob_policy=DeadlineAwareKnobPolicy(
        min_report_frac=0.4))
    eng = FederatedEngine(tiny_model, fl, ds, strategy=strat,
                          profiles=profiles, client_profiles=cp,
                          dynamics=dyn, aggregator="sync")
    res = eng.run(time_mode="wall_clock")
    # pressure built on the latency dual...
    assert any(r.constraints["latency"]["lam"] > 0.0 for r in res.history)
    # ...and the closed loop tightened the deadline, which capped at
    # least one later round's simulated cost below the opening round's
    # (Eq. 8 token preservation keeps per-client compute roughly
    # constant, so only the deadline can shorten a straggler-bound
    # round)
    assert dyn.stragglers.deadline < 4.0
    assert min(r.round_seconds for r in res.history[1:]) < \
        res.history[0].round_seconds
