"""End-to-end system behaviour: the dry-run launcher (subprocess, tiny
mesh) and the sharding recipe's structural guarantees."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_dryrun(tmp_path, arch, shape):
    env = dict(os.environ)
    env.update({
        "PYTHONPATH": SRC,
        "DRYRUN_DEVICES": "8",
        "REPRO_MESH_OVERRIDE": "2,4",
        "DRYRUN_DIR": str(tmp_path),
    })
    code = (
        "import repro.launch.dryrun as d\n"
        "import repro.configs.registry as reg\n"
        "d.get_config = reg.get_smoke_config\n"
        "from repro.configs.base import INPUT_SHAPES, InputShape\n"
        "INPUT_SHAPES['train_4k'] = InputShape('train_4k', 128, 8, 'train')\n"
        "INPUT_SHAPES['decode_32k'] = InputShape('decode_32k', 256, 8, 'decode')\n"
        "INPUT_SHAPES['prefill_32k'] = InputShape('prefill_32k', 256, 8, 'prefill')\n"
        "INPUT_SHAPES['long_500k'] = InputShape('long_500k', 2048, 1, 'decode')\n"
        f"rec = d.run_one('{arch}', '{shape}', False, out_dir='{tmp_path}', force=True)\n"
        "assert rec['status'] == 'ok', rec.get('error', '')[-2000:]\n"
    )
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    with open(os.path.join(str(tmp_path),
                           f"{arch}__{shape}__singlepod.json")) as f:
        return json.load(f)


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("gemma2-9b", "train_4k"),
    ("phi3.5-moe-42b-a6.6b", "decode_32k"),
    ("xlstm-1.3b", "long_500k"),
])
def test_dryrun_lowers_and_compiles(tmp_path, arch, shape):
    rec = _run_dryrun(tmp_path, arch, shape)
    assert rec["status"] == "ok"
    r = rec["roofline"]
    assert r["hlo_flops_per_device"] > 0
    assert r["t_compute_s"] >= 0 and r["t_memory_s"] > 0
    assert rec["collectives"]["total_bytes_per_device"] > 0
    assert r["dominant"] in ("compute", "memory", "collective")


def test_sharding_recipe_divisibility():
    """Every full config's parameter sharding must only split divisible
    dims (replicate otherwise) — structural check without a real mesh."""
    import jax
    from repro.configs import ARCH_IDS, get_config
    from repro.launch import specs as S
    from repro.models import build

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
        devices = None

    captured_orig = S.NamedSharding

    def fake_ns(mesh, spec):
        return spec

    S.NamedSharding = fake_ns
    try:
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            model = build(cfg)
            shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            tree = S.param_shardings(FakeMesh(), shapes, cfg)
            flat_specs = dict(S._tree_paths(tree))
            flat_shapes = dict(S._tree_paths(shapes))
            n_sharded = 0
            for path, spec in flat_specs.items():
                dims = flat_shapes[path].shape
                for dim, ax in zip(dims, tuple(spec)):
                    if ax is None:
                        continue
                    n_sharded += 1
                    n = 16
                    assert dim % n == 0, (arch, path, dims, spec)
            assert n_sharded > 0, f"{arch}: nothing sharded"
    finally:
        S.NamedSharding = captured_orig
