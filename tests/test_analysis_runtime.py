"""Runtime-sanitizer pins: the steady-state engine round loop runs with
zero implicit host<->device transfers and zero jit recompiles after
round 1 (repro.analysis.runtime). Tests skip gracefully when the jax
build lacks the transfer-guard / monitoring hooks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.runtime import (RecompileWatchCallback, RecompileWatcher,
                                    TransferGuardCallback, no_transfers,
                                    transfer_guard_supported)
from repro.configs import get_config, get_fl_config
from repro.data import load_corpus
from repro.fl import FederatedEngine
from repro.models import build

needs_guard = pytest.mark.skipif(not transfer_guard_supported(),
                                 reason="jax build has no transfer_guard")


# ---------------------------------------------------------------------------
# the primitives
# ---------------------------------------------------------------------------


@needs_guard
def test_no_transfers_blocks_implicit_h2d():
    x = jnp.asarray(np.arange(4, dtype=np.float32))
    with pytest.raises(Exception):
        with no_transfers():
            _ = x + 1               # Python scalar operand: implicit h2d


@needs_guard
def test_no_transfers_allows_staged_and_jitted_work():
    x = jnp.asarray(np.arange(4, dtype=np.float32))
    one = jnp.asarray(np.asarray(1.0, np.float32))
    f = jax.jit(lambda a: a * 2)
    _ = f(x)                        # warm the cache outside the guard
    with no_transfers():
        y = f(x + one)
        _ = np.asarray(y)           # explicit d2h stays allowed
    assert float(np.asarray(y)[0]) == pytest.approx(2.0)


def test_recompile_watcher_counts_cache_misses():
    w = RecompileWatcher()
    if not w.supported:
        pytest.skip("jax build has no monitoring hooks")

    @jax.jit
    def g(a):
        return a * 3

    x = jnp.asarray(np.arange(8, dtype=np.float32))
    with w:
        g(x)
        first = w.mark("cold")
        g(x)                        # identical shapes: cache hit
        assert w.mark("warm") == 0
        g(jnp.asarray(np.arange(16, dtype=np.float32)))  # new shape
        second = w.mark("reshape")
    assert first >= 1 and second >= 1
    assert w.buckets["warm"] == 0
    assert w.total == first + second


# ---------------------------------------------------------------------------
# the engine pins
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_run():
    """One 3-round fedavg/sequential/sync run under both sanitizers."""
    ds = load_corpus(target_bytes=60_000)
    cfg = get_config("charlm-shakespeare").replace(
        vocab_size=max(ds.vocab_size, 64), num_layers=3, d_model=48,
        num_heads=4, num_kv_heads=4, head_dim=12, d_ff=96)
    fl = get_fl_config().replace(
        rounds=3, num_clients=4, clients_per_round=2, s_base=3, b_base=8,
        seq_len=16, eval_batches=1, eval_batch_size=8)
    fl = fl.replace(duals=dataclasses.replace(fl.duals, s_min=2, b_min=4))
    guard = TransferGuardCallback(from_round=2)
    watch = RecompileWatchCallback()
    try:
        result = FederatedEngine(build(cfg), fl, ds, strategy="fedavg",
                                 executor="sequential",
                                 callbacks=[guard, watch]).run()
    finally:
        guard.close()               # an engine crash must not leak the guard
    return result, guard, watch


@needs_guard
def test_engine_steady_state_is_transfer_free(tiny_run):
    """Rounds >= 2 run under jax.transfer_guard("disallow"): the round
    loop finishing at all IS the assertion — any implicit transfer in
    client training, aggregation or eval would have raised."""
    result, guard, _ = tiny_run
    assert len(result.history) == 3
    assert guard.guarded_rounds == [2, 3]


def test_engine_zero_recompiles_after_round_one(tiny_run):
    """Round 1 warms every jit cache (train step, masked apply, eval);
    from round 2 on the same executables must be reused — a drifting
    shape or static argument would show up as a backend compile."""
    _, _, watch = tiny_run
    if not watch.supported:
        pytest.skip("jax build has no monitoring hooks")
    assert watch.per_round.get(1, 0) > 0, "round 1 should compile"
    assert watch.steady_state_compiles(first_steady_round=2) == 0, (
        f"steady-state rounds recompiled: {watch.per_round}")
