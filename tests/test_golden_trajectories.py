"""Golden regression anchors for the federated engine.

Tiny fixed-seed FedAvg and CAFL-L runs whose per-round losses, knobs,
duals and participation sets are checked against committed JSON
(``tests/golden/``). Engine refactors that change semantics — sampling
stream, aggregation math, dual updates, knob policy — fail here even if
every behavioral test still passes.

Regenerate after an *intentional* semantic change with:

    PYTHONPATH=src python -m pytest tests/test_golden_trajectories.py \
        --update-golden

and commit the diff with a justification (see tests/README.md).
"""
import dataclasses
import json
import os

import pytest

from repro.configs import get_config, get_fl_config
from repro.data import load_corpus
from repro.fl import FederatedEngine
from repro.models import build

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

# losses go through jitted matmuls: allow cross-BLAS wiggle, far below
# the ~1e-1 shift a semantic change (different batch stream) causes
LOSS_TOL = 5e-3
# duals/usages are host-side float arithmetic on deterministic inputs
EXACT_TOL = 1e-9


@pytest.fixture(scope="module")
def tiny_setup():
    ds = load_corpus(target_bytes=60_000)
    cfg = get_config("charlm-shakespeare").replace(
        vocab_size=max(ds.vocab_size, 64), num_layers=3, d_model=48,
        num_heads=4, num_kv_heads=4, head_dim=12, d_ff=96)
    fl = get_fl_config().replace(
        rounds=3, num_clients=4, clients_per_round=2, s_base=3, b_base=8,
        seq_len=16, eval_batches=1, eval_batch_size=8)
    fl = fl.replace(duals=dataclasses.replace(fl.duals, s_min=2, b_min=4))
    return ds, cfg, fl


@pytest.fixture(scope="module")
def tiny_model(tiny_setup):
    _, cfg, _ = tiny_setup
    return build(cfg)


def _trajectory(result):
    return {
        "method": result.method,
        "rounds": [
            {
                "round": r.round,
                "val_loss": r.val_loss,
                "train_loss": r.train_loss,
                "knobs": r.knobs,
                "duals": r.duals,
                "usage": r.usage,
                "wire_mb_actual": r.wire_mb_actual,
                "participants": r.participants,
                "dropped": r.dropped,
                "num_available": r.num_available,
            }
            for r in result.history
        ],
    }


def _check_round(got, want, rnd):
    assert got["round"] == want["round"]
    assert got["knobs"] == want["knobs"], f"round {rnd}: knob policy moved"
    assert got["participants"] == want["participants"], \
        f"round {rnd}: sampling stream moved"
    assert got["dropped"] == want["dropped"]
    assert got["num_available"] == want["num_available"]
    for key in ("val_loss", "train_loss", "wire_mb_actual"):
        assert got[key] == pytest.approx(want[key], rel=LOSS_TOL,
                                         abs=LOSS_TOL), \
            f"round {rnd}: {key} drifted"
    for res, lam in want["duals"].items():
        assert got["duals"][res] == pytest.approx(lam, abs=EXACT_TOL), \
            f"round {rnd}: dual {res} moved"
    for res, u in want["usage"].items():
        assert got["usage"][res] == pytest.approx(u, rel=1e-6), \
            f"round {rnd}: usage {res} moved"


@pytest.mark.parametrize("method", ["fedavg", "cafl"])
def test_golden_trajectory(method, tiny_setup, tiny_model, update_golden):
    ds, cfg, fl = tiny_setup
    res = FederatedEngine(tiny_model, fl, ds, strategy=method).run()
    got = _trajectory(res)
    path = os.path.join(GOLDEN_DIR, f"{method}.json")
    if update_golden:
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(path, "w") as f:
            json.dump(got, f, indent=1, sort_keys=True)
        pytest.skip(f"golden regenerated: {path}")
    assert os.path.exists(path), \
        f"missing golden {path}; run with --update-golden to create it"
    with open(path) as f:
        want = json.load(f)
    assert got["method"] == want["method"]
    assert len(got["rounds"]) == len(want["rounds"])
    for g, w in zip(got["rounds"], want["rounds"]):
        _check_round(g, w, g["round"])
