"""The perf-trajectory harness (`repro.bench` + `benchmarks/run.py`):
schema round-trip, direction-aware compare verdicts, the ratchet's
exit behavior on a synthetic regression, registry completeness, and a
tiny-scale run of every registered benchmark (the `benchmarks/` tree's
first test coverage)."""
import dataclasses
import json

import pytest

from repro.bench import (FAILING, IMPROVEMENT, MISSING, NEW, REGRESSION,
                         WITHIN_NOISE, Benchmark, BenchmarkRecord,
                         Fingerprint, MetricRecord, MetricSpec, Snapshot,
                         TimingStats, all_benchmarks, areas, compare_metric,
                         compare_snapshots, run_benchmark, snapshot_filename,
                         time_callable)
from repro.bench import compare as compare_cli
from repro.bench.schema import SCHEMA_VERSION

FP = Fingerprint(jax_version="0.0.test", backend="cpu", device_kind="cpu",
                 cpu_count=1, python_version="3.10.0")


def mrec(name, value, direction="lower", rtol=0.1, atol=0.0, unit="us"):
    return MetricRecord(name=name, value=value, unit=unit,
                        direction=direction, rtol=rtol, atol=atol)


def snap(metrics, area="test_area", scale="smoke", benchmark="b.one"):
    return Snapshot(area=area, scale=scale, fingerprint=FP,
                    records=(BenchmarkRecord(benchmark=benchmark, scale=scale,
                                             metrics=tuple(metrics),
                                             context={"note": "synthetic"}),))


# ---------------------------------------------------------------- schema

class TestSchema:
    def test_round_trip(self):
        s = snap([mrec("t_us", 123.4), mrec("speedup", 1.4,
                                            direction="higher", unit="x")])
        assert Snapshot.from_json(s.to_json()) == s

    def test_json_is_typed_not_strings(self):
        s = snap([mrec("speedup", 1.43, direction="higher", unit="x")])
        d = json.loads(s.to_json())
        m = d["records"][0]["metrics"][0]
        assert m["value"] == 1.43 and isinstance(m["value"], float)
        assert m["direction"] == "higher"
        assert d["schema_version"] == SCHEMA_VERSION

    def test_newer_schema_rejected(self):
        d = json.loads(snap([mrec("t_us", 1.0)]).to_json())
        d["schema_version"] = SCHEMA_VERSION + 1
        with pytest.raises(ValueError, match="newer"):
            Snapshot.from_dict(d)

    def test_bad_direction_rejected(self):
        with pytest.raises(ValueError, match="direction"):
            mrec("t_us", 1.0, direction="sideways")
        with pytest.raises(ValueError, match="direction"):
            MetricSpec("t_us", unit="us", direction="sideways")

    def test_save_load(self, tmp_path):
        s = snap([mrec("t_us", 123.4)])
        path = tmp_path / snapshot_filename("test_area")
        s.save(str(path))
        assert Snapshot.load(str(path)) == s

    def test_lookups(self):
        s = snap([mrec("t_us", 1.0)])
        assert s.record("b.one").metric("t_us").value == 1.0
        assert s.record("b.two") is None
        assert s.record("b.one").metric("nope") is None


# --------------------------------------------------------------- compare

class TestCompareVerdicts:
    def test_lower_is_better_regresses_upward(self):
        base = mrec("rounds_to_target", 4.0, rtol=0.0, atol=1.0)
        assert compare_metric(base, mrec("rounds_to_target", 6.0))[0] \
            == REGRESSION
        assert compare_metric(base, mrec("rounds_to_target", 5.0))[0] \
            == WITHIN_NOISE
        assert compare_metric(base, mrec("rounds_to_target", 2.0))[0] \
            == IMPROVEMENT

    def test_higher_is_better_regresses_downward(self):
        base = mrec("batched_speedup", 1.4, direction="higher", rtol=0.25)
        assert compare_metric(base, mrec("batched_speedup", 1.0))[0] \
            == REGRESSION
        assert compare_metric(base, mrec("batched_speedup", 1.3))[0] \
            == WITHIN_NOISE
        assert compare_metric(base, mrec("batched_speedup", 2.0))[0] \
            == IMPROVEMENT

    def test_band_is_max_of_atol_rtol(self):
        base = mrec("du", 0.1, rtol=0.25, atol=1.0)   # atol dominates
        assert compare_metric(base, mrec("du", 1.05))[0] == WITHIN_NOISE
        assert compare_metric(base, mrec("du", 1.2))[0] == REGRESSION

    def test_tol_scale_widens_band(self):
        base = mrec("t_us", 100.0, rtol=0.1)
        assert compare_metric(base, mrec("t_us", 115.0))[0] == REGRESSION
        assert compare_metric(base, mrec("t_us", 115.0),
                              tol_scale=2.0)[0] == WITHIN_NOISE

    def test_missing_metric_fails_new_does_not(self):
        base = snap([mrec("a_us", 1.0), mrec("b_us", 2.0)])
        fresh = snap([mrec("a_us", 1.0), mrec("c_us", 3.0)])
        report = compare_snapshots(base, fresh)
        verdicts = {(d.metric): d.verdict for d in report.diffs}
        assert verdicts["b_us"] == MISSING and MISSING in FAILING
        assert verdicts["c_us"] == NEW and NEW not in FAILING
        assert not report.ok

    def test_identical_snapshots_ok(self):
        s = snap([mrec("a_us", 1.0), mrec("s", 2.0, direction="higher")])
        report = compare_snapshots(s, s)
        assert report.ok and all(d.verdict == WITHIN_NOISE
                                 for d in report.diffs)

    def test_scale_and_fingerprint_mismatch_are_notes(self):
        base = snap([mrec("a_us", 1.0)])
        fresh = dataclasses.replace(
            snap([mrec("a_us", 1.0)], scale="tiny"),
            fingerprint=dataclasses.replace(FP, cpu_count=64))
        report = compare_snapshots(base, fresh)
        assert report.ok and len(report.notes) == 2

    def test_render_mentions_regression(self):
        base = snap([mrec("a_us", 100.0, rtol=0.1)])
        report = compare_snapshots(base, snap([mrec("a_us", 200.0)]))
        assert REGRESSION in report.render()


class TestCompareCLI:
    def test_synthetic_regression_exits_nonzero(self, tmp_path, capsys):
        base = snap([mrec("speedup", 2.0, direction="higher", rtol=0.1,
                          unit="x")])
        fresh = snap([mrec("speedup", 1.0, direction="higher", unit="x")])
        bp, fp_ = tmp_path / "base.json", tmp_path / "fresh.json"
        base.save(str(bp))
        fresh.save(str(fp_))
        assert compare_cli.main([str(bp), str(fp_)]) == 1
        assert REGRESSION in capsys.readouterr().out

    def test_clean_compare_exits_zero(self, tmp_path):
        s = snap([mrec("t_us", 5.0)])
        p = tmp_path / "s.json"
        s.save(str(p))
        assert compare_cli.main([str(p), str(p)]) == 0


# ------------------------------------------------------- run.py ratchet

class TestRunCheck:
    """`python -m benchmarks.run --check` semantics, on synthetic
    snapshots (the real benchmarks are exercised at tiny scale below)."""

    def test_regression_fails_check(self, tmp_path):
        from benchmarks.run import check_areas
        base = snap([mrec("rounds_to_target", 4.0, rtol=0.0, atol=1.0)])
        base.save(str(tmp_path / snapshot_filename("test_area")))
        fresh = snap([mrec("rounds_to_target", 7.0)])   # regressed upward
        reports, ok = check_areas({"test_area": fresh}, str(tmp_path))
        assert not ok and reports[0].regressions

    def test_matching_passes_check(self, tmp_path):
        from benchmarks.run import check_areas
        s = snap([mrec("t_us", 5.0)])
        s.save(str(tmp_path / snapshot_filename("test_area")))
        reports, ok = check_areas({"test_area": s}, str(tmp_path))
        assert ok and reports[0].ok

    def test_missing_baseline_fails_check(self, tmp_path, capsys):
        from benchmarks.run import check_areas
        _, ok = check_areas({"test_area": snap([mrec("t_us", 1.0)])},
                            str(tmp_path))
        assert not ok
        assert "--record" in capsys.readouterr().err


class TestOnlySelection:
    def test_unknown_name_errors(self):
        from benchmarks.run import load_registry, select
        load_registry()
        with pytest.raises(SystemExit):
            select("kernal")          # the silent-no-op bug, now an error

    def test_prefixes_and_aliases(self):
        from benchmarks.run import load_registry, select
        load_registry()
        mods, sel = select("table1,fig2")
        assert mods == ["table1", "fig2_constraints"] and sel == []
        mods, sel = select("kernel_bench")       # legacy module name
        assert mods == [] and sel == ["kernels"]
        mods, sel = select("wire_bench")         # module-name alias
        assert mods == [] and sel == ["wire"]
        mods, sel = select("fl.executor")        # benchmark name -> area
        assert sel == ["fl_engine"]

    def test_default_selects_everything(self):
        from benchmarks.run import ANALYSIS_MODULES, load_registry, select
        load_registry()
        mods, sel = select(None)
        assert mods == ANALYSIS_MODULES
        assert set(sel) == {"fl_engine", "kernels", "wire"}


# -------------------------------------------------------------- registry

EXPECTED = {"fl_engine": {"fl.executor", "fl.dynamics", "fl.aggregator",
                          "fl.wall_clock", "fl.controller",
                          "fl.memory_static"},
            "kernels": {"kernel.quantize_roundtrip",
                        "kernel.blockwise_attention", "charlm.grad_step"},
            "wire": {"wire.quantize_topk", "wire.masked_sum"}}


@pytest.fixture(scope="module")
def registry():
    from benchmarks.run import load_registry
    load_registry()
    return {a: all_benchmarks(a) for a in areas()}


class TestRegistryCompleteness:
    def test_expected_benchmarks_registered(self, registry):
        assert set(registry) == set(EXPECTED)
        for area, benches in registry.items():
            assert {b.name for b in benches} == EXPECTED[area]

    def test_every_benchmark_has_all_scales(self, registry):
        for benches in registry.values():
            for b in benches:
                assert set(b.presets) >= {"tiny", "smoke", "full"}, b.name
                assert b.metrics, b.name

    def test_speedup_and_rounds_directions(self, registry):
        """The ratchet's direction-awareness on the two metrics the
        issue names: batched_speedup regresses downward,
        rounds_to_target upward."""
        by_name = {b.name: b for bs in registry.values() for b in bs}
        assert by_name["fl.executor"].spec("batched_speedup").direction \
            == "higher"
        assert by_name["fl.aggregator"].spec(
            "fedbuff_rounds_to_target").direction == "lower"

    def test_duplicate_metric_names_rejected(self):
        with pytest.raises(ValueError, match="duplicate"):
            Benchmark(name="b", area="a", fn=lambda p: {},
                      metrics=(MetricSpec("m", unit="us"),
                               MetricSpec("m", unit="us")),
                      presets={"tiny": {}, "smoke": {}, "full": {}})

    def test_missing_preset_rejected(self):
        with pytest.raises(ValueError, match="presets"):
            Benchmark(name="b", area="a", fn=lambda p: {},
                      metrics=(MetricSpec("m", unit="us"),),
                      presets={"smoke": {}})


# ---------------------------------------------------------------- runner

class TestRunner:
    def test_time_callable_stats(self):
        calls = []
        stats = time_callable(lambda: calls.append(1), warmup=2, repeats=8,
                              block=False)
        assert len(calls) == 10 and stats.n == 8
        assert stats.median_us >= 0 and stats.iqr_us >= 0

    def test_metric_mismatch_rejected(self):
        b = Benchmark(name="b", area="a",
                      fn=lambda p: {"declared": 1.0, "undeclared": 2.0},
                      metrics=(MetricSpec("declared", unit="us"),
                               MetricSpec("absent", unit="us")),
                      presets={"tiny": {}, "smoke": {}, "full": {}})
        with pytest.raises(ValueError, match="metric mismatch"):
            run_benchmark(b, "tiny")

    def test_timing_stats_flow_into_record(self):
        b = Benchmark(
            name="b", area="a",
            fn=lambda p: {"t_us": TimingStats(median_us=7.0, iqr_us=1.0,
                                              n=5),
                          "x": 2.0, "context": {"k": "v"}},
            metrics=(MetricSpec("t_us", unit="us"),
                     MetricSpec("x", unit="x", direction="higher")),
            presets={"tiny": {}, "smoke": {}, "full": {}})
        rec = run_benchmark(b, "tiny")
        t = rec.metric("t_us")
        assert (t.value, t.iqr, t.n) == (7.0, 1.0, 5)
        assert rec.metric("x").n == 1 and rec.context == {"k": "v"}

    def test_unknown_scale_rejected(self):
        b = Benchmark(name="b", area="a", fn=lambda p: {},
                      metrics=(MetricSpec("m", unit="us"),),
                      presets={"tiny": {}, "smoke": {}, "full": {}})
        with pytest.raises(KeyError, match="preset"):
            run_benchmark(b, "galactic")


# ------------------------------------------------------------ csv shim

class TestEmitter:
    def test_snapshot_rows_legacy_format(self):
        from benchmarks.common import snapshot_rows
        s = snap([mrec("t_us", 12.3), mrec("speedup", 1.4,
                                           direction="higher", unit="x")])
        rows = dict((name, (us, derived))
                    for name, us, derived in snapshot_rows(s))
        assert rows["b.one.t_us"][0] == 12.3            # us column filled
        assert rows["b.one.speedup"][0] == 0.0          # derived metric
        assert "1.4x" in rows["b.one.speedup"][1]
        assert rows["b.one.note"] == (0.0, "synthetic")

    def test_header_emitted_once(self, capsys):
        import benchmarks.common as common
        old = common._header_emitted
        common._header_emitted = False
        try:
            common.emit([("a", 1.0, "x")])
            common.emit([("b", 2.0, "y")])
            out = capsys.readouterr().out
        finally:
            common._header_emitted = old
        assert out.count(common.CSV_HEADER) == 1


# ----------------------------------------------- tiny-scale real runs

def _bench_ids():
    from benchmarks.run import load_registry
    load_registry()
    return [b.name for b in all_benchmarks()]


@pytest.mark.parametrize("name", _bench_ids())
def test_tiny_scale_run(name):
    """Every registered benchmark runs end-to-end at tiny scale and
    produces exactly its declared, finite metrics."""
    import math

    from repro.bench import get
    bench = get(name)
    rec = run_benchmark(bench, "tiny")
    assert rec.benchmark == name and rec.scale == "tiny"
    assert {m.name for m in rec.metrics} == {m.name for m in bench.metrics}
    for m in rec.metrics:
        assert math.isfinite(m.value), (name, m.name)
        assert m.n >= 1
