"""Property-based tests (hypothesis) on system invariants:
causality, chunk-size invariance, scan-vs-loop equivalence, proxy
monotonicity, policy floors."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import FLConfig, get_smoke_config
from repro.core.duals import DualState
from repro.core.policy import policy
from repro.core.resources import calibrate
from repro.core.policy import Knobs
from repro.models import build
from repro.models.layers import blockwise_attention
from repro.models.rglru import rglru_scan
from repro.models.ssm import mlstm_chunkwise


# ---------------------------------------------------------------------------
# attention causality: future tokens never affect past outputs
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([16, 32, 48]))
def test_attention_causality(seed, q_chunk):
    rng = np.random.default_rng(seed)
    b, s, h, d = 1, 64, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    out1 = blockwise_attention(q, k, v, window=None, softcap=None,
                               q_chunk=q_chunk)
    # perturb the last quarter of k/v; first half of outputs must not move
    k2 = k.at[:, 3 * s // 4:].add(1.0)
    v2 = v.at[:, 3 * s // 4:].add(-2.0)
    out2 = blockwise_attention(q, k2, v2, window=None, softcap=None,
                               q_chunk=q_chunk)
    np.testing.assert_allclose(np.asarray(out1[:, : s // 2]),
                               np.asarray(out2[:, : s // 2]), atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_model_causality_end_to_end(seed):
    cfg = get_smoke_config("minitron-8b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (1, 32)).astype(np.int32)
    toks2 = toks.copy()
    toks2[:, -8:] = (toks2[:, -8:] + 7) % cfg.vocab_size

    def logits_at(tokens, pos):
        batch = {"tokens": jnp.asarray(tokens),
                 "targets": jnp.asarray(tokens)}
        lg, _ = model.prefill(params, batch)
        return lg  # last position only — use position `pos` via slicing below

    # compare intermediate activations via loss on first half
    mask = np.zeros((1, 32), np.float32)
    mask[:, :16] = 1.0
    l1, _ = model.train_loss(params, {"tokens": jnp.asarray(toks),
                                      "targets": jnp.asarray(toks),
                                      "loss_mask": jnp.asarray(mask)})
    l2, _ = model.train_loss(params, {"tokens": jnp.asarray(toks2),
                                      "targets": jnp.asarray(toks),
                                      "loss_mask": jnp.asarray(mask)})
    assert abs(float(l1) - float(l2)) < 1e-5


# ---------------------------------------------------------------------------
# mLSTM: chunk-size invariance (chunkwise == different chunkwise)
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([4, 8, 16, 64]))
def test_mlstm_chunk_invariance(seed, chunk):
    rng = np.random.default_rng(seed)
    b, s, h, dh = 1, 64, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32)) / math.sqrt(dh)
    v = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
    li = jnp.asarray(rng.normal(size=(b, s, h)).astype(np.float32))
    lf = jnp.asarray((-np.abs(rng.normal(size=(b, s, h)))).astype(np.float32))
    h_ref, (C_ref, n_ref, m_ref) = mlstm_chunkwise(q, k, v, li, lf, chunk=s)
    h_c, (C_c, n_c, m_c) = mlstm_chunkwise(q, k, v, li, lf, chunk=chunk)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_ref),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(C_c), np.asarray(C_ref),
                               atol=2e-4, rtol=2e-3)


def test_mlstm_chunkwise_matches_stepwise():
    """Chunkwise-parallel form == token-by-token recurrence."""
    from repro.models.ssm import mlstm_step
    rng = np.random.default_rng(3)
    b, s, h, dh = 1, 24, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32)) / math.sqrt(dh)
    v = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
    li = jnp.asarray(rng.normal(size=(b, s, h)).astype(np.float32))
    lf = jnp.asarray((-np.abs(rng.normal(size=(b, s, h)))).astype(np.float32))
    h_par, _ = mlstm_chunkwise(q, k, v, li, lf, chunk=8)
    C = jnp.zeros((b, h, dh, dh))
    n = jnp.zeros((b, h, dh))
    m = jnp.full((b, h), -1e30)
    outs = []
    for t in range(s):
        o, (C, n, m) = mlstm_step(q[:, t], k[:, t], v[:, t], li[:, t],
                                  lf[:, t], (C, n, m))
        outs.append(o)
    h_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(h_seq),
                               atol=2e-4, rtol=2e-3)


# ---------------------------------------------------------------------------
# RG-LRU: associative scan == sequential loop
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_rglru_scan_equals_loop(seed):
    rng = np.random.default_rng(seed)
    b, s, w = 2, 33, 8
    a = jnp.asarray(rng.uniform(0.5, 0.999, size=(b, s, w)).astype(np.float32))
    bx = jnp.asarray(rng.normal(size=(b, s, w)).astype(np.float32))
    h_scan = rglru_scan(a, bx)
    h = jnp.zeros((b, w))
    hs = []
    for t in range(s):
        h = a[:, t] * h + bx[:, t]
        hs.append(h)
    h_loop = jnp.stack(hs, axis=1)
    np.testing.assert_allclose(np.asarray(h_scan), np.asarray(h_loop),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# policy / proxy properties
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(*(st.floats(0.0, 10.0) for _ in range(4)))
def test_policy_respects_floors_everywhere(le, lc, lm, lt):
    fl = FLConfig()
    kn = policy(DualState(lam={"energy": le, "comm": lc, "memory": lm,
                               "temp": lt}), fl)
    d = fl.duals
    assert kn.k >= d.k_min and kn.s >= d.s_min and kn.b >= d.b_min
    assert kn.q in (0, 1, 2)
    assert kn.k <= fl.k_base and kn.s <= fl.s_base and kn.b <= fl.b_base
    assert kn.s * kn.b * kn.grad_accum >= fl.s_base * fl.b_base


@settings(max_examples=50, deadline=None)
@given(st.floats(1e5, 1e8), st.integers(10, 80), st.integers(8, 64),
       st.sampled_from([0, 1, 2]))
def test_proxy_monotonicity(p, s, b, q):
    fl = FLConfig()
    res = calibrate(2e6, fl)
    kn = Knobs(k=6, s=s, b=b, q=q)
    u = res.usage(p, kn)
    assert all(v >= 0 for v in u.values())
    u_more_params = res.usage(p * 2, kn)
    assert u_more_params["energy"] > u["energy"]
    assert u_more_params["comm"] > u["comm"]
    assert u_more_params["memory"] > u["memory"]
    kn2 = Knobs(k=6, s=s + 1, b=b, q=q)
    assert res.usage(p, kn2)["energy"] > u["energy"]
    assert res.usage(p, kn2)["temp"] > u["temp"]


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 64))
def test_rope_relative_shift_invariance(seed, shift):
    """RoPE attention scores depend only on relative positions: shifting
    all positions by a constant leaves q·k scores unchanged."""
    from repro.models.layers import rope
    rng = np.random.default_rng(seed)
    b, s, h, d = 1, 8, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    def scores(p):
        qr = rope(q, p, 10_000.0)
        kr = rope(k, p, 10_000.0)
        return jnp.einsum("bqhd,bkhd->bhqk", qr, kr)
    s0 = scores(pos)
    s1 = scores(pos + shift)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               atol=1e-3, rtol=1e-4)


def test_mla_decode_matches_full_expansion():
    """Absorbed-matmul MLA decode == non-absorbed full expansion."""
    from repro.configs import get_smoke_config
    from repro.models import build
    cfg = get_smoke_config("deepseek-v3-671b")
    import dataclasses
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(5))
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 24)), jnp.int32)
    lg, cache = model.prefill(params, {"tokens": toks[:, :20]},
                              max_new_tokens=8)
    for t in range(4):
        lg, cache = model.decode_step(params, cache, toks[:, 20 + t:21 + t])
    full, _ = model.prefill(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, 0]),
                               atol=3e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 6))
def test_freezing_monotone_and_headroom(k):
    """count_active is monotone in k and the head stays trainable."""
    from repro.configs import get_config
    from repro.core.freezing import count_active, mask_tree
    from repro.models import build
    cfg = get_config("charlm-shakespeare").replace(vocab_size=64)
    model = build(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params)
    m_k = mask_tree(params, cfg, k)
    a_k = count_active(params, m_k)
    if k < cfg.num_layers:
        m_k1 = mask_tree(params, cfg, k + 1)
        assert count_active(params, m_k1) >= a_k
    # final norm always trainable
    assert float(np.asarray(jax.tree.leaves(m_k["io"]["final_norm"])[0])) == 1.0
