"""Schedule-determinism analysis (repro.analysis.sched): the
happens-before model over recorded runs, the adversarial tie queue,
signature comparison, and — as the tier-1 acceptance gate — the
SchedulePermuter proving a 3-round wall-clock FedBuff run and a
MaskedSum cohort shuffle invariant under adversarial legal event
permutations."""
import math
from types import SimpleNamespace

import pytest

from repro.analysis.sched import (AdversarialTieQueue, HBGraph, SchedEvent,
                                  ScheduleRecorder, ScheduleSanitizerCallback,
                                  SchedulePermuter)
from repro.analysis.sched.gate import SCENARIOS, _tiny_stack, run_scenario
from repro.analysis.sched.permute import compare_signatures


# ---------------------------------------------------------------------------
# happens-before model (pure: hand-built event streams)
# ---------------------------------------------------------------------------


def _ev(kind, rnd, time, index, client=-1, clients=()):
    return SchedEvent(kind=kind, round=rnd, time=time, index=index,
                      client=client, clients=tuple(clients))


@pytest.fixture()
def tied_round():
    """round_start; c0 and c1 arrive simultaneously; their apply; a
    later c2 + apply; dual; round_end."""
    return HBGraph([
        _ev("round_start", 0, 0.0, 0),
        _ev("deliver", 0, 1.0, 1, client=0),
        _ev("deliver", 0, 1.0, 2, client=1),
        _ev("apply", 0, 1.0, 3, clients=(0, 1)),
        _ev("deliver", 0, 2.0, 4, client=2),
        _ev("apply", 0, 2.0, 5, clients=(2,)),
        _ev("dual", 0, 2.0, 6),
        _ev("round_end", 0, 2.0, 7),
    ])


def test_hb_orders_strict_time_and_causality(tied_round):
    g = tied_round
    assert g.happens_before(0, 1)          # round_start before everything
    assert g.happens_before(0, 7)
    assert g.happens_before(1, 3)          # delivery -> its apply
    assert g.happens_before(2, 3)
    assert g.happens_before(3, 4)          # strictly earlier clock reading
    assert g.happens_before(1, 4)          # ... transitively from t=1.0
    assert g.happens_before(4, 5)          # delivery -> apply, same instant
    assert g.happens_before(5, 6) and g.happens_before(5, 7)
    assert not g.happens_before(4, 1)      # edges only point forward


def test_hb_simultaneous_deliveries_are_schedule_freedom(tied_round):
    pairs = tied_round.unordered_pairs()
    assert [(a.index, b.index) for a, b in pairs] == [(1, 2)]


@pytest.mark.parametrize("cert,tie_broken,expect_certified", [
    ("exact", True, True),
    ("canonical", True, True),
    ("tiebreak", True, True),
    ("tiebreak", False, False),
    (None, True, False),
])
def test_hb_race_certification(tied_round, cert, tie_broken,
                               expect_certified):
    races = tied_round.races(cert, tie_broken=tie_broken)
    assert len(races) == 1                 # the (c0, c1) delivery pair
    race = races[0]
    assert race.state == ("aggregator",)
    assert race.certified is expect_certified
    assert ("RACE" in race.describe()) is (not expect_certified)


def test_hb_per_client_deliveries_are_chained():
    # same client reports twice at the same instant (re-report): the
    # one-device rule sequences them even though time does not
    g = HBGraph([
        _ev("deliver", 0, 1.0, 0, client=0),
        _ev("deliver", 0, 1.0, 1, client=0),
        _ev("deliver", 0, 1.0, 2, client=1),
    ])
    assert g.happens_before(0, 1)
    assert not g.happens_before(0, 2) and not g.happens_before(2, 0)


def test_hb_round_boundary_orders_across_rounds():
    g = HBGraph([
        _ev("round_start", 0, 0.0, 0),
        _ev("deliver", 0, 1.0, 1, client=0),
        _ev("round_end", 0, 1.0, 2),
        _ev("round_start", 1, 1.0, 3),
        _ev("deliver", 1, 1.0, 4, client=1),
    ])
    # c0's delivery and c1's are time-tied, but the round boundary
    # between them forces the order
    assert g.happens_before(1, 4)
    assert g.happens_before(2, 3)
    assert g.unordered_pairs() == []


def test_recorder_rejects_truncated_clock_log():
    rec = ScheduleRecorder()
    clock = SimpleNamespace(event_count=5,
                            events=[("deliver:c0", 1.0, 1.0)], now=1.0)
    with pytest.raises(ValueError, match="truncated"):
        rec.events(SimpleNamespace(clock=clock))


# ---------------------------------------------------------------------------
# adversarial ties + signature comparison (pure units)
# ---------------------------------------------------------------------------


def test_adversarial_tie_queue_is_legal_and_replayable():
    def deliveries(seed):
        q = AdversarialTieQueue(seed=seed)
        for i, arrival in enumerate([2.0, 1.0, 1.0, 1.0, 2.0]):
            q.push(arrival, f"r{i}")
        return [(e.arrival, e.report) for e in q.drain()]

    a, b, c = deliveries(0), deliveries(0), deliveries(1)
    assert a == b                          # replayable per seed
    # legal: arrival order is always respected ...
    assert [t for t, _ in a] == sorted(t for t, _ in a)
    assert {r for _, r in a} == {r for _, r in c}
    # ... and some seed pair resolves the t=1.0 tie differently
    assert any(deliveries(s) != a for s in range(1, 8))
    # ties are finite and sit strictly inside the tie-break slot
    ev = AdversarialTieQueue(seed=3).stamp(1.0, "r")
    assert math.isfinite(ev.tie) and ev.arrival == 1.0


def _sig(val=1.0, knobs=None, order=(0, 1)):
    return {"rounds": [{
        "round": 0, "val_loss": val, "train_loss": 1.0,
        "wire_mb_actual": 1.0, "energy_true": 1.0, "mean_staleness": 0.0,
        "sim_time": 1.0, "round_seconds": 1.0, "updates_applied": 1,
        "reports_applied": 2, "num_available": 2,
        "usage": {"t": 1.0}, "ratios": {"t": 1.0}, "duals": {"t": 0.0},
        "knobs": knobs or {"k": 2}, "participants": frozenset(order),
        "participant_order": tuple(order), "dropped": frozenset(),
    }], "final": []}


def test_compare_signatures_modes():
    base = _sig()
    assert compare_signatures(base, _sig(), "exact") == []
    drift = _sig(val=1.0 + 1e-9)
    assert compare_signatures(base, drift, "exact")       # bit-exact fails
    assert compare_signatures(base, drift, "tolerance") == []
    # knob/int/set fields stay exact in every mode
    assert compare_signatures(base, _sig(knobs={"k": 3}), "tolerance")
    # participant_order is telemetry: permuted delivery alone must match
    assert compare_signatures(base, _sig(order=(1, 0)), "exact") == []


# ---------------------------------------------------------------------------
# tier-1 acceptance: the permuter over real engine runs
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_stack():
    return _tiny_stack()


def test_masked_cohort_shuffle_is_bit_identical(tiny_stack):
    model, fl, ds = tiny_stack
    row, findings, problems = run_scenario("masked_shuffle", model, fl, ds)
    assert problems == [] and findings == []
    assert row["commutativity"] == "exact" and row["mode"] == "exact"
    assert row["total_swapped"] > 0        # the shuffle really happened
    assert row["mismatches"] == [] and row["races"] == 0


def test_fedbuff_wall_clock_invariant_under_permutations(tiny_stack):
    model, fl, ds = tiny_stack
    row, findings, problems = run_scenario("fedbuff_wall", model, fl, ds)
    assert problems == [] and findings == []
    assert row["permutations"] >= 8 and row["mode"] == "exact"
    assert row["total_swapped"] > 0        # non-vacuous: orders changed
    assert row["mismatches"] == []
    assert row["races"] == 0               # tiebreak certificate holds
    assert row["unordered_pairs"] > 0      # there was freedom to race in


def test_sanitizer_callback_rides_along_strict(tiny_stack):
    model, fl, ds = tiny_stack
    sanitizer = ScheduleSanitizerCallback()          # strict=True
    eng, _ = SCENARIOS["sync_ties"](model, fl, ds, sanitizer)
    eng.run(time_mode="wall_clock")                  # must not raise
    assert sanitizer.graph is not None
    assert sanitizer.races == []
    assert len(sanitizer.certified) == len(
        sanitizer.graph.races(eng.aggregator.commutativity))


def test_permuter_restores_engine_configuration(tiny_stack):
    model, fl, ds = tiny_stack
    eng, kw = SCENARIOS["sync_ties"](model, fl, ds,
                                     ScheduleSanitizerCallback(strict=False))
    strategy, factory = eng.strategy, eng.event_queue_factory
    kw["permutations"] = 1
    report = SchedulePermuter(eng, run_kwargs={"time_mode": "wall_clock"},
                              **kw).run()
    assert report.ok()
    assert eng.strategy is strategy        # caller's objects put back
    assert eng.event_queue_factory is factory
