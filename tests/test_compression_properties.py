"""Hypothesis property tests for the wire round-trip
(``compress_decompress`` / ``ops.quantize_dequantize``): for q in {1, 2}
the reconstruction error of every element is bounded by half the
per-block mid-tread step (absmax/(2^(bits-1)-1)/2), across odd shapes
(non-multiple of the block size), scalars, and empty leaves."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import compression  # noqa: E402
from repro.kernels import ops  # noqa: E402

BITS = {1: 8, 2: 2}
BLOCK = 256

odd_shapes = st.sampled_from([
    (), (1,), (7,), (255,), (257,), (511,), (3, 0, 5), (0,),
    (3, 85), (5, 51, 2), (BLOCK,), (BLOCK + 1,), (2, BLOCK - 1),
])


def _per_block_bound(x_flat: np.ndarray, bits: int) -> np.ndarray:
    """Elementwise bound: half the mid-tread step of the element's block
    (blocks are taken over the zero-padded flattened tensor)."""
    n = x_flat.size
    pad = (-n) % BLOCK
    blocks = np.pad(x_flat, (0, pad)).reshape(-1, BLOCK)
    absmax = np.abs(blocks).max(axis=1, keepdims=True)
    scale = absmax / (2 ** (bits - 1) - 1)
    return np.repeat(scale / 2, BLOCK, axis=1).reshape(-1)[:n]


@settings(max_examples=40, deadline=None)
@given(odd_shapes, st.sampled_from([1, 2]), st.floats(1e-3, 1e3),
       st.integers(0, 2 ** 31 - 1))
def test_roundtrip_error_bounded_by_block_scale(shape, q, amp, seed):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=shape) * amp).astype(np.float32)
    y = np.asarray(ops.quantize_dequantize(jnp.asarray(x), bits=BITS[q]))
    assert y.shape == x.shape and y.dtype == x.dtype
    assert np.all(np.isfinite(y))
    if x.size == 0:
        return
    err = np.abs(y - x).reshape(-1)
    bound = _per_block_bound(x.reshape(-1), BITS[q])
    # fp32 slack: scale and (code + 0.5) * scale each round once
    assert np.all(err <= bound * (1 + 1e-3) + 1e-6)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([1, 2]), st.integers(0, 2 ** 31 - 1))
def test_tree_roundtrip_mixed_leaves(q, seed):
    """compress_decompress maps over a pytree with empty, scalar, and
    non-block-aligned leaves without reshaping surprises; q=0 is the
    identity."""
    rng = np.random.default_rng(seed)
    tree = {
        "empty": jnp.zeros((0,), jnp.float32),
        "scalar": jnp.asarray(np.float32(rng.normal())),
        "odd": jnp.asarray(rng.normal(size=(3, 85)).astype(np.float32)),
        "aligned": jnp.asarray(
            rng.normal(size=(2, BLOCK)).astype(np.float32)),
    }
    out = compression.compress_decompress(tree, q)
    for key in tree:
        assert out[key].shape == tree[key].shape
    ident = compression.compress_decompress(tree, 0)
    for key in tree:
        np.testing.assert_array_equal(np.asarray(ident[key]),
                                      np.asarray(tree[key]))
    # per-leaf error bound holds through the tree entry point
    for key in ("scalar", "odd", "aligned"):
        x = np.asarray(tree[key]).reshape(-1)
        y = np.asarray(out[key]).reshape(-1)
        bound = _per_block_bound(x, BITS[q])
        assert np.all(np.abs(y - x) <= bound * (1 + 1e-3) + 1e-6)


def test_zero_and_constant_blocks():
    """Degenerate blocks: all-zero stays exactly zero; a constant block
    is a mid-tread grid point (code L-1), so it reconstructs within
    fp32 rounding of the constant."""
    for q in (1, 2):
        z = np.asarray(ops.quantize_dequantize(
            jnp.zeros((2 * BLOCK + 7,), jnp.float32), bits=BITS[q]))
        np.testing.assert_array_equal(z, 0.0)
        c = np.full((BLOCK + 3,), 0.7, np.float32)
        y = np.asarray(ops.quantize_dequantize(jnp.asarray(c),
                                               bits=BITS[q]))
        np.testing.assert_allclose(y, c, rtol=1e-6)
