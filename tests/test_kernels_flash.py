"""Pallas flash-attention kernel vs oracle: shape/dtype/feature sweeps in
interpret mode, plus equivalence of the model's pure-JAX blockwise path."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.models.layers import blockwise_attention


def mk(rng, b, s, h, kvh, d, dtype=np.float32):
    q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(dtype))
    k = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(dtype))
    v = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(dtype))
    return q, k, v


@pytest.mark.parametrize("s,blk", [(128, 128), (256, 128), (512, 128)])
@pytest.mark.parametrize("h,kvh", [(4, 4), (4, 2), (8, 1)])
def test_flash_causal_matches_ref(s, blk, h, kvh, rng):
    q, k, v = mk(rng, 2, s, h, kvh, 64)
    out = flash_attention_bhsd(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3), causal=True,
                               blk_q=blk, blk_k=blk, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out.transpose(0, 2, 1, 3)),
                               np.asarray(exp), atol=2e-5)


@pytest.mark.parametrize("window", [64, 128])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_flash_window_softcap(window, softcap, rng):
    q, k, v = mk(rng, 1, 256, 4, 2, 32)
    out = flash_attention_bhsd(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3), causal=True,
                               window=window, softcap=softcap, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True, window=window,
                                  softcap=softcap)
    np.testing.assert_allclose(np.asarray(out.transpose(0, 2, 1, 3)),
                               np.asarray(exp), atol=2e-5)


def test_flash_bidirectional(rng):
    q, k, v = mk(rng, 2, 128, 4, 4, 64)
    out = flash_attention_bhsd(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3), causal=False,
                               interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out.transpose(0, 2, 1, 3)),
                               np.asarray(exp), atol=2e-5)


def test_flash_bf16(rng):
    q, k, v = mk(rng, 1, 128, 4, 4, 64)
    q, k, v = (t.astype(jnp.bfloat16) for t in (q, k, v))
    out = flash_attention_bhsd(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3), causal=True,
                               interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out.transpose(0, 2, 1, 3), np.float32),
        np.asarray(exp, np.float32), atol=3e-2)


@pytest.mark.parametrize("s,q_chunk", [(96, 32), (256, 64), (130, 64)])
@pytest.mark.parametrize("window", [None, 48])
def test_model_blockwise_path_matches_oracle(s, q_chunk, window, rng):
    """The pure-JAX blockwise attention used by every model (and by the
    dry-run lowering) is numerically the same computation as the kernel."""
    q, k, v = mk(rng, 2, s, 4, 2, 32)
    out = blockwise_attention(q, k, v, window=window, softcap=None,
                              q_chunk=q_chunk)
    exp = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


def test_online_softmax_long_kv_path(rng):
    """Force the inner kv-chunk scan (L > 2*kv_chunk) in _attend_block."""
    from repro.models.layers import _attend_block
    b, cq, h, d = 1, 16, 2, 32
    L = 640
    q = jnp.asarray(rng.normal(size=(b, cq, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, L, h, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, L, h, d)).astype(np.float32))
    qpos = jnp.arange(L - cq, L)
    kpos = jnp.arange(L)
    out = _attend_block(q, k, v, qpos, kpos, 1 / math.sqrt(d), None, None,
                        kv_chunk=128)
    # oracle: direct softmax
    scores = jnp.einsum("bqhd,blhd->bhql", q, k) / math.sqrt(d)
    mask = kpos[None, :] <= qpos[:, None]
    scores = jnp.where(mask[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    exp = jnp.einsum("bhql,blhd->bqhd", w, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)
