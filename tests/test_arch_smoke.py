"""Per-architecture smoke tests (assignment requirement): reduced variant
(2 layers, d_model <= 512, <= 4 experts) of each family, one forward /
train step on CPU, asserting output shapes and no NaNs; plus
prefill+decode equals full forward (the serving-path correctness
invariant)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import build

B, S = 2, 48


def make_batch(cfg, rng, seq=S, batch=B):
    toks = rng.integers(0, cfg.vocab_size, (batch, seq + 4)).astype(np.int32)
    batch_d = {"tokens": jnp.asarray(toks[:, :seq]),
               "targets": jnp.asarray(toks[:, 1:seq + 1])}
    if cfg.frontend and cfg.frontend.kind == "vision":
        batch_d["patch_embeds"] = jnp.asarray(rng.normal(
            size=(batch, cfg.frontend.num_prefix_tokens,
                  cfg.frontend.embed_dim)).astype(np.float32))
    if cfg.encdec:
        batch_d["src_embeds"] = jnp.asarray(rng.normal(
            size=(batch, 32, cfg.frontend.embed_dim)).astype(np.float32))
    return batch_d, jnp.asarray(toks)


def smoke_cfg(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 8 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
        # exact-match decode tests need no capacity drops
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finiteness(arch, rng):
    cfg = smoke_cfg(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch, _ = make_batch(cfg, rng)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(model.train_loss, has_aux=True))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32))), \
            f"{arch}: non-finite grad"
    # one SGD step moves the loss
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype),
                           params, grads)
    loss2, _ = jax.jit(model.train_loss)(params2, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_full_forward(arch, rng):
    cfg = smoke_cfg(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch, toks = make_batch(cfg, rng)
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_new_tokens=8))(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    step = jax.jit(model.decode_step)
    for t in range(4):
        logits, cache = step(params, cache, toks[:, S + t:S + t + 1])
        assert np.all(np.isfinite(np.asarray(logits)))
    full = dict(batch)
    full["tokens"] = toks
    logits_full, _ = jax.jit(lambda p, b: model.prefill(p, b))(params, full)
    a = np.asarray(logits[:, 0])
    b_ = np.asarray(logits_full[:, 0])
    scale = np.max(np.abs(b_)) + 1e-9
    np.testing.assert_allclose(a / scale, b_ / scale, atol=2e-4,
                               err_msg=f"{arch}: decode != full forward")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_instantiates_shapes_only(arch):
    """FULL configs are exercised via eval_shape only (no allocation)."""
    from repro.configs import get_config
    cfg = get_config(arch)
    model = build(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    assert n > 1e8, f"{arch}: implausibly small full config ({n/1e6:.0f}M)"
    counts = model.param_count()
    assert counts["active"] <= counts["total"]


def test_charlm_decode_matches_full_forward(rng):
    """Regression: learned-position decode must read a scalar position from
    the scan-stacked cache indices (the paper's own model is the only
    learned-pos arch, so the generic arch sweep misses this path)."""
    from repro.configs import get_config
    cfg = get_config("charlm-shakespeare").replace(vocab_size=64)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.integers(0, 64, (2, 20)), jnp.int32)
    logits, cache = jax.jit(lambda p, b: model.prefill(
        p, b, max_new_tokens=8))(params, {"tokens": toks[:, :16]})
    step = jax.jit(model.decode_step)
    for t in range(4):
        logits, cache = step(params, cache, toks[:, 16 + t:17 + t])
    full, _ = jax.jit(lambda p, b: model.prefill(p, b))(
        params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, 0]), atol=2e-4)
