"""Pallas quantization kernel vs pure-jnp oracle: shape/dtype sweeps in
interpret mode (assignment requirement), both dispatch backends
(``FORCE_BACKEND in {"ref", "pallas"}``) over every shape class the FL
trees produce (scalars, odd tails, non-tile-multiples), mid-tread
quantization-error bounds, the ``qdq(0) == 0`` zero-preservation
regression, and hypothesis property tests (skipped when hypothesis is
not installed — the backend/shape sweeps still run)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # pragma: no cover - property tests skip
    given = None

from repro.kernels import ops, ref
from repro.kernels.quantize import ROWS_PER_TILE, dequantize_blocks, quantize_blocks

#: shapes covering every class the FL trees produce: scalars, short
#: vectors, odd tails (n % block != 0), and padded tails that are a
#: block multiple but not a block*ROWS_PER_TILE tile multiple
SHAPES = [(), (1,), (37,), (3, 129), (5, 7, 11), (2048, 3),
          (256 * ROWS_PER_TILE + 17,), (3 * 256,)]


@pytest.fixture(params=["ref", "pallas"])
def backend(request, monkeypatch):
    monkeypatch.setattr(ops, "FORCE_BACKEND", request.param)
    return request.param


@pytest.mark.parametrize("bits", [8, 2])
@pytest.mark.parametrize("n_blocks,block", [(8, 256), (16, 128), (32, 512)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_kernel_matches_ref_blocks(bits, n_blocks, block, dtype, rng):
    x = jnp.asarray(rng.normal(size=(n_blocks, block)).astype(np.float32),
                    dtype=dtype).astype(jnp.float32)
    codes_k, scales_k = quantize_blocks(x, bits, interpret=True)
    codes_r, scales_r = ref.quantize_blocks_ref(x, bits)
    np.testing.assert_array_equal(np.asarray(codes_k), np.asarray(codes_r))
    np.testing.assert_allclose(np.asarray(scales_k), np.asarray(scales_r),
                               rtol=1e-6)
    deq_k = dequantize_blocks(codes_k, scales_k, interpret=True)
    deq_r = ref.dequantize_blocks_ref(codes_r, scales_r)
    np.testing.assert_allclose(np.asarray(deq_k), np.asarray(deq_r), rtol=1e-6)


@pytest.mark.parametrize("bits", [8, 2])
def test_quantization_error_bound(bits, backend, rng):
    """Mid-tread quantizer error is at most scale/2 = absmax/(2(L-1))."""
    x = jnp.asarray(rng.normal(size=(2048,)).astype(np.float32))
    y = ops.quantize_dequantize(x, bits=bits, block=256)
    err = np.abs(np.asarray(y - x))
    blocks = np.asarray(x).reshape(-1, 256)
    absmax = np.abs(blocks).max(axis=1, keepdims=True)
    bound = np.repeat(absmax / (2 ** (bits - 1) - 1) / 2, 256,
                      axis=1).reshape(-1)
    assert np.all(err <= bound + 1e-6)


@pytest.mark.parametrize("shape", SHAPES, ids=str)
def test_arbitrary_shapes_roundtrip(shape, backend, rng):
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    y = ops.quantize_dequantize(x, bits=8)
    assert y.shape == x.shape and y.dtype == x.dtype
    amax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(y - x))) <= amax / 254 * (1 + 1e-3) + 1e-6


@pytest.mark.parametrize("bits", [8, 2])
def test_qdq_zero_is_exactly_zero(bits, backend):
    """Regression: the mid-rise code had no zero level, so exact-zero
    inputs came back as +0.5*scale. Mid-tread must return exact zeros —
    both for all-zero blocks and for zeros embedded among nonzeros
    (what a top-k sparsifier or freezing mask produces)."""
    z = ops.quantize_dequantize(jnp.zeros((1024,), jnp.float32), bits=bits)
    np.testing.assert_array_equal(np.asarray(z), 0.0)
    x = np.linspace(-1.0, 1.0, 512, dtype=np.float32)
    x[::3] = 0.0                      # exact zeros inside nonzero blocks
    y = np.asarray(ops.quantize_dequantize(jnp.asarray(x), bits=bits))
    np.testing.assert_array_equal(y[::3], 0.0)


@pytest.mark.parametrize("topk", [None, 32])
def test_pallas_and_ref_backends_agree(topk, rng):
    x = jnp.asarray(rng.normal(size=(4096 + 37,)).astype(np.float32))
    old = ops.FORCE_BACKEND
    try:
        ops.FORCE_BACKEND = "pallas"
        a = ops.quantize_dequantize(x, bits=8, topk=topk)
        ops.FORCE_BACKEND = "ref"
        b = ops.quantize_dequantize(x, bits=8, topk=topk)
    finally:
        ops.FORCE_BACKEND = old
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


if given is not None:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 4), st.integers(1, 513), st.sampled_from([2, 8]),
           st.floats(0.01, 100.0))
    def test_property_error_bound_and_shape(rows, cols, bits, scale):
        """Property: round-trip preserves shape, error bounded by half
        the mid-tread step per block, idempotent on already-quantized
        data."""
        rng = np.random.default_rng(rows * 1000 + cols)
        x = jnp.asarray((rng.normal(size=(rows, cols)) * scale)
                        .astype(np.float32))
        y = ops.quantize_dequantize(x, bits=bits, block=256)
        assert y.shape == x.shape
        assert np.all(np.isfinite(np.asarray(y)))
        amax = float(jnp.max(jnp.abs(x)))
        bound = amax / (2 ** (bits - 1) - 1) / 2
        # relative slack: scale and code*scale round in fp32
        assert float(jnp.max(jnp.abs(y - x))) <= bound * (1 + 1e-3) + 1e-5
        # idempotence: quantizing the dequantized signal is (nearly) stable
        z = ops.quantize_dequantize(y, bits=bits, block=256)
        assert float(jnp.max(jnp.abs(z - y))) <= 2 * bound * (1 + 1e-3) + 1e-5
