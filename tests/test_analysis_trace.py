"""Tests for repro.analysis.trace: the jaxpr cost model, the TRACE rule
family (positive + negative fixtures per rule), the registered repo
entry points, the Budgets.memory static feasibility gate, and the
tier-1 bracket pin of the static peak against XLA's own
``memory_analysis`` for the real char-LM client step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.trace import (DEFAULT_TRACE_TABLE, EntryPoint,
                                  charlm_trace_setup, collect_entry_points,
                                  cost_of_jaxpr, memory_gate, run_trace,
                                  run_trace_rules, trace_entry,
                                  trace_rule_ids, traced_entries,
                                  unwrap_pjit)
from repro.analysis.trace.gate import build_table, diff_table, load_table

F32 = jnp.float32


def _entry(fn, args, name="fixture.entry", **kw):
    return EntryPoint(name=name, path="tests/test_analysis_trace.py",
                      line=1, build=lambda: (fn, args), **kw)


def _findings(fn, args, **kw):
    return run_trace_rules([trace_entry(_entry(fn, args, **kw))])


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


def test_matmul_cost_exact():
    a = jax.ShapeDtypeStruct((64, 128), F32)
    b = jax.ShapeDtypeStruct((128, 32), F32)
    cost = cost_of_jaxpr(jax.make_jaxpr(lambda x, y: x @ y)(a, b))
    assert cost.flops == 2 * 64 * 32 * 128
    assert cost.input_bytes == (64 * 128 + 128 * 32) * 4
    assert cost.output_bytes == 64 * 32 * 4
    # inputs pinned + output live together
    assert cost.peak_bytes == cost.input_bytes + cost.output_bytes
    assert cost.transfer_bytes == 0


def test_liveness_chain_and_donation():
    """a = x*2; b = a+1; c = b*3 — without donation x is pinned, so the
    worst instant holds x plus two temps; donating x frees it after its
    only read and the peak drops by exactly one buffer."""
    n = 1024

    def chain(x):
        a = x * 2.0
        b = a + 1.0
        return b * 3.0

    closed = jax.make_jaxpr(chain)(jax.ShapeDtypeStruct((n,), F32))
    pinned = cost_of_jaxpr(closed)
    donated = cost_of_jaxpr(closed, donated=[0])
    assert pinned.peak_bytes == 3 * n * 4
    assert donated.peak_bytes == 2 * n * 4
    assert pinned.flops == 3 * n


def test_scan_flops_scale_with_length():
    def body(c, x):
        return c + x, c

    def f(xs):
        return jax.lax.scan(body, jnp.zeros((16,), F32), xs)

    cost = cost_of_jaxpr(unwrap_pjit(
        jax.make_jaxpr(f)(jax.ShapeDtypeStruct((10, 16), F32))))
    # one 16-wide add per iteration, 10 iterations
    assert cost.flops >= 10 * 16
    assert cost.flops < 10 * 16 * 4


def test_unwrap_pjit_exposes_body():
    f = jax.jit(lambda x: x * 2.0)
    closed = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((8,), F32))
    assert closed.jaxpr.eqns[0].primitive.name == "pjit"
    inner = unwrap_pjit(closed)
    assert all(e.primitive.name != "pjit" for e in inner.jaxpr.eqns)


# ---------------------------------------------------------------------------
# TRACE001 dtype promotion
# ---------------------------------------------------------------------------


def test_trace001_fires_on_f64_widening():
    finds = _findings(lambda x: x.astype(jnp.float64) * 2.0,
                      (jax.ShapeDtypeStruct((8,), F32),), x64=True)
    assert any(f.rule == "TRACE001" for f in finds)


def test_trace001_clean_on_f32_path():
    finds = _findings(lambda x: x * 2.0 + 1.0,
                      (jax.ShapeDtypeStruct((8,), F32),), x64=True)
    assert not [f for f in finds if f.rule == "TRACE001"]


# ---------------------------------------------------------------------------
# TRACE002 missed donation
# ---------------------------------------------------------------------------


def _update_like(p, o):
    return p + o, o * 2.0


def test_trace002_fires_without_donation():
    args = (jnp.ones((32,), F32), jnp.ones((32,), F32))
    finds = _findings(jax.jit(_update_like), args, donatable=(1,))
    assert any(f.rule == "TRACE002" for f in finds)


def test_trace002_clean_with_donation():
    args = (jnp.ones((32,), F32), jnp.ones((32,), F32))
    finds = _findings(jax.jit(_update_like, donate_argnums=(1,)), args,
                      donatable=(1,))
    assert not [f for f in finds if f.rule == "TRACE002"]


# ---------------------------------------------------------------------------
# TRACE003 dense cohort materialization
# ---------------------------------------------------------------------------


def test_trace003_fires_on_stacked_combine():
    deltas = tuple(jnp.zeros((256,), F32) for _ in range(4))
    finds = _findings(lambda *ds: jnp.stack(ds).mean(axis=0), deltas,
                      cohort=4)
    assert any(f.rule == "TRACE003" for f in finds)


def test_trace003_clean_on_incremental_combine():
    from repro.core.aggregation import aggregate
    deltas = tuple({"w": jnp.zeros((256,), F32)} for _ in range(4))
    finds = _findings(lambda *ds: aggregate(list(ds)), deltas, cohort=4)
    assert not [f for f in finds if f.rule == "TRACE003"]


# ---------------------------------------------------------------------------
# TRACE004 host callbacks in jit
# ---------------------------------------------------------------------------


def test_trace004_fires_on_debug_callback():
    def noisy(x):
        jax.debug.print("x={x}", x=x.sum())
        return x * 2.0

    finds = _findings(noisy, (jax.ShapeDtypeStruct((8,), F32),))
    assert any(f.rule == "TRACE004" for f in finds)


def test_trace004_clean_on_pure_fn():
    finds = _findings(lambda x: x * 2.0,
                      (jax.ShapeDtypeStruct((8,), F32),))
    assert not [f for f in finds if f.rule == "TRACE004"]


# ---------------------------------------------------------------------------
# the registered repo entry points
# ---------------------------------------------------------------------------


def test_trace_rule_registry():
    assert trace_rule_ids() == ["TRACE001", "TRACE002", "TRACE003",
                                "TRACE004"]


def test_registry_covers_the_paper_surfaces():
    names = {e.name for e in collect_entry_points()}
    assert {"fl.client_grad_step", "fl.client_update_step",
            "fl.client_local_step", "fl.client_local_step@baseline",
            "fl.executor_batched_round", "fl.aggregate_sync",
            "fl.aggregate_weighted", "kernels.wire_dense",
            "kernels.wire_topk", "kernels.masked_sum",
            "constraints.dual_update"} <= names


def test_repo_entries_trace_clean():
    """Tier-1 gate: no TRACE findings on the registered entry points
    (the committed-baseline equivalent for the traced IR is zero)."""
    traced = traced_entries()
    findings = run_trace_rules(traced)
    assert findings == [], [f.format() for f in findings]


def test_every_entry_costs_something():
    from repro.analysis.trace.rules import DEVICE_PUT_MIN_BYTES
    for t in traced_entries():
        assert t.cost.peak_bytes > 0, t.entry.name
        assert t.cost.eqns > 0, t.entry.name
        # scalar pre-staging only; nothing TRACE004 would flag
        assert t.cost.transfer_bytes < DEVICE_PUT_MIN_BYTES, t.entry.name


def test_client_update_step_actually_donates():
    t = {x.entry.name: x for x in traced_entries()}["fl.client_update_step"]
    assert t.donatable_leaves > 0
    assert t.aliased_outputs == t.donatable_leaves


def test_donation_shrinks_static_peak():
    """The TRACE002 satellite's win, statically visible: the update
    step's peak with donated opt-state/grads is strictly below the
    undonated peak, by at least the opt-state size."""
    t = {x.entry.name: x for x in traced_entries()}["fl.client_update_step"]
    undonated = cost_of_jaxpr(t.closed_jaxpr)
    donated = t.cost
    assert donated.peak_bytes < undonated.peak_bytes


# ---------------------------------------------------------------------------
# the memory gate
# ---------------------------------------------------------------------------


def test_memory_gate_baseline_violates_and_adapted_fits():
    """The paper's Fig. 2 shape, statically: at FedAvg baseline knobs
    the client step exceeds Budgets.memory (0.31 > 0.26 by Table-1
    calibration); at the adapted operating point it fits."""
    rows = {r.entry: r for r in memory_gate(traced_entries())}
    base = rows["fl.client_local_step@baseline"]
    adapted = rows["fl.client_local_step"]
    assert base.memory_units == pytest.approx(0.31)
    assert base.violated and not base.gated       # negative control
    assert adapted.gated and not adapted.violated
    assert adapted.memory_units < base.memory_units


def test_trace_table_committed_and_clean():
    """The committed TRACE_BUDGETS.json matches a fresh trace (the CI
    --trace gate's ratchet) and the full run reports no problems."""
    report = run_trace(root=".")
    assert report.problems == [], report.problems
    assert report.findings == []
    table = load_table(DEFAULT_TRACE_TABLE)
    assert table is not None
    assert set(table["entries"]) == {t.entry.name
                                     for t in report.traced}


def test_diff_table_catches_regression_and_stale_rows():
    traced = list(traced_entries())
    table = build_table(traced, memory_gate(traced))
    name = traced[0].entry.name
    table["entries"][name]["peak_bytes"] = \
        int(table["entries"][name]["peak_bytes"] * 0.5)
    table["entries"]["ghost.entry"] = {"peak_bytes": 1}
    problems = diff_table(table, traced)
    assert any("peak regressed" in p for p in problems)
    assert any("ghost.entry" in p for p in problems)


# ---------------------------------------------------------------------------
# bracket pin: static peak vs XLA memory_analysis (tier-1)
# ---------------------------------------------------------------------------

#: the declared band: the jaxpr-level estimate prices the *unfused*
#: program with ideal liveness, XLA's measured footprint adds buffer
#: alignment and scheduler temporaries but removes fused intermediates
#: — empirically the two agree within a small constant factor (ratio
#: ~0.96 at the declared shapes; the band leaves room for jax/XLA
#: version drift without letting the estimate decouple from reality).
BRACKET_LO = 0.5
BRACKET_HI = 4.0


def test_static_peak_brackets_compiled_high_water():
    entries = {e.name: e for e in collect_entry_points()}
    ep = entries["fl.client_local_step"]
    fn, args = ep.build()
    static_peak = trace_entry(ep).cost.peak_bytes
    stats = fn.lower(*args).compile().memory_analysis()
    measured = (stats.argument_size_in_bytes + stats.output_size_in_bytes
                + stats.temp_size_in_bytes - stats.alias_size_in_bytes)
    assert measured > 0
    ratio = static_peak / measured
    assert BRACKET_LO <= ratio <= BRACKET_HI, (
        f"static {static_peak} B vs measured {measured} B "
        f"(ratio {ratio:.2f}) outside [{BRACKET_LO}, {BRACKET_HI}]")


# ---------------------------------------------------------------------------
# the traceable dual-update twin
# ---------------------------------------------------------------------------


def test_dual_step_jnp_matches_scalar_law():
    from repro.configs import get_fl_config
    from repro.constraints.controllers import (DeadzoneSubgradient,
                                               dual_step_jnp)

    cfg = get_fl_config().duals
    ctrl = DeadzoneSubgradient()
    ratios = [0.2, 0.89, 0.95, 1.0, 1.04, 1.051, 1.3, 5.0]
    lams = [0.0, 0.5, cfg.lambda_max]
    for lam in lams:
        want = np.array([ctrl.step("k", lam, r, cfg) for r in ratios],
                        np.float32)
        got = dual_step_jnp(jnp.full((len(ratios),), lam, F32),
                            jnp.asarray(ratios, F32),
                            cfg.eta, cfg.deadzone, cfg.lambda_max)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6,
                                   atol=1e-7)


# ---------------------------------------------------------------------------
# CLI integration
# ---------------------------------------------------------------------------


def test_cli_trace_exits_clean_on_repo():
    from repro.analysis.cli import EXIT_CLEAN, main
    assert main(["--trace"]) == EXIT_CLEAN


def test_cli_trace_json_shape(capsys):
    from repro.analysis.cli import main
    import json
    main(["--trace", "--json"])
    payload = json.loads(capsys.readouterr().out)
    assert "trace" in payload
    entries = payload["trace"]["entries"]
    assert entries and all("peak_bytes" in r and "flops" in r
                           for r in entries)
    assert payload["trace"]["gate"]


def test_charlm_trace_setup_shapes():
    runner, params, batch = charlm_trace_setup(b=4)
    assert batch["tokens"].shape == (4, runner.fl.seq_len)
    assert len(jax.tree.leaves(params)) > 0
