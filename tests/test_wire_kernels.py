"""The Pallas wire-path pipeline (repro.kernels.wire + ops): fused
quantize/top-k semantics and ref<->pallas bit-compatibility, the
fixed-point masked-sum kernel vs the NumPy uint64 oracle, and the
wire-accounting pin — ``compression.wire_bytes`` must price exactly
the tuple ``ops.quantize_wire`` ships."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression
from repro.kernels import ops, ref
from repro.kernels import wire as wk
from repro.kernels.quantize import ROWS_PER_TILE

BLOCK = 256


@pytest.fixture(params=["ref", "pallas"])
def backend(request, monkeypatch):
    monkeypatch.setattr(ops, "FORCE_BACKEND", request.param)
    return request.param


# ---------------------------------------------------------------------------
# fused quantize + top-k
# ---------------------------------------------------------------------------


def test_topk_mask_semantics(rng):
    """Exactly k survivors per block, and they are the k largest
    magnitudes with ties broken toward the lower index — i.e. the mask
    matches a stable argsort oracle."""
    absx = np.abs(rng.normal(size=(16, BLOCK)).astype(np.float32))
    absx[3, :10] = absx[3, 10]        # ties inside a block
    absx[7] = 0.0                     # fully degenerate block
    for k in (1, 32, BLOCK - 1, BLOCK):
        keep = np.asarray(ref.topk_mask_ref(jnp.asarray(absx), k))
        assert keep.sum(axis=1).tolist() == [k] * 16
        # stable argsort on (-magnitude, index): the canonical oracle
        order = np.argsort(-absx, axis=1, kind="stable")
        for r in range(16):
            want = np.zeros(BLOCK, bool)
            want[order[r, :k]] = True
            np.testing.assert_array_equal(keep[r], want, err_msg=f"row {r}")


def test_quantize_topk_kernel_matches_ref(rng):
    x = jnp.asarray(rng.normal(size=(ROWS_PER_TILE * 2, BLOCK))
                    .astype(np.float32))
    for bits, k in ((8, 32), (2, 8), (8, 1)):
        ck, sk, mk = wk.quantize_topk_blocks(x, bits, k, interpret=True)
        cr, sr, mr = ref.quantize_topk_blocks_ref(x, bits, k)
        np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
        np.testing.assert_array_equal(np.asarray(mk), np.asarray(mr))
        np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)


def test_sparse_roundtrip_properties(backend, rng):
    """Dropped coordinates come back exactly 0.0, survivors obey the
    dense mid-tread bound (the scale is the dense absmax), and k=block
    degrades to the dense format."""
    x = np.asarray(rng.normal(size=(1000,)).astype(np.float32))
    y = np.asarray(ops.quantize_dequantize(jnp.asarray(x), bits=8, topk=32))
    blocks = np.pad(x, (0, 24)).reshape(-1, BLOCK)
    absmax = np.abs(blocks).max(axis=1)
    kept = 0
    for b in range(blocks.shape[0]):
        yb = np.pad(y, (0, 24)).reshape(-1, BLOCK)[b]
        nz = yb != 0.0
        kept += int(nz.sum())
        assert np.all(np.abs(yb[nz] - blocks[b][nz])
                      <= absmax[b] / 254 * (1 + 1e-3) + 1e-6)
    assert kept <= 32 * blocks.shape[0]
    dense = np.asarray(ops.quantize_dequantize(jnp.asarray(x), bits=8))
    full = np.asarray(ops.quantize_dequantize(jnp.asarray(x), bits=8,
                                              topk=BLOCK))
    np.testing.assert_array_equal(full, dense)


# ---------------------------------------------------------------------------
# fixed-point masked sum
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("clients", [1, 2, 5, 33])
def test_masked_sum_matches_uint64_oracle(clients, backend, rng):
    """The limb fold is exact mod 2^64 for any cohort size, on both
    dispatch backends, against NumPy's native wrapping uint64 sum."""
    vals = rng.integers(0, 2 ** 64, size=(clients, 1000), dtype=np.uint64)
    want = np.add.reduce(vals, axis=0)
    hi, lo = ops.split_limbs(vals)
    hi_s, lo_s = ops.masked_sum(hi, lo)
    got = ops.merge_limbs(np.asarray(hi_s), np.asarray(lo_s))
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(ops.masked_sum_u64(vals), want)


def test_masked_sum_u64_default_cpu_path(rng):
    """The un-forced host-level fold (NumPy one-pass on CPU) agrees
    with the forced limb backends bit-for-bit."""
    vals = rng.integers(0, 2 ** 64, size=(7, 513), dtype=np.uint64)
    old = ops.FORCE_BACKEND
    try:
        ops.FORCE_BACKEND = None
        a = ops.masked_sum_u64(vals)
        ops.FORCE_BACKEND = "pallas"
        b = ops.masked_sum_u64(vals)
        ops.FORCE_BACKEND = "ref"
        c = ops.masked_sum_u64(vals)
    finally:
        ops.FORCE_BACKEND = old
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, c)


def test_masked_sum_rejects_oversized_cohort():
    vals = np.zeros((2, 4), np.uint64)
    hi, lo = ops.split_limbs(vals)
    ops.masked_sum(hi, lo)            # fine at 2 clients
    with pytest.raises(ValueError, match="clients"):
        ops.masked_sum(np.zeros((ops.MASKED_SUM_MAX_CLIENTS + 1, 1),
                                np.uint32),
                       np.zeros((ops.MASKED_SUM_MAX_CLIENTS + 1, 1),
                                np.uint32))


def test_split_merge_limbs_roundtrip(rng):
    vals = rng.integers(0, 2 ** 64, size=(3, 97), dtype=np.uint64)
    hi, lo = ops.split_limbs(vals)
    assert hi.dtype == lo.dtype == np.uint32
    np.testing.assert_array_equal(ops.merge_limbs(hi, lo), vals)


# ---------------------------------------------------------------------------
# wire accounting: wire_bytes prices exactly what quantize_wire ships
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 255, 256, 1000, BLOCK * ROWS_PER_TILE,
                               BLOCK * ROWS_PER_TILE + 17])
@pytest.mark.parametrize("topk", [None, 32])
def test_wire_bytes_matches_quantize_wire_tuple(n, topk, backend, rng):
    """Regression: the accounting used ceil(n/block) scale blocks while
    the Pallas path shipped ROWS_PER_TILE-padded tuples. Both backends
    must now emit exactly ceil(n/block) blocks, and wire_bytes must
    equal the modeled size of that tuple (packed codes + 1-bit mask for
    top-k + fp32 scales)."""
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    codes, scales, mask, n_valid = ops.quantize_wire(x, bits=8, topk=topk)
    n_blocks = -(-n // BLOCK)
    assert n_valid == n
    assert codes.shape == (n_blocks, BLOCK)
    assert scales.shape == (n_blocks,)
    if topk is None:
        assert mask is None
        modeled = codes.size * 1 + scales.size * 4       # int8 + fp32
    else:
        assert mask.shape == (n_blocks, BLOCK)
        # shipped: topk packed int8 codes + 1-bit mask + fp32 scale
        modeled = n_blocks * (topk * 1 + BLOCK / 8) + scales.size * 4
    assert compression.wire_bytes(x, q=1, topk=topk) == modeled


def test_wire_bytes_2bit_packing(rng):
    """q=2 models 2-bit code packing: a quarter of the int8 payload."""
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    n_blocks = -(-1000 // BLOCK)
    assert compression.wire_bytes(x, q=2) == \
        n_blocks * BLOCK * 2 / 8 + n_blocks * 4
    assert compression.wire_bytes(x, q=2, topk=32) == \
        n_blocks * (32 * 2 / 8 + BLOCK / 8) + n_blocks * 4
    # q=0 ships raw fp32, no scales
    assert compression.wire_bytes(x, q=0) == 4000


def test_quantize_wire_empty_and_scalar(backend):
    codes, scales, mask, n = ops.quantize_wire(jnp.zeros((0,)), bits=8)
    assert n == 0 and codes.shape == (0, BLOCK) and scales.shape == (0,)
    codes, scales, mask, n = ops.quantize_wire(jnp.asarray(1.5), bits=8)
    assert n == 1 and codes.shape == (1, BLOCK)
    assert int(np.asarray(codes)[0, 0]) == 127