"""Unit tests for the CAFL-L core: duals, dead-zone, policy, token budget,
resource proxies, freezing masks, aggregation."""
import numpy as np
import pytest

from repro.configs import Budgets, DualConfig, FLConfig
from repro.core.duals import (DualState, deadzone, dual_update,
                              lagrangian_value, usage_ratios)
from repro.core.policy import Knobs, fedavg_knobs, policy, token_budget_accum
from repro.core.resources import BYTES_PER_PARAM, TABLE1_FEDAVG, calibrate

FL = FLConfig()


def test_deadzone():
    assert deadzone(1.0, 0.05) == 0.0
    assert deadzone(1.04, 0.05) == 0.0
    assert deadzone(0.97, 0.05) == 0.0
    assert deadzone(1.2, 0.05) == pytest.approx(0.2)
    assert deadzone(0.5, 0.05) == pytest.approx(-0.5)


def test_dual_update_directions():
    st = DualState()
    budgets = Budgets(energy=1.0, comm_mb=1.0, memory=1.0, temp=1.0)
    cfg = DualConfig(eta=0.5, deadzone=0.05)
    # over budget -> dual rises
    st2 = dual_update(st, {"energy": 2.0, "comm": 1.0, "memory": 0.5,
                           "temp": 1.02}, budgets, cfg)
    assert st2.lam["energy"] == pytest.approx(0.5)
    assert st2.lam["comm"] == 0.0                      # inside dead-zone
    assert st2.lam["memory"] == 0.0                    # clamped at 0
    assert st2.lam["temp"] == 0.0                      # inside dead-zone
    # under budget -> dual decays toward 0
    st3 = dual_update(st2, {"energy": 0.5, "comm": 1.0, "memory": 0.5,
                            "temp": 1.0}, budgets, cfg)
    assert st3.lam["energy"] < st2.lam["energy"]


def test_dual_clamps():
    budgets = Budgets(energy=1.0, comm_mb=1.0, memory=1.0, temp=1.0)
    cfg = DualConfig(eta=100.0, deadzone=0.05, lambda_max=10.0)
    st = dual_update(DualState(), {"energy": 99.0, "comm": 99.0,
                                   "memory": 99.0, "temp": 99.0}, budgets, cfg)
    assert all(v == 10.0 for v in st.lam.values())


def test_policy_baseline_at_zero_duals():
    kn = policy(DualState(), FL)
    assert (kn.k, kn.s, kn.b, kn.q) == (FL.k_base, FL.s_base, FL.b_base, 0)
    assert kn.grad_accum == 1


def test_policy_floors():
    st = DualState(lam={"energy": 10.0, "comm": 10.0, "memory": 10.0,
                        "temp": 10.0})
    kn = policy(st, FL)
    assert kn.k == FL.duals.k_min
    assert kn.s == FL.duals.s_min
    assert kn.b >= FL.duals.b_min
    assert kn.q == 2


def test_policy_monotone_in_duals():
    lo = policy(DualState(lam={"energy": 0.5, "comm": 0.5, "memory": 0.5,
                               "temp": 0.5}), FL)
    hi = policy(DualState(lam={"energy": 2.0, "comm": 2.0, "memory": 2.0,
                               "temp": 2.0}), FL)
    assert hi.k <= lo.k and hi.s <= lo.s and hi.b <= lo.b and hi.q >= lo.q


def test_token_budget_preservation():
    t_target = FL.s_base * FL.b_base
    for s in (10, 17, 40):
        for b in (8, 16, 32):
            ga = token_budget_accum(FL, s, b)
            assert s * b * ga >= t_target          # never under-trains
            assert s * b * (ga - 1) < t_target     # minimal accum (Eq. 8)


def test_token_budget_accum_edges():
    t_target = FL.s_base * FL.b_base
    # at or above the token target -> no accumulation
    assert token_budget_accum(FL, FL.s_base, FL.b_base) == 1
    assert token_budget_accum(FL, FL.s_base * 2, FL.b_base) == 1
    assert token_budget_accum(FL, FL.s_base + 1, FL.b_base) == 1
    # ablation: token_budget=False always yields 1
    fl_off = FL.replace(token_budget=False)
    for s, b in ((1, 1), (10, 8), (40, 32)):
        assert token_budget_accum(fl_off, s, b) == 1
    # tiny s*b -> ceil to the full target
    assert token_budget_accum(FL, 1, 1) == t_target
    assert token_budget_accum(FL, 1, 2) == -(-t_target // 2)


def test_token_preservation_clamped():
    """fl.token_preservation="clamped": Eq. 8 rounds *down*, so the
    grad-accum boost can never train past the baseline round (the ceil
    mode's overshoot is what starves tight straggler deadlines)."""
    fl_c = FL.replace(token_preservation="clamped")
    t_target = FL.s_base * FL.b_base
    for s in (2, 3, 7, 10, 25, 40, 80):
        for b in (4, 8, 11, 17, 32, 64):
            ga_ceil = token_budget_accum(FL, s, b)
            ga_cl = token_budget_accum(fl_c, s, b)
            assert 1 <= ga_cl <= ga_ceil
            if s * b <= t_target:
                assert s * b * ga_cl <= t_target       # never overshoots
                assert s * b * (ga_cl + 1) > t_target  # maximal under it
    # the concrete overshoot: ceil inflates past the baseline round
    # (deadline poison), clamped stays at or under it
    s, b = 7, 11
    assert token_budget_accum(FL, s, b) * s * b > t_target
    assert token_budget_accum(fl_c, s, b) * s * b <= t_target
    # ablation unaffected; unknown mode rejected
    assert token_budget_accum(fl_c.replace(token_budget=False), 2, 2) == 1
    with pytest.raises(ValueError):
        token_budget_accum(FL.replace(token_preservation="banana"), 2, 2)


def test_clamped_policy_never_blows_baseline_deadline():
    """Under any dual pressure, clamped knobs keep the simulated round
    time at or below one baseline round on calibration silicon — a
    deadline >= 1.0 can no longer be starved by the accum boost."""
    fl_c = FL.replace(token_preservation="clamped")
    t_target = FL.s_base * FL.b_base
    grid = (0.0, 0.3, 0.8, 2.0, 10.0)
    for lam_e in grid:
        for lam_t in grid:
            st = DualState(lam={"energy": lam_e, "comm": 0.4,
                                "memory": 0.7, "temp": lam_t})
            kn_c = policy(st, fl_c)
            assert kn_c.s * kn_c.grad_accum * kn_c.b <= t_target
            # ...while ceil mode overshoots for at least some of these
    overshoots = []
    for lam in grid:
        st = DualState(lam={"energy": lam, "comm": 0.4, "memory": 0.7,
                            "temp": lam})
        kn = policy(st, FL)
        overshoots.append(kn.s * kn.grad_accum * kn.b > t_target)
    assert any(overshoots)


def test_aggregate_weighted():
    import jax.numpy as jnp
    from repro.core import aggregation
    deltas = [{"w": jnp.ones(4)}, {"w": jnp.full(4, 5.0)}]
    # plain mean
    mean = aggregation.aggregate(deltas)
    np.testing.assert_allclose(np.asarray(mean["w"]), 3.0)
    # |D_i|-weighted (weights normalize; scale-invariant)
    for weights in ([1.0, 3.0], [10.0, 30.0]):
        w = aggregation.aggregate(deltas, weights)
        np.testing.assert_allclose(np.asarray(w["w"]), 4.0)
    # single client passes through
    one = aggregation.aggregate(deltas[:1])
    np.testing.assert_allclose(np.asarray(one["w"]), 1.0)
    # structure preserved
    import jax
    assert (jax.tree.structure(mean) == jax.tree.structure(deltas[0]))


def test_calibration_matches_table1_fedavg_row():
    res = calibrate(1.9e6, FL)
    kn = fedavg_knobs(FL)
    u = res.usage(1.9e6, kn)
    assert u["energy"] == pytest.approx(TABLE1_FEDAVG["energy"], rel=1e-6)
    assert u["comm"] == pytest.approx(TABLE1_FEDAVG["comm"], rel=1e-6)
    assert u["memory"] == pytest.approx(TABLE1_FEDAVG["memory"], rel=1e-6)
    assert u["temp"] == pytest.approx(TABLE1_FEDAVG["temp"], rel=1e-6)


def test_proxies_scale_as_appendix_a1():
    res = calibrate(2e6, FL)
    kn = fedavg_knobs(FL)
    u0 = res.usage(2e6, kn)
    # energy linear in params, s, b
    assert res.usage(1e6, kn)["energy"] == pytest.approx(u0["energy"] / 2)
    half_s = Knobs(k=kn.k, s=kn.s // 2, b=kn.b, q=0)
    assert res.usage(2e6, half_s)["energy"] == pytest.approx(u0["energy"] / 2)
    # comm scales with bytes_per_param(q)
    for q in (1, 2):
        kq = Knobs(k=kn.k, s=kn.s, b=kn.b, q=q)
        assert res.usage(2e6, kq)["comm"] == pytest.approx(
            u0["comm"] * BYTES_PER_PARAM[q] / 4.0)
    # memory has a floor: params->0 keeps 0.2*alpha_m
    assert res.usage(0.0, kn)["memory"] == pytest.approx(0.2 * res.alpha_m)


def test_control_loop_converges_into_budgets():
    """End-to-end dual/policy dynamics with proxy-only usage (no NN)."""
    res = calibrate(1.9e6, FL)
    duals = DualState()

    def p_active(k):
        return 1.9e6 * (0.94 * k / FL.k_base + 0.06)

    ratios_hist = []
    for _ in range(80):
        kn = policy(duals, FL)
        u = res.usage(p_active(kn.k), kn)
        duals = dual_update(duals, u, FL.budgets, FL.duals)
        ratios_hist.append(usage_ratios(u, FL.budgets))
    tail = ratios_hist[-10:]
    for r in ("energy", "comm", "memory", "temp"):
        mean_r = np.mean([x[r] for x in tail])
        assert mean_r < 1.15, f"{r} not controlled: {mean_r:.2f}"
    # and FedAvg violates comm/memory (the paper's Fig. 2)
    u_fa = res.usage(1.9e6, fedavg_knobs(FL))
    r_fa = usage_ratios(u_fa, FL.budgets)
    assert r_fa["comm"] > 5.0 and r_fa["memory"] > 1.05


def test_lagrangian_value_penalty():
    budgets = Budgets(energy=1.0, comm_mb=1.0, memory=1.0, temp=1.0)
    st = DualState(lam={"energy": 2.0, "comm": 0.0, "memory": 0.0, "temp": 0.0})
    val = lagrangian_value(1.0, {"energy": 1.5, "comm": 0.1, "memory": 0.1,
                                 "temp": 0.1}, budgets, st)
    assert val == pytest.approx(1.0 + 2.0 * 0.5)
