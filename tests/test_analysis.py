"""Tests for the repro.analysis static-analysis subsystem: the rule
engine over seeded-violation / clean fixture trees, baseline
round-tripping, CLI exit codes, and the repo-level zero-new-findings
policy (see tests/README.md)."""
import json
import os
import shutil

import pytest

from repro.analysis import (Analyzer, Baseline, Finding, rule_ids,
                            run_analysis)
from repro.analysis.cli import main as cli_main

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
SEEDED = os.path.join(HERE, "fixtures", "analysis", "seeded")
CLEAN = os.path.join(HERE, "fixtures", "analysis", "clean")

ALL_RULES = ("JAX001", "JAX002", "JAX003", "JAX004",
             "REPRO001", "REPRO002", "REPRO003",
             "SCHED001", "SCHED002", "SCHED003", "SCHED004")


@pytest.fixture(scope="module")
def seeded_result():
    return run_analysis(SEEDED)


@pytest.fixture(scope="module")
def clean_result():
    return run_analysis(CLEAN)


# ---------------------------------------------------------------------------
# rule engine over the fixture trees
# ---------------------------------------------------------------------------


def test_registry_has_all_rules():
    assert set(rule_ids()) == set(ALL_RULES)


def test_every_rule_fires_on_seeded_tree(seeded_result):
    by_rule = seeded_result.by_rule()
    assert set(by_rule) == set(ALL_RULES)


def test_clean_tree_is_clean(clean_result):
    assert clean_result.findings == []


@pytest.mark.parametrize("rule,path,needle", [
    ("JAX001", "src/mod_jax001.py", "consumed twice"),
    ("JAX001", "src/mod_jax001.py", "used after jax.random.split"),
    ("JAX001", "src/mod_jax001.py", "inside a loop"),
    ("JAX002", "src/mod_jax002.py", "declared static"),
    ("JAX003", "src/mod_jax003.py", "import time"),
    ("JAX004", "src/repro/fl/engine.py", "per-client Python loop"),
    ("REPRO001", "src/repro/kernels/wire.py", "no pure-jnp twin"),
    ("REPRO002", "benchmarks/bench_bad.py", "no MetricSpec"),
    ("REPRO002", "benchmarks/bench_bad.py", "direction"),
    ("REPRO003", "src/mod_repro003.py", "wire accounting"),
    ("REPRO003", "src/mod_repro003.py", "token_budget"),
    ("SCHED001", "src/repro/fl/aggregator.py", "accumulation inside a loop"),
    ("SCHED001", "src/repro/fl/aggregator.py", "folds report buffer"),
    ("SCHED002", "src/repro/fl/clock.py", "insertion order"),
    ("SCHED002", "src/repro/fl/clock.py", "per-process order"),
    ("SCHED003", "src/repro/fl/clock.py", "bare timestamp '.arrival'"),
    ("SCHED003", "src/repro/fl/clock.py", "bare timestamp '.t'"),
    ("SCHED004", "src/repro/fl/aggregator.py", "module-level RNG"),
    ("SCHED004", "src/repro/fl/aggregator.py", "without a seed"),
    ("SCHED004", "src/repro/fl/aggregator.py", "component state"),
    ("SCHED004", "src/repro/fl/aggregator.py", "global RNG singleton"),
])
def test_seeded_violation_is_found(seeded_result, rule, path, needle):
    hits = [f for f in seeded_result.findings
            if f.rule == rule and f.path == path and needle in f.message]
    assert hits, (f"{rule} should flag {path} with {needle!r}; got "
                  f"{[f.format() for f in seeded_result.findings]}")


def test_findings_carry_location_and_hint(seeded_result):
    for f in seeded_result.findings:
        assert f.line >= 1 and f.path and f.hint
        assert f"{f.path}:{f.line}" in f.format()


def test_rule_filtering():
    only = run_analysis(SEEDED, rules=[
        r for r in Analyzer(SEEDED).rules if r.id == "JAX003"])
    assert {f.rule for f in only.findings} == {"JAX003"}


# ---------------------------------------------------------------------------
# baseline round-trip
# ---------------------------------------------------------------------------


def test_baseline_roundtrip(tmp_path, seeded_result):
    path = str(tmp_path / "base.json")
    Baseline.from_findings(seeded_result.findings).save(path)
    base = Baseline.load(path)
    new, suppressed, stale = base.diff(seeded_result.findings)
    assert new == [] and stale == []
    assert len(suppressed) == len(seeded_result.findings)


def test_baseline_flags_new_and_stale(tmp_path, seeded_result):
    findings = list(seeded_result.findings)
    held_out, rest = findings[0], findings[1:]
    base = Baseline.from_findings(rest)
    new, suppressed, stale = base.diff(findings)
    assert [f.fingerprint for f in new] == [held_out.fingerprint]
    # a baseline entry with no matching finding is stale
    extra = Finding(rule="JAX001", path="src/gone.py", line=1,
                    message="was fixed", hint="", snippet="x = 1")
    base2 = Baseline.from_findings(rest + [extra])
    _, _, stale2 = base2.diff(rest)
    assert [e["fingerprint"] for e in stale2] == [extra.fingerprint]


def test_fingerprint_survives_line_drift():
    a = Finding(rule="R", path="p.py", line=3, message="m", hint="",
                snippet="x = jnp.ones(4)")
    b = Finding(rule="R", path="p.py", line=300, message="m", hint="",
                snippet="x = jnp.ones(4)")
    c = Finding(rule="R", path="p.py", line=3, message="m", hint="",
                snippet="y = jnp.ones(4)")
    assert a.fingerprint == b.fingerprint != c.fingerprint


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_exit_codes(capsys):
    assert cli_main(["--root", SEEDED]) == 1
    assert cli_main(["--root", CLEAN]) == 0
    assert cli_main(["--root", SEEDED, "--rules", "NOPE"]) == 2
    assert cli_main(["--list-rules"]) == 0
    capsys.readouterr()


def test_cli_json_output(capsys):
    assert cli_main(["--root", SEEDED, "--json"]) == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["new"] and not payload["suppressed"]
    assert {f["rule"] for f in payload["new"]} == set(ALL_RULES)


def test_cli_update_baseline_then_clean(tmp_path, capsys):
    root = str(tmp_path / "tree")
    shutil.copytree(SEEDED, root)
    assert cli_main(["--root", root]) == 1
    assert cli_main(["--root", root, "--update-baseline"]) == 0
    assert cli_main(["--root", root]) == 0          # all suppressed now
    out = capsys.readouterr().out
    assert "suppressed by baseline" in out
    # fixing a violation makes its baseline entry stale -> exit 1
    eng = os.path.join(root, "src", "repro", "fl", "engine.py")
    with open(eng, "w") as f:
        f.write("def aggregate_round(stacked):\n    return stacked.sum(0)\n")
    assert cli_main(["--root", root]) == 1
    assert "STALE" in capsys.readouterr().out
    assert cli_main(["--root", root, "--update-baseline"]) == 0
    assert cli_main(["--root", root]) == 0


# ---------------------------------------------------------------------------
# the repo itself: the zero-new-findings policy
# ---------------------------------------------------------------------------


def test_repo_is_clean_under_committed_baseline():
    """CI's analysis gate, as a test: every finding in the tree is owned
    by the committed ANALYSIS_BASELINE.json — new code adds nothing."""
    assert os.path.exists(os.path.join(REPO, "ANALYSIS_BASELINE.json"))
    assert cli_main(["--root", REPO]) == 0
