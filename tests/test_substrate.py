"""Substrate tests: data pipeline, optimizers, aggregation, specs."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_config, get_smoke_config
from repro.core.aggregation import aggregate, apply_delta
from repro.data import FederatedData, load_corpus, sample_batch, synthetic_batch
from repro.optim import adamw, apply_updates, clip_by_global_norm, make_optimizer


def test_corpus_loads_and_batches():
    ds = load_corpus(target_bytes=50_000)
    assert ds.vocab_size > 20
    assert len(ds.train) > 40_000 and len(ds.val) > 4_000
    rng = np.random.default_rng(0)
    b = sample_batch(ds.train, rng, 4, 16)
    assert b["tokens"].shape == (4, 16) and b["targets"].shape == (4, 16)
    # targets are next-char shifted
    assert ds.decode(b["tokens"][0][1:]) == ds.decode(b["targets"][0][:-1])


def test_federated_partition_covers_everyone():
    ds = load_corpus(target_bytes=50_000)
    fd = FederatedData(ds.train, num_clients=8, seed=0)
    sizes = [fd.shard_size(i) for i in range(8)]
    assert sum(sizes) == len(ds.train)
    assert min(sizes) > 100
    fd2 = FederatedData(ds.train, num_clients=8, seed=0, noniid_alpha=0.3)
    sizes2 = [fd2.shard_size(i) for i in range(8)]
    assert sum(sizes2) == len(ds.train)
    assert np.std(sizes2) > np.std(sizes)  # non-IID skews shard sizes


def test_adamw_optimizes_quadratic():
    opt = adamw(0.1)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        ups, state = opt.update(grads, state, params)
        params = apply_updates(params, ups)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


@pytest.mark.parametrize("name", ["sgd", "momentum", "adam", "adamw"])
def test_optimizers_reduce_quadratic(name):
    opt = make_optimizer(name, 0.05)
    params = {"w": jnp.asarray([1.0, -1.5])}
    state = opt.init(params)
    def loss(p):
        return float(jnp.sum(p["w"] ** 2))
    l0 = loss(params)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}
        ups, state = opt.update(grads, state, params)
        params = apply_updates(params, ups)
    assert loss(params) < l0 * 0.5


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_aggregation_mean_and_weighted():
    d1 = {"w": jnp.asarray([1.0, 1.0])}
    d2 = {"w": jnp.asarray([3.0, 5.0])}
    mean = aggregate([d1, d2])
    np.testing.assert_allclose(np.asarray(mean["w"]), [2.0, 3.0])
    weighted = aggregate([d1, d2], weights=[3.0, 1.0])
    np.testing.assert_allclose(np.asarray(weighted["w"]), [1.5, 2.0])
    p = {"w": jnp.asarray([10.0, 10.0])}
    np.testing.assert_allclose(np.asarray(apply_delta(p, mean)["w"]),
                               [12.0, 13.0])


@pytest.mark.parametrize("arch", ["paligemma-3b", "seamless-m4t-medium",
                                  "qwen2-72b"])
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_input_specs_match_model_batformat(arch, shape_name):
    """input_specs() structures must match what the model consumes —
    validated by eval_shape of the step function on smoke-size dims."""
    from repro.launch import specs as S
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    batch = S.input_specs(cfg, shape)
    assert "tokens" in batch
    total = shape.seq_len
    if cfg.encdec:
        assert batch["src_embeds"].shape[0] == shape.global_batch
        assert batch["tokens"].shape[1] + (
            batch["src_embeds"].shape[1] if shape.kind == "train" else 0
        ) <= total
    elif cfg.frontend is not None:
        assert batch["patch_embeds"].shape[1] + batch["tokens"].shape[1] == total
    else:
        assert batch["tokens"].shape == (shape.global_batch, total)
    if shape.kind == "train":
        assert "targets" in batch
    else:
        assert "targets" not in batch


def test_synthetic_batch_shapes():
    cfg = get_smoke_config("paligemma-3b")
    b = synthetic_batch(cfg, 2, 64)
    assert b["tokens"].shape == (2, 64 - cfg.frontend.num_prefix_tokens)
    assert b["patch_embeds"].shape == (2, cfg.frontend.num_prefix_tokens,
                                       cfg.frontend.embed_dim)
    cfg2 = get_smoke_config("seamless-m4t-medium")
    b2 = synthetic_batch(cfg2, 2, 64)
    assert b2["src_embeds"].shape[1] + b2["tokens"].shape[1] == 64


def test_schedules():
    from repro.optim.schedules import (constant, inverse_sqrt,
                                       scale_lr_for_accum, warmup_cosine)
    f = warmup_cosine(1.0, 10, 100)
    assert f(0) == pytest.approx(0.1)
    assert f(9) == pytest.approx(1.0)
    assert f(10) == pytest.approx(1.0)
    assert f(100) == pytest.approx(0.1)       # final_frac
    assert all(f(s) >= f(s + 1) - 1e-9 for s in range(10, 100))
    g = inverse_sqrt(1.0, 16)
    assert g(15) == pytest.approx(1.0)
    assert g(64) == pytest.approx(0.5)
    assert constant(0.3)(123) == 0.3
    assert scale_lr_for_accum(0.1, 4) == pytest.approx(0.4)
    assert scale_lr_for_accum(0.1, 4, "sqrt") == pytest.approx(0.2)
