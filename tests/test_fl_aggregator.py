"""Server-update policies (repro.fl.aggregator): sync-barrier
equivalence with the pre-refactor stream, FedBuff buffering + staleness
discounts, staleness-policy invariants (hypothesis), masked-sum
exactness under every dropout combination, and engine integration
(late reports delivered instead of discarded)."""
import dataclasses
from itertools import combinations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_fl_config
from repro.core import aggregation
from repro.core.policy import Knobs
from repro.data import load_corpus
from repro.fl import (ClientInfo, ClientReport, ConstantStaleness,
                      DeadlineStragglers, DeviceProfile, EventQueue, FedAvg,
                      FedBuffAggregator, FederatedEngine, FleetDynamics,
                      MaskedSumAggregator, PolynomialStaleness, RoundCallback,
                      StalenessWeightedAggregator, SyncAggregator,
                      UniformSampler, make_aggregator, make_staleness_policy)
from repro.models import build

KN = Knobs(k=2, s=4, b=8, q=0)
FLC = get_fl_config()


def _ci(cid, shard=100):
    return ClientInfo(cid, DeviceProfile("default", FLC.budgets), shard)


def _report(cid, value, weight=1.0, staleness=0, rnd=1, shard=100):
    rep = ClientReport(client=_ci(cid, shard),
                       delta={"w": jnp.full(3, float(value))},
                       weight=float(weight), knobs=KN, policy_knobs=KN,
                       round_trained=rnd - staleness)
    rep.round_submitted = rnd
    rep.staleness = staleness
    return rep


# ---------------------------------------------------------------------------
# unit: sync / fedbuff / staleness policies
# ---------------------------------------------------------------------------


def test_sync_barrier_buffers_until_flush():
    agg = SyncAggregator()
    agg.reset(FedAvg(FLC).aggregate)
    for i, v in enumerate((1.0, 5.0, 9.0)):
        assert agg.submit(_report(i, v)) is None
    assert agg.state_snapshot()["buffered"] == 3
    upd = agg.flush(1)
    np.testing.assert_allclose(np.asarray(upd.delta["w"]), 5.0)  # plain mean
    assert upd.round == 1 and len(upd.reports) == 3
    assert upd.mean_staleness == 0.0
    assert agg.flush(2) is None                # barrier drained the buffer
    assert agg.state_snapshot()["updates_applied"] == 1


def test_sync_weights_route_through_combine():
    """ClientReport.weight is the single weight path: a weighted combine
    sees the example counts, an unweighted one ignores them."""
    reports = [_report(0, 1.0, weight=1.0), _report(1, 5.0, weight=3.0)]
    for weighted, want in ((False, 3.0), (True, 4.0)):
        agg = SyncAggregator()
        agg.reset(FedAvg(FLC, weighted=weighted).aggregate)
        for r in reports:
            agg.submit(r)
        np.testing.assert_allclose(np.asarray(agg.flush(1).delta["w"]), want)


def test_fedbuff_applies_every_k_arrivals():
    agg = FedBuffAggregator(buffer_size=2, policy=PolynomialStaleness(0.0))
    agg.reset(FedAvg(FLC).aggregate)
    assert agg.submit(_report(0, 2.0)) is None
    upd = agg.submit(_report(1, 4.0))          # K-th arrival fires mid-round
    np.testing.assert_allclose(np.asarray(upd.delta["w"]), 3.0)
    assert agg.submit(_report(2, 8.0)) is None  # buffer persists across
    assert agg.flush(1) is None                 # rounds: flush is a no-op
    assert agg.state_snapshot()["buffered"] == 1
    upd2 = agg.submit(_report(3, 2.0, rnd=2))
    np.testing.assert_allclose(np.asarray(upd2.delta["w"]), 5.0)
    assert agg.state_snapshot()["updates_applied"] == 2


def test_fedbuff_staleness_discounts_deltas():
    """A report tau rounds stale *at apply time* contributes
    (1+tau)^-alpha of itself, so late work is used but cannot drag the
    model at full strength."""
    agg = FedBuffAggregator(buffer_size=2, policy=PolynomialStaleness(0.5))
    agg.reset(FedAvg(FLC).aggregate)
    agg.submit(_report(0, 4.0, staleness=0, rnd=4))
    upd = agg.submit(_report(1, 4.0, staleness=3, rnd=4))
    want = (4.0 + 4.0 * (1 + 3) ** -0.5) / 2
    np.testing.assert_allclose(np.asarray(upd.delta["w"]), want, rtol=1e-6)
    assert upd.mean_staleness == pytest.approx(1.5)


def test_fedbuff_staleness_accrues_in_buffer():
    """A fresh report that sits in the buffer while rounds pass ages:
    tau counts from its training round to the round it is APPLIED, not
    the round it was delivered (Nguyen et al.'s definition)."""
    agg = FedBuffAggregator(buffer_size=2, policy=PolynomialStaleness(0.5))
    agg.reset(FedAvg(FLC).aggregate)
    agg.submit(_report(0, 4.0, staleness=0, rnd=1))   # fresh at round 1
    upd = agg.submit(_report(1, 4.0, staleness=0, rnd=3))  # fires round 3
    want = (4.0 * (1 + 2) ** -0.5 + 4.0) / 2   # report 0 aged 2 rounds
    np.testing.assert_allclose(np.asarray(upd.delta["w"]), want, rtol=1e-6)
    assert upd.mean_staleness == pytest.approx(1.0)


def test_staleness_weighted_modes():
    reports = [_report(0, 2.0, weight=2.0, staleness=0),
               _report(1, 6.0, weight=2.0, staleness=1, rnd=2)]
    pol = ConstantStaleness(0.5)
    # mode="scale": the late delta itself is attenuated (works under the
    # paper's unweighted mean)
    agg = StalenessWeightedAggregator(policy=pol, mode="scale")
    agg.reset(FedAvg(FLC).aggregate)
    for r in reports:
        agg.submit(r)
    np.testing.assert_allclose(np.asarray(agg.flush(2).delta["w"]),
                               (2.0 + 3.0) / 2)
    # mode="weight": the late client's example-count weight is halved
    # (bites only with a weight-respecting combine)
    agg = StalenessWeightedAggregator(policy=pol, mode="weight")
    agg.reset(FedAvg(FLC, weighted=True).aggregate)
    for r in reports:
        agg.submit(r)
    np.testing.assert_allclose(np.asarray(agg.flush(2).delta["w"]),
                               (2.0 * 2 + 6.0 * 1) / 3, rtol=1e-6)


def test_make_aggregator_resolution():
    assert isinstance(make_aggregator("sync", FLC), SyncAggregator)
    fb = make_aggregator("fedbuff", FLC)
    assert isinstance(fb, FedBuffAggregator)
    assert fb.buffer_size == max(2, (FLC.clients_per_round + 1) // 2)
    assert isinstance(make_aggregator("staleness", FLC),
                      StalenessWeightedAggregator)
    assert isinstance(make_aggregator("masked", FLC), MaskedSumAggregator)
    inst = SyncAggregator()
    assert make_aggregator(inst, FLC) is inst      # instances pass through
    with pytest.raises(ValueError):
        make_aggregator("telepathic", FLC)
    with pytest.raises(ValueError):
        make_staleness_policy("psychic")


# ---------------------------------------------------------------------------
# hypothesis: staleness-discount invariants
# ---------------------------------------------------------------------------

try:        # hypothesis widens the sweep; without it a fixed grid runs
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

POLICIES = [PolynomialStaleness(0.0), PolynomialStaleness(0.5),
            PolynomialStaleness(2.0), ConstantStaleness(0.25),
            ConstantStaleness(1.0)]


def _check_discount_invariants(entries, policy):
    """Discounts live in (0, 1], never increase with staleness, and
    discounted weights renormalize to a positive unit simplex — the
    combine path can never flip or zero a late client's sign."""
    weights = [w for w, _ in entries]
    staleness = [s for _, s in entries]
    discounts = [policy.discount(s) for s in staleness]
    assert all(0.0 < d <= 1.0 for d in discounts)
    assert policy.discount(0) == 1.0
    for s in range(0, 50, 7):
        assert policy.discount(s + 1) <= policy.discount(s)
    effective = [w * d for w, d in zip(weights, discounts)]
    norm = aggregation.normalize_weights(effective, len(effective))
    assert all(x > 0.0 for x in norm)
    assert sum(norm) == pytest.approx(1.0, abs=1e-9)


@pytest.mark.parametrize("policy", POLICIES, ids=lambda p: repr(vars(p)))
def test_staleness_discount_invariants_grid(policy):
    entries = [(w, s) for w in (1e-3, 1.0, 37.5, 1e6)
               for s in (0, 1, 3, 17, 50)]
    _check_discount_invariants(entries, policy)


if HAVE_HYPOTHESIS:
    @given(entries=st.lists(
        st.tuples(st.floats(min_value=1e-3, max_value=1e6),
                  st.integers(min_value=0, max_value=50)),
        min_size=1, max_size=8),
        policy=st.sampled_from(POLICIES))
    @settings(deadline=None, max_examples=100)
    def test_staleness_discount_invariants(entries, policy):
        _check_discount_invariants(entries, policy)


# ---------------------------------------------------------------------------
# masked sums: exact under every dropout combination
# ---------------------------------------------------------------------------


def _fixed_point_mean(deltas, weights, scale):
    """The unmasked fixed-point reference: what a correct secure sum
    must equal bit-for-bit once every mask is removed."""
    leaves_list = [jax.tree.flatten(d)[0] for d in deltas]
    treedef = jax.tree.flatten(deltas[0])[1]
    tot_w = sum(weights)
    out = []
    for pos in range(len(leaves_list[0])):
        acc = np.zeros(np.shape(leaves_list[0][pos]), np.int64)
        for leaves, w in zip(leaves_list, weights):
            acc = acc + np.rint(
                np.asarray(leaves[pos], np.float64) * w * scale
            ).astype(np.int64)
        out.append(jnp.asarray(
            (acc.astype(np.float64) / (scale * tot_w)).astype(np.float32)))
    return jax.tree.unflatten(treedef, out)


@pytest.mark.parametrize("path", ["numpy", "kernel"])
def test_masked_sum_exact_under_every_dropout_combination(path):
    """Pairwise-mask cancellation + dropped-mask reconstruction is
    modular-integer exact: for EVERY subset of a 4-client cohort that
    reports (the PR 2 churn/deadline dropout patterns), the unmasked
    result equals the plain weighted mean of the reporters — on both
    the sequential NumPy oracle and the stacked kernel fold (modular
    sums are associative, so the paths must be bit-identical)."""
    rng = np.random.default_rng(0)
    cohort = [_ci(i, shard=50 + 17 * i) for i in range(4)]
    deltas = [{"a": jnp.asarray(rng.normal(size=(3, 2)).astype(np.float32)),
               "b": jnp.asarray(rng.normal(size=(5,)).astype(np.float32))}
              for _ in cohort]
    weights = [float(ci.shard_size) for ci in cohort]
    for n_rep in range(1, len(cohort) + 1):
        for subset in combinations(range(len(cohort)), n_rep):
            agg = MaskedSumAggregator(use_weights=True, path=path)
            agg.reset(FedAvg(FLC).aggregate)
            agg.begin_round(3, cohort)
            for i in subset:
                rep = ClientReport(client=cohort[i], delta=deltas[i],
                                   weight=weights[i], knobs=KN,
                                   policy_knobs=KN, round_trained=3)
                assert agg.submit(rep) is None
            upd = agg.flush(3)
            assert len(upd.reports) == n_rep
            # bit-for-bit: masks left zero residue behind
            want_fp = _fixed_point_mean([deltas[i] for i in subset],
                                        [weights[i] for i in subset],
                                        agg.scale)
            for key in ("a", "b"):
                np.testing.assert_array_equal(np.asarray(upd.delta[key]),
                                              np.asarray(want_fp[key]))
            # and the fixed-point grid itself is a faithful weighted mean
            want = aggregation.aggregate([deltas[i] for i in subset],
                                         [weights[i] for i in subset])
            for key in ("a", "b"):
                np.testing.assert_allclose(np.asarray(upd.delta[key]),
                                           np.asarray(want[key]),
                                           rtol=0, atol=1e-6)


def test_masked_sum_edges():
    cohort = [_ci(0), _ci(1)]
    agg = MaskedSumAggregator()       # default: the paper's plain mean
    agg.reset(FedAvg(FLC).aggregate)
    agg.begin_round(1, cohort)
    assert agg.flush(1) is None                 # everyone dropped
    # a report from outside the agreed cohort is a protocol violation
    agg.begin_round(2, cohort)
    with pytest.raises(AssertionError):
        agg.submit(_report(7, 1.0))
    # unweighted mode: weights play no role in the mean
    agg.begin_round(3, cohort)
    agg.submit(_report(0, 2.0, weight=1.0))
    agg.submit(_report(1, 6.0, weight=99.0))
    np.testing.assert_allclose(np.asarray(agg.flush(3).delta["w"]), 4.0,
                               rtol=0, atol=1e-7)
    assert agg.state_snapshot()["masks_reconstructed"] == 0


# ---------------------------------------------------------------------------
# commutativity certificates: the determinism contract, unit-level
# ---------------------------------------------------------------------------


def _update_bytes(upd):
    return np.asarray(upd.delta["w"]).tobytes()


def _check_barrier_commutes(kind, values, perm):
    """Sync/MaskedSum certificates: the flushed update is a function of
    the report *set* — any submission-order permutation is bit-exact."""
    def run(order):
        if kind == "sync":
            agg = SyncAggregator()
            agg.reset(FedAvg(FLC, weighted=True).aggregate)
        else:
            agg = MaskedSumAggregator(use_weights=True, path="numpy")
            agg.reset(FedAvg(FLC).aggregate)
            agg.begin_round(1, [_ci(i) for i in range(len(values))])
        for i in order:
            assert agg.submit(
                _report(i, values[i], weight=1.0 + (i % 3))) is None
        return _update_bytes(agg.flush(1))
    assert run(perm) == run(range(len(values)))


def _check_streaming_tiebroken(kind, specs, seed):
    """FedBuff/StalenessWeighted certificate: with distinct arrivals the
    sort_key order is a function of the report set alone, so any
    push-order shuffle delivers the same sequence — and the update
    stream it produces must be bit-identical."""
    def run(push_order):
        if kind == "fedbuff":
            agg = FedBuffAggregator(buffer_size=2,
                                    policy=PolynomialStaleness(0.5))
        else:
            agg = StalenessWeightedAggregator(
                policy=ConstantStaleness(0.5), mode="scale")
        agg.reset(FedAvg(FLC).aggregate)
        q = EventQueue()
        for i in push_order:
            value, stale, arrival = specs[i]
            q.push(arrival, _report(i, value, staleness=stale, rnd=4))
        out = []
        for ev in q.drain():
            upd = agg.submit(ev.report)
            if upd is not None:
                out.append(_update_bytes(upd))
        tail = agg.flush(4)
        if tail is not None:
            out.append(_update_bytes(tail))
        return out
    n = len(specs)
    perm = list(np.random.default_rng(seed).permutation(n))
    ident = run(list(range(n)))
    assert ident                           # something was applied
    assert run(perm) == ident


def _specs(rng, n):
    # distinct arrivals by construction: the tie-break is the sort key's
    # *arrival* component, exercised without ties
    arrivals = rng.permutation(n) * 1.0
    return [(float(rng.normal()), int(rng.integers(0, 4)), float(a))
            for a in arrivals]


@pytest.mark.parametrize("kind", ["sync", "masked"])
def test_barrier_fold_commutes_grid(kind):
    rng = np.random.default_rng(7)
    for n in (1, 3, 6):
        _check_barrier_commutes(kind, list(rng.normal(size=n)),
                                list(rng.permutation(n)))


@pytest.mark.parametrize("kind", ["fedbuff", "staleness"])
def test_streaming_tiebroken_grid(kind):
    rng = np.random.default_rng(11)
    for n in (2, 4, 7):
        _check_streaming_tiebroken(kind, _specs(rng, n), seed=n)


if HAVE_HYPOTHESIS:
    @given(values=st.lists(st.floats(min_value=-1e3, max_value=1e3,
                                     allow_nan=False, allow_infinity=False),
                           min_size=1, max_size=8),
           kind=st.sampled_from(["sync", "masked"]),
           seed=st.integers(min_value=0, max_value=2 ** 16))
    @settings(deadline=None, max_examples=40)
    def test_barrier_fold_commutes(values, kind, seed):
        perm = list(np.random.default_rng(seed).permutation(len(values)))
        _check_barrier_commutes(kind, values, perm)

    @given(n=st.integers(min_value=2, max_value=8),
           kind=st.sampled_from(["fedbuff", "staleness"]),
           seed=st.integers(min_value=0, max_value=2 ** 16))
    @settings(deadline=None, max_examples=40)
    def test_streaming_tiebroken(n, kind, seed):
        rng = np.random.default_rng(seed)
        _check_streaming_tiebroken(kind, _specs(rng, n), seed=seed + 1)


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_setup():
    ds = load_corpus(target_bytes=60_000)
    cfg = get_config("charlm-shakespeare").replace(
        vocab_size=max(ds.vocab_size, 64), num_layers=3, d_model=48,
        num_heads=4, num_kv_heads=4, head_dim=12, d_ff=96)
    fl = get_fl_config().replace(
        rounds=2, num_clients=4, clients_per_round=2, s_base=3, b_base=8,
        seq_len=16, eval_batches=1, eval_batch_size=8)
    fl = fl.replace(duals=dataclasses.replace(fl.duals, s_min=2, b_min=4))
    return ds, cfg, fl


@pytest.fixture(scope="module")
def tiny_model(tiny_setup):
    _, cfg, _ = tiny_setup
    return build(cfg)


def _straggler_dynamics(fl, deadline=1.1, jitter=0.5):
    return FleetDynamics(
        sampler=UniformSampler(fl.clients_per_round),
        stragglers=DeadlineStragglers.for_config(fl, deadline=deadline,
                                                 jitter=jitter))


def test_engine_explicit_sync_is_stream_identical(tiny_setup, tiny_model):
    """aggregator=None, aggregator="sync" and an explicit instance all
    reproduce the same trajectory, stragglers included — the refactor
    moved the barrier without changing it (goldens pin the rest)."""
    ds, cfg, fl = tiny_setup
    runs = [FederatedEngine(tiny_model, fl, ds, strategy="cafl",
                            dynamics=_straggler_dynamics(fl),
                            aggregator=agg).run()
            for agg in (None, "sync", SyncAggregator())]
    for other in runs[1:]:
        for ra, rb in zip(runs[0].history, other.history):
            assert ra.participants == rb.participants
            assert ra.dropped == rb.dropped and rb.late_arrivals == []
            assert ra.knobs == rb.knobs and ra.duals == rb.duals
            assert ra.val_loss == pytest.approx(rb.val_loss, abs=1e-6)
            assert ra.usage == pytest.approx(rb.usage)
            assert rb.updates_applied == (1 if rb.participants else 0)
            assert rb.reports_applied == len(rb.participants)
            assert rb.mean_staleness == 0.0


def test_engine_fedbuff_delivers_late_reports(tiny_setup, tiny_model):
    """Under deadline stragglers, an accepts_late aggregator turns
    deadline-missers into late arrivals: they show up as participants
    of a later round with positive staleness, not as losses."""
    ds, cfg, fl = tiny_setup
    fl = fl.replace(rounds=5, clients_per_round=3)
    updates = []
    plans = []

    class Catcher(RoundCallback):
        def on_server_update(self, engine, update):
            updates.append(update)

        def on_round_composed(self, engine, plan):
            plans.append(plan)

    dyn = _straggler_dynamics(fl, deadline=0.95, jitter=0.5)
    res = FederatedEngine(
        tiny_model, fl, ds, strategy="cafl", dynamics=dyn,
        aggregator=FedBuffAggregator(buffer_size=2),
        callbacks=[Catcher()]).run()
    assert any(r.late_arrivals for r in res.history), \
        "deadline=0.95 with jitter must produce at least one late delivery"
    for r in res.history:
        assert set(r.late_arrivals) <= set(r.participants)
        if r.late_arrivals:
            assert r.mean_staleness > 0.0
        assert np.isfinite(r.val_loss)
    # a miss is only ever LOST when its delivery would overrun the run
    # horizon (the simulator never executes work it cannot apply)
    for plan in plans:
        for pos, cid in enumerate(plan.sampled):
            if cid in plan.dropped and cid not in plan.late:
                delay = dyn.stragglers.late_rounds(plan.times[pos])
                assert delay is None or plan.round + delay > fl.rounds
    assert sum(r.updates_applied for r in res.history) == len(updates) > 0
    assert sum(r.reports_applied for r in res.history) == \
        sum(len(u.reports) for u in updates)
    # buffer_size respected, except the terminal drain may run partial
    assert all(len(u.reports) == 2 for u in updates[:-1])
    assert len(updates[-1].reports) <= 2
    # a client never trains two rounds concurrently: while its late
    # report is in flight it is out of the sampling roster
    busy = {}
    for plan in plans:
        for cid in plan.sampled:
            assert busy.get(cid, 0) < plan.round, \
                f"client {cid} sampled while still training"
        for cid in plan.late:
            pos = plan.sampled.index(cid)
            delay = dyn.stragglers.late_rounds(plan.times[pos])
            busy[cid] = plan.round + delay
    # every executed report is eventually applied (terminal drain):
    # participants and applied reports agree in total
    assert sum(r.reports_applied for r in res.history) == \
        sum(len(r.participants) for r in res.history)
    # late reports repay token debt (they were used, not lost): only
    # clients whose report was actually discarded may carry debt
    lost = {c for r in res.history for c in r.dropped}
    assert all(dyn.debt(cid) == 0 for cid in range(fl.num_clients)
               if cid not in lost)


def test_engine_staleness_aggregator_smoke(tiny_setup, tiny_model):
    ds, cfg, fl = tiny_setup
    fl = fl.replace(rounds=4, clients_per_round=3)
    res = FederatedEngine(
        tiny_model, fl, ds, strategy="cafl",
        dynamics=_straggler_dynamics(fl, deadline=0.95, jitter=0.5),
        aggregator="staleness").run()
    # the barrier still applies at most one update per round
    for r in res.history:
        assert r.updates_applied <= 1
        assert np.isfinite(r.val_loss)
        for lam in r.duals.values():
            assert np.isfinite(lam) and lam >= 0.0


def test_engine_masked_matches_sync(tiny_setup, tiny_model):
    """End-to-end: swapping the open barrier for the secure-aggregation
    simulation changes only *how securely* the mean is computed — the
    default combination rule (paper's plain mean) is identical, so the
    trajectories agree up to fixed-point quantization. The weighted
    variants agree likewise."""
    ds, cfg, fl = tiny_setup
    for strategy, masked in (
            ("fedavg", MaskedSumAggregator()),
            ("fedavg_weighted", MaskedSumAggregator(use_weights=True))):
        res_sync = FederatedEngine(tiny_model, fl, ds, strategy=strategy,
                                   aggregator="sync").run()
        res_masked = FederatedEngine(tiny_model, fl, ds, strategy=strategy,
                                     aggregator=masked).run()
        for ra, rb in zip(res_sync.history, res_masked.history):
            assert ra.participants == rb.participants
            assert ra.train_loss == pytest.approx(rb.train_loss, abs=1e-6)
            assert ra.val_loss == pytest.approx(rb.val_loss, abs=2e-3)


def test_run_federated_honors_fl_aggregator(tiny_setup, tiny_model):
    """The seed wrapper picks up fl.aggregator (config-driven policy
    selection) without any API change."""
    from repro.core import run_federated
    ds, cfg, fl = tiny_setup
    res = run_federated(tiny_model, fl.replace(aggregator="fedbuff"),
                        tiny_setup[0], method="fedavg", rounds=1, log=None)
    assert len(res.history) == 1
    assert np.isfinite(res.history[0].val_loss)
