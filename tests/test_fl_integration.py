"""Integration tests: the full federated loop end-to-end on a tiny model,
freezing masks, compression wiring, aggregation, checkpoint round-trip."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_fl_config
from repro.core import run_federated
from repro.core.compression import compress_decompress, compression_error, wire_mb
from repro.core.freezing import apply_mask, count_active, count_params, mask_tree
from repro.data import load_corpus
from repro.models import build


@pytest.fixture(scope="module")
def tiny_setup():
    ds = load_corpus(target_bytes=60_000)
    cfg = get_config("charlm-shakespeare").replace(
        vocab_size=max(ds.vocab_size, 64), num_layers=3, d_model=64,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128)
    fl = get_fl_config().replace(
        rounds=2, num_clients=4, clients_per_round=2, s_base=4, b_base=8,
        seq_len=24, eval_batches=1, eval_batch_size=8)
    # floors must sit below the tiny baselines for the test
    fl = fl.replace(duals=dataclasses.replace(fl.duals, s_min=2, b_min=4))
    return ds, cfg, fl


def test_run_federated_both_methods(tiny_setup):
    ds, cfg, fl = tiny_setup
    model = build(cfg)
    for method in ("fedavg", "cafl"):
        res = run_federated(model, fl, ds, method=method, log=None)
        assert len(res.history) == fl.rounds
        assert all(np.isfinite(r.val_loss) for r in res.history)
        s = res.summary(tail=2)
        assert s["comm_mb"] > 0 and s["energy"] > 0
        if method == "fedavg":
            k = res.history[0].knobs
            assert (k["k"], k["s"], k["b"], k["q"]) == (fl.k_base, fl.s_base,
                                                        fl.b_base, 0)


def test_training_actually_learns(tiny_setup):
    ds, cfg, fl = tiny_setup
    model = build(cfg)
    fl5 = fl.replace(rounds=5, s_base=8)
    res = run_federated(model, fl5, ds, method="fedavg", log=None)
    assert res.history[-1].val_loss < res.history[0].val_loss - 0.1, \
        "FedAvg should reduce val loss over 5 rounds"


def test_freezing_mask_structure(tiny_setup):
    ds, cfg, fl = tiny_setup
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    total = count_params(params)
    m_all = mask_tree(params, cfg, cfg.num_layers)
    assert count_active(params, m_all) == pytest.approx(total)
    m_1 = mask_tree(params, cfg, 1)
    act1 = count_active(params, m_1)
    assert 0 < act1 < total
    # frozen grads are exactly zero after masking
    fake_grads = jax.tree.map(jnp.ones_like, params)
    masked = apply_mask(fake_grads, m_1)
    n_zero = sum(int(np.sum(np.asarray(l) == 0)) for l in jax.tree.leaves(masked))
    assert n_zero == pytest.approx(total - act1)
    # monotone in k
    acts = [count_active(params, mask_tree(params, cfg, k))
            for k in range(1, cfg.num_layers + 1)]
    assert all(a <= b + 1e-6 for a, b in zip(acts, acts[1:]))


def test_compression_in_loop_reduces_wire(tiny_setup):
    ds, cfg, fl = tiny_setup
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # varying values: the mid-tread grid represents constant blocks
    # exactly, which would make every error below zero
    delta = jax.tree.map(
        lambda p: 0.01 * jnp.cos(jnp.arange(p.size, dtype=jnp.float32)
                                 ).reshape(p.shape).astype(p.dtype), params)
    mb0 = wire_mb(delta, 0)
    mb1 = wire_mb(delta, 1)
    mb2 = wire_mb(delta, 2)
    assert mb1 < mb0 / 3.5 and mb2 < mb0 / 12
    mb2s = wire_mb(delta, 2, topk=32)
    assert mb2s < mb2  # sparse wire format ships fewer bytes still
    err1 = compression_error(delta, 1)["rel_l2"]
    err2 = compression_error(delta, 2)["rel_l2"]
    errs = compression_error(delta, 2, topk=32)["rel_l2"]
    assert 0 < err1 < err2 < errs <= 1.0
    rt = compress_decompress(delta, 2)
    # structure preserved
    assert jax.tree.structure(rt) == jax.tree.structure(delta)


def test_wire_topk_threads_to_client_wire_path(tiny_setup):
    """fl.wire_topk threads through both client paths (sequential
    ClientRunner.train_client and the batched executor): at q>0 the
    sparse format ships fewer bytes and a sparser delta than dense,
    while q=0 ignores the knob (no quantized wire to sparsify)."""
    ds, cfg, fl = tiny_setup
    from repro.core.client import ClientRunner
    from repro.core.policy import Knobs
    from repro.core.resources import calibrate
    from repro.data.federated import FederatedData
    from repro.fl import ClientInfo, DeviceProfile, make_executor
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    resources = calibrate(count_params(params), fl)
    data = FederatedData(ds.train, fl.num_clients, seed=fl.seed)
    dense = ClientRunner(model, fl, data, resources)
    sparse = ClientRunner(model, fl.replace(wire_topk=32), data, resources)

    def nnz(result):
        return sum(int(np.sum(np.asarray(leaf) != 0))
                   for leaf in jax.tree.leaves(result.delta))

    kn = Knobs(k=cfg.num_layers, s=2, b=4, q=1, grad_accum=1)
    r_d = dense.train_client(0, params, kn)
    r_s = sparse.train_client(0, params, kn)
    assert 0 < r_s.wire_mb_actual < r_d.wire_mb_actual
    assert nnz(r_s) < nnz(r_d)
    assert np.isfinite(r_s.train_loss)
    # q=0 ships raw fp32 regardless of wire_topk
    kn0 = Knobs(k=cfg.num_layers, s=2, b=4, q=0, grad_accum=1)
    assert sparse.train_client(0, params, kn0).wire_mb_actual == \
        pytest.approx(dense.train_client(0, params, kn0).wire_mb_actual)
    # batched executor reads runner.fl.wire_topk too
    profile = DeviceProfile("default", fl.budgets, resources=resources)
    assignments = [(ClientInfo(0, profile, 1), kn)]
    b_s, = make_executor("batched", sparse).run_round(params, assignments)
    b_d, = make_executor("batched", dense).run_round(params, assignments)
    assert 0 < b_s.wire_mb_actual < b_d.wire_mb_actual
    assert b_s.wire_mb_actual == pytest.approx(r_s.wire_mb_actual, rel=1e-4)


def test_checkpoint_roundtrip(tiny_setup, tmp_path):
    ds, cfg, fl = tiny_setup
    from repro.checkpointing import load, save
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    path = str(tmp_path / "ckpt.msgpack")
    save(path, params)
    restored = load(path, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cafl_adapts_when_budget_tightened(tiny_setup):
    """With a tiny comm budget the policy must engage compression."""
    ds, cfg, fl = tiny_setup
    model = build(cfg)
    import dataclasses as dc
    tight = fl.replace(rounds=4,
                       budgets=dc.replace(fl.budgets, comm_mb=1e-4))
    res = run_federated(model, tight, ds, method="cafl", log=None)
    qs = [r.knobs["q"] for r in res.history]
    assert qs[-1] >= 1, f"compression never engaged: {qs}"
    assert res.history[-1].duals["comm"] > 0
