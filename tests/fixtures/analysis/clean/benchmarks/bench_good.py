"""Clean REPRO002 pattern: every emitted metric is declared."""
from repro.bench import MetricSpec, benchmark

_PRESETS = {"tiny": {}, "smoke": {}, "full": {}}


@benchmark("fixtures.good", "fixtures",
           metrics=[MetricSpec("time_us", "us", direction="lower"),
                    MetricSpec("speedup", "x", direction="higher")],
           presets=_PRESETS)
def bench_good(params):
    return {"time_us": 1.0, "speedup": 2.0,
            "context": {"note": "context is the non-metric channel"}}
