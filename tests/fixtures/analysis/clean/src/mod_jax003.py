"""Clean JAX003 patterns: definitions at import, execution in main()."""
import jax
import jax.numpy as jnp

softplus = jax.jit(lambda x: jnp.logaddexp(x, 0.0))  # defining-only: fine


def make_table():
    return jnp.arange(16)                 # inside a function: fine


def main():
    print(softplus(make_table()))


if __name__ == "__main__":
    key = jax.random.PRNGKey(0)           # main guard: fine
    main()
