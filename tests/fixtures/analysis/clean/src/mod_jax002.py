"""Clean JAX002 patterns: hashable scalars/tuples as static args."""
import functools

import jax


@functools.partial(jax.jit, static_argnums=(1,))
def scaled(x, factor):
    return x * factor


step = jax.jit(lambda x, mode: x, static_argnames=("mode",))


def run(x):
    y = scaled(x, 3)                      # int: hashable, fine
    return step(y, mode="fast")           # str: hashable, fine
