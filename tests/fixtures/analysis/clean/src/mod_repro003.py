"""Clean REPRO003 patterns: integer arithmetic, float at the edge."""


def wire_bytes(n_params, bits):
    return -(-n_params * bits // 8)       # exact ceil-div


def spend(rounds):
    token_budget = rounds * 3 // 2        # exact integers
    token_budget -= rounds
    return token_budget


def report_mb(nbytes):
    # reporting edge, not an accounting name: floats allowed here
    return nbytes / 1e6
