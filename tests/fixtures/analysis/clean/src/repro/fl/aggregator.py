"""Clean SCHED patterns: canonical-order folds, engine-threaded rng."""
import numpy as np


def combine(reports):
    stats = sorted(reports, key=lambda r: (r.round, r.client_id))
    total = 0.0
    for r in stats:                   # canonical order: schedule-free
        total += r.value
    return total, float(np.mean([r.value for r in stats]))


def jitter(rng, n):
    return rng.normal(size=n)         # rng threaded by the engine: fine
