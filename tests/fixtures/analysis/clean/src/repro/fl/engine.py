"""Clean JAX004 pattern: per-client work stays in stacked arrays."""
import jax.numpy as jnp


def aggregate_round(deltas_stacked, weights):
    return jnp.tensordot(weights, deltas_stacked, axes=1)


def label_rows(rows):
    out = []
    for row in rows:                      # not per-client state: fine
        out.append(str(row))
    return out
