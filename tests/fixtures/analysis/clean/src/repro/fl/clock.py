"""Clean SCHED patterns: sorted iteration, total-order event keys."""


def expire(busy_until, now):
    return sorted(c for c, due in busy_until.items() if due < now)


def drain(pending):
    ready = {p for p in pending}
    return list(sorted(ready))


def next_event(events):
    events.sort(key=lambda e: e.sort_key())
    return min(events, key=lambda e: (e.arrival, e.tie, e.seq))
