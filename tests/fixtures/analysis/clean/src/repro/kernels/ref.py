"""Pure-jnp twins for the clean fixture kernels."""


def paired_kernel_ref(x):
    return x
