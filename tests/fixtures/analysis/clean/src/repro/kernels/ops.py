"""Dispatch point for the clean fixture kernels."""
from repro.kernels.ref import paired_kernel_ref
from repro.kernels.wire import paired_kernel


def paired(x, use_pallas=False):
    return paired_kernel(x) if use_pallas else paired_kernel_ref(x)
