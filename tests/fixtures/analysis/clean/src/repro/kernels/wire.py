"""Clean REPRO001 pattern: kernel with twin, dispatch, and test."""


def paired_kernel(x):
    return x
