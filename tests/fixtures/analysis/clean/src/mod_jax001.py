"""Clean JAX001 patterns: split-before-use, carry, fold_in per step."""
import jax


def double_sample(key):
    k1, k2 = jax.random.split(key)
    return jax.random.normal(k1, (4,)) + jax.random.normal(k2, (4,))


def carry_loop(key, n):
    total = 0.0
    for _ in range(n):
        key, sub = jax.random.split(key)   # carry pattern: key rebinds
        total += jax.random.uniform(sub)
    return total


def fold_loop(key, n):
    total = 0.0
    for i in range(n):
        total += jax.random.uniform(jax.random.fold_in(key, i))
    return total
