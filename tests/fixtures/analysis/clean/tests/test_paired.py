"""Bit-equality pin for the clean fixture kernel pair."""
from repro.kernels.ops import paired
from repro.kernels.ref import paired_kernel_ref
from repro.kernels.wire import paired_kernel


def test_paired_kernel_matches_ref():
    assert paired_kernel(1.0) == paired_kernel_ref(1.0)
    assert paired(1.0, use_pallas=True) == paired(1.0, use_pallas=False)
