"""Seeded REPRO001 violation: a public kernel with no ref twin."""


def orphan_kernel(x):
    return x
