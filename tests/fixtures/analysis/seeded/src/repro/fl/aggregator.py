"""Seeded SCHED001/SCHED004 violations: delivery-order report folds
and component-owned RNG streams."""
import numpy as np

_RNG = np.random.default_rng(0)       # SCHED004: module-level shared rng


class JitterPolicy:
    def __init__(self):
        # SCHED004 twice: rng on component state, and unseeded
        self.rng = np.random.default_rng()

    def pick(self, reports):
        np.random.shuffle(reports)    # SCHED004: global singleton draw
        return reports[0]


def combine(reports):
    total = 0.0
    for r in reports:                 # SCHED001: += over delivery order
        total += r.value
    # SCHED001: fold over a comprehension iterating the buffer
    mean = np.mean([r.value for r in reports])
    return total, mean
