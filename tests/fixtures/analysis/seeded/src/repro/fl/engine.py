"""Seeded JAX004 violation: per-client Python loop in the engine."""


def aggregate_round(clients, deltas):
    total = None
    for client in clients:                # JAX004: per-client Python loop
        d = deltas[client]
        total = d if total is None else total + d
    return total
