"""Seeded SCHED002/SCHED003 violations: unordered-container iteration
and timestamp ordering without a tie-break."""


def expire(busy_until, now):
    # SCHED002: items() on a schedule-tracking dict, order = insertion
    return [c for c, due in busy_until.items() if due < now]


def drain(pending):
    ready = {p for p in pending}
    out = []
    for p in ready:                   # SCHED002: set iteration order
        out.append(p)
    return out


def next_event(events):
    events.sort(key=lambda e: e.arrival)    # SCHED003: bare timestamp
    return min(events, key=lambda e: e.t)   # SCHED003: ties possible
