"""Seeded JAX001 violations: PRNG keys consumed twice / after split."""
import jax


def double_sample(seed):
    k = jax.random.PRNGKey(seed)
    a = jax.random.normal(k, (4,))
    b = jax.random.normal(k, (4,))        # JAX001: key consumed twice
    return a + b


def use_after_split(key):
    k1, k2 = jax.random.split(key)
    noise = jax.random.normal(key, (2,))  # JAX001: parent used after split
    return noise + jax.random.normal(k1, (2,)) + jax.random.normal(k2, (2,))


def loop_reuse(seed, n):
    k = jax.random.PRNGKey(seed)
    total = 0.0
    for _ in range(n):
        total += jax.random.uniform(k)    # JAX001: same key every iteration
    return total
