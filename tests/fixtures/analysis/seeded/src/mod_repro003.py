"""Seeded REPRO003 violations: float arithmetic in exact accounting."""


def wire_bytes(n_params, bits):
    return n_params * bits / 8.0          # REPRO003: true division


def spend(rounds):
    token_budget = rounds * 0.5           # REPRO003: float constant
    token_budget += float(rounds)         # REPRO003: float() cast
    return token_budget
