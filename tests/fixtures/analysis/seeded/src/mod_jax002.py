"""Seeded JAX002 violations: unhashable values at static jit args."""
import functools

import jax


@functools.partial(jax.jit, static_argnums=(1,))
def scaled(x, cfg):
    return x * cfg[0]


step = jax.jit(lambda x, opts: x, static_argnames=("opts",))


def run(x):
    y = scaled(x, [1, 2, 3])              # JAX002: list at static position
    return step(y, opts={"lr": 0.1})      # JAX002: dict at static name
