"""Seeded JAX003 violations: device computation at import time."""
import jax
import jax.numpy as jnp

TABLE = jnp.arange(16)                    # JAX003: import-time device work
KEY = jax.random.PRNGKey(0)               # JAX003: import-time device work


def lookup(i):
    return TABLE[i]
