"""Seeded REPRO002 violations: undeclared metric + bad direction."""
from repro.bench import MetricSpec, benchmark

_PRESETS = {"tiny": {}, "smoke": {}, "full": {}}


@benchmark("seeded.bad", "fixtures",
           metrics=[MetricSpec("time_us", "us", direction="sideways")],
           presets=_PRESETS)
def bench_bad(params):
    return {"time_us": 1.0,
            "surprise_metric": 2.0}       # REPRO002: no MetricSpec
