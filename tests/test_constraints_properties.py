"""Hypothesis invariants every ``DualController`` must keep, over
arbitrary violation-ratio trajectories:

    dual feasibility        0 <= lambda <= lambda_max, always
    dead-band no-chatter    in-band ratios never move a resting dual,
                            and after any history the dual is
                            stationary under consecutive in-band steps
                            (at most one settling step)
    monotone pressure       sustained violation -> non-decreasing
                            lambda; sustained slack -> non-increasing

plus the bit-for-bit stream equivalence of ``DeadzoneSubgradient``
with the seed's ``dual_update`` under random usage streams.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import Budgets, DualConfig  # noqa: E402
from repro.constraints import (  # noqa: E402
    AdaptiveStep, DeadzoneSubgradient, PIController,
)
from repro.core.duals import RESOURCES, DualState, dual_update  # noqa: E402

CFG = DualConfig()          # eta=0.35, deadzone=0.05, lambda_max=10.0

CONTROLLERS = {
    "deadzone": DeadzoneSubgradient,
    "adaptive": AdaptiveStep,
    "pi": PIController,
}

ratio_seqs = st.lists(st.floats(min_value=0.0, max_value=8.0,
                                allow_nan=False), min_size=1, max_size=40)


def _trajectory(ctrl, ratios, cfg=CFG, key="k"):
    lam, out = 0.0, []
    for r in ratios:
        lam = ctrl.step(key, lam, r, cfg)
        out.append(lam)
    return out


@pytest.mark.parametrize("name", sorted(CONTROLLERS))
@given(ratios=ratio_seqs)
@settings(max_examples=60, deadline=None)
def test_controller_dual_feasibility_bounds(name, ratios):
    """0 <= lambda <= lambda_max along any ratio trajectory."""
    traj = _trajectory(CONTROLLERS[name](), ratios)
    assert all(0.0 <= lam <= CFG.lambda_max for lam in traj)


@pytest.mark.parametrize("name", sorted(CONTROLLERS))
@given(ratios=st.lists(st.floats(min_value=1.0 - CFG.deadzone,
                                 max_value=1.0 + CFG.deadzone),
                       min_size=1, max_size=20))
@settings(max_examples=40, deadline=None)
def test_controller_no_chatter_from_rest(name, ratios):
    """Inside the +-deadzone band a resting dual never moves."""
    traj = _trajectory(CONTROLLERS[name](), ratios)
    assert all(lam == 0.0 for lam in traj)


@pytest.mark.parametrize("name", sorted(CONTROLLERS))
@given(prefix=ratio_seqs,
       inband=st.floats(min_value=1.0 - CFG.deadzone,
                        max_value=1.0 + CFG.deadzone))
@settings(max_examples=40, deadline=None)
def test_controller_stationary_inside_band(name, prefix, inband):
    """After any history, consecutive in-band ratios leave lambda
    stationary (the dead-zone's no-chatter guarantee: at most one
    settling step, then no further movement)."""
    ctrl = CONTROLLERS[name]()
    lam = _trajectory(ctrl, prefix)[-1]
    settled = ctrl.step("k", lam, inband, CFG)
    for _ in range(3):
        nxt = ctrl.step("k", settled, inband, CFG)
        assert nxt == settled
        settled = nxt


@pytest.mark.parametrize("name", sorted(CONTROLLERS))
@given(ratio=st.floats(min_value=1.0 + CFG.deadzone + 1e-6, max_value=8.0),
       steps=st.integers(min_value=2, max_value=30))
@settings(max_examples=40, deadline=None)
def test_controller_monotone_under_sustained_violation(name, ratio, steps):
    """A persistently violated constraint builds non-decreasing
    pressure, and strictly positive pressure immediately."""
    traj = _trajectory(CONTROLLERS[name](), [ratio] * steps)
    assert traj[0] > 0.0
    assert all(b >= a for a, b in zip(traj, traj[1:]))


@pytest.mark.parametrize("name", sorted(CONTROLLERS))
@given(ratio=st.floats(min_value=0.0, max_value=1.0 - CFG.deadzone - 1e-6),
       steps=st.integers(min_value=2, max_value=30))
@settings(max_examples=40, deadline=None)
def test_controller_decays_under_sustained_slack(name, ratio, steps):
    """Sustained under-budget usage releases pressure monotonically
    down to (and never below) zero."""
    ctrl = CONTROLLERS[name]()
    lam = 0.0
    for _ in range(5):                            # build pressure first
        lam = ctrl.step("k", lam, 3.0, CFG)
    traj = []
    for _ in range(steps):
        lam = ctrl.step("k", lam, ratio, CFG)
        traj.append(lam)
    assert all(b <= a for a, b in zip(traj, traj[1:]))
    assert all(lam >= 0.0 for lam in traj)


@given(usages=st.lists(
    st.tuples(*[st.floats(min_value=0.0, max_value=10.0)] * 4),
    min_size=1, max_size=25))
@settings(max_examples=60, deadline=None)
def test_deadzone_controller_is_dual_update_bit_for_bit(usages):
    budgets = Budgets(energy=1.3, comm_mb=0.7, memory=0.9, temp=1.1)
    bmap = {"energy": 1.3, "comm": 0.7, "memory": 0.9, "temp": 1.1}
    ctrl = DeadzoneSubgradient()
    state = DualState()
    lam = {r: 0.0 for r in RESOURCES}
    for tup in usages:
        usage = dict(zip(RESOURCES, tup))
        state = dual_update(state, usage, budgets, CFG)
        lam = {r: ctrl.step(r, lam[r], usage[r] / bmap[r], CFG)
               for r in RESOURCES}
        assert lam == state.lam                  # exact float equality
