"""Fleet dynamics (repro.fl.dynamics): deterministic participation under
every sampler x availability x straggler combination, dropout weight
renormalization, token-budget carry-over, and engine integration
(CAFL-L with dropout keeps finite non-negative duals; the default
bundle reproduces the static-fleet loop exactly)."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config, get_fl_config
from repro.core import aggregation
from repro.core.policy import Knobs, fedavg_knobs
from repro.data import load_corpus
from repro.fl import (BernoulliChurn, ClientInfo,
                      DeadlineStragglers, DeviceProfile, FederatedEngine,
                      FleetDynamics, FullParticipation,
                      PeriodicAvailability, ResourceAwareSampler,
                      RoundCallback, RoundRobinSampler, UniformSampler,
                      make_dynamics)
from repro.models import build

SAMPLERS = ["full", "uniform", "round_robin", "resource_aware"]
AVAILABILITY = ["always", "periodic", "bernoulli"]
STRAGGLERS = ["none", "deadline"]


def _fleet(n=8, het=False):
    fl = get_fl_config()
    fast = DeviceProfile("fast", fl.budgets, compute_scale=0.5)
    slow = DeviceProfile("slow", fl.budgets.scaled(0.5), compute_scale=3.0,
                         availability=0.5)
    profiles = [fast if (not het or i % 2 == 0) else slow for i in range(n)]
    return [ClientInfo(i, profiles[i], shard_size=100 + i) for i in range(n)]


def _trace(dynamics, clients, seed, rounds=6, duals=None):
    """Run composition+deadline for several rounds; return the
    (sampled, dropped) id tuples per round."""
    rng = np.random.default_rng(seed)
    dynamics.reset()
    kn = Knobs(k=2, s=4, b=8, q=0)
    out = []
    for t in range(1, rounds + 1):
        _, sampled = dynamics.compose(t, clients, rng, duals or {})
        base = [kn] * len(sampled)
        knobs = dynamics.adjust_knobs(sampled, base)
        surv, drop, _ = dynamics.finish(t, sampled, knobs, rng)
        dynamics.settle(sampled, base, knobs, surv, drop)
        out.append((tuple(ci.client_id for ci in sampled),
                    tuple(sampled[i].client_id for i in drop)))
    return out


@pytest.mark.parametrize("sampler", SAMPLERS)
@pytest.mark.parametrize("availability", AVAILABILITY)
@pytest.mark.parametrize("stragglers", STRAGGLERS)
def test_same_seed_same_participation(sampler, availability, stragglers):
    """Every combination is deterministic given the seed."""
    fl = get_fl_config().replace(num_clients=8, clients_per_round=3)
    clients = _fleet(8, het=True)
    runs = [_trace(make_dynamics(fl, sampler, availability, stragglers,
                                 deadline=1.0, churn_p=0.7),
                   clients, seed=42) for _ in range(2)]
    assert runs[0] == runs[1]
    # and a different seed moves at least one stochastic combination
    if "bernoulli" == availability or sampler in ("uniform",
                                                  "resource_aware"):
        other = _trace(make_dynamics(fl, sampler, availability, stragglers,
                                     deadline=1.0, churn_p=0.7),
                       clients, seed=43)
        assert other != runs[0]


def test_uniform_sampler_matches_legacy_stream():
    """Default bundle consumes the generator exactly like the old
    engine's inline ``rng.choice(N, size=K, replace=False)``."""
    fl = get_fl_config().replace(num_clients=16, clients_per_round=6)
    clients = _fleet(16)
    rng_new = np.random.default_rng(fl.seed)
    rng_old = np.random.default_rng(fl.seed)
    dyn = FleetDynamics.default(fl)
    for t in range(1, 5):
        _, sampled = dyn.compose(t, clients, rng_new, {})
        legacy = rng_old.choice(fl.num_clients, size=fl.clients_per_round,
                                replace=False)
        assert [ci.client_id for ci in sampled] == [int(c) for c in legacy]


def test_round_robin_visits_everyone():
    clients = _fleet(6)
    dyn = FleetDynamics(sampler=RoundRobinSampler(2))
    trace = _trace(dyn, clients, seed=0, rounds=3)
    seen = [cid for sampled, _ in trace for cid in sampled]
    assert sorted(seen) == list(range(6))     # one full cycle, no repeats


def test_full_participation_takes_all_available():
    clients = _fleet(5)
    dyn = FleetDynamics(sampler=FullParticipation())
    (sampled, dropped), = _trace(dyn, clients, seed=0, rounds=1)
    assert sampled == tuple(range(5)) and dropped == ()


def test_periodic_availability_windows():
    av = PeriodicAvailability(period=4, on_rounds=2)
    clients = _fleet(8)
    rng = np.random.default_rng(0)
    for rnd in range(1, 9):
        got = {ci.client_id for ci in av.available(rnd, clients, rng)}
        want = {c for c in range(8) if (rnd + c) % 4 < 2}
        assert got == want
    # per-profile override: profile "fast" always on
    av2 = PeriodicAvailability(period=4, on_rounds=1,
                               per_profile={"fast": (1, 1)})
    got = {ci.client_id
           for ci in av2.available(3, _fleet(4, het=True), rng)}
    assert {0, 2} <= got                      # fast clients are 0 and 2


def test_bernoulli_churn_respects_profile_availability():
    clients = _fleet(8, het=True)             # odd ids: availability=0.5
    churn = BernoulliChurn(p=1.0)
    rng = np.random.default_rng(7)
    counts = {c: 0 for c in range(8)}
    for rnd in range(200):
        for ci in churn.available(rnd, clients, rng):
            counts[ci.client_id] += 1
    fast = np.mean([counts[c] for c in range(0, 8, 2)])
    slow = np.mean([counts[c] for c in range(1, 8, 2)])
    assert fast == 200                        # p=1.0 * availability 1.0
    assert 60 < slow < 140                    # ~100 of 200


def test_resource_aware_sampler_prefers_headroom():
    clients = _fleet(8, het=True)
    duals = {"fast": {"energy": 0.0, "comm": 0.0, "memory": 0.0,
                      "temp": 0.0},
             "slow": {"energy": 3.0, "comm": 1.0, "memory": 0.0,
                      "temp": 0.5}}
    s = ResourceAwareSampler(4, explore=0.0)
    rng = np.random.default_rng(0)
    picked = s.sample(1, clients, rng, duals)
    assert all(ci.profile.name == "fast" for ci in picked)
    # no duals yet -> uniform fallback still returns k clients
    assert len(s.sample(1, clients, np.random.default_rng(0), {})) == 4


def test_resource_aware_explore_avoids_starvation():
    """A pressed tier must keep getting sampled (its duals can only
    decay through participation); the explore slots guarantee it."""
    clients = _fleet(8, het=True)
    duals = {"fast": {"energy": 0.0, "comm": 0.0, "memory": 0.0,
                      "temp": 0.0},
             "slow": {"energy": 9.0, "comm": 9.0, "memory": 9.0,
                      "temp": 9.0}}
    s = ResourceAwareSampler(4)                  # default explore=0.25
    rng = np.random.default_rng(0)
    slow_picks = sum(
        sum(ci.profile.name == "slow" for ci in s.sample(t, clients, rng,
                                                         duals))
        for t in range(50))
    assert slow_picks > 0


def test_deadline_stragglers_drop_slow_silicon():
    fl = get_fl_config()
    model = DeadlineStragglers.for_config(fl, deadline=1.5, jitter=0.0)
    clients = _fleet(8, het=True)             # slow tier: compute_scale=3
    kn = fedavg_knobs(fl)                     # exactly 1.0 baseline units
    surv, drop, times = model.split(1, clients, [kn] * 8,
                                    np.random.default_rng(0))
    assert sorted(clients[i].client_id for i in surv) == [0, 2, 4, 6]
    assert sorted(clients[i].client_id for i in drop) == [1, 3, 5, 7]
    assert times[0] == pytest.approx(0.5) and times[1] == pytest.approx(3.0)


def test_dropout_renormalization_matches_survivor_mean():
    """Aggregating survivors with their shard weights equals the
    weighted mean renormalized over survivors only."""
    import jax.numpy as jnp
    deltas = [{"w": jnp.full(3, 1.0)}, {"w": jnp.full(3, 5.0)},
              {"w": jnp.full(3, 9.0)}]
    weights = [1.0, 3.0, 6.0]
    surv = [0, 2]                             # client 1 dropped
    agg = aggregation.aggregate([deltas[i] for i in surv],
                                [weights[i] for i in surv])
    want = (1.0 * 1.0 + 9.0 * 6.0) / (1.0 + 6.0)
    assert np.allclose(np.asarray(agg["w"]), want)


def test_token_debt_carries_to_next_participation():
    dyn = FleetDynamics(sampler=FullParticipation(), max_carry_accum=4)
    dyn.reset()
    clients = _fleet(2)
    kn = Knobs(k=2, s=4, b=8, q=0, grad_accum=1)
    base = [kn, kn]
    # round 1: client 1 drops -> owes s*ga*b = 32 sequences
    dyn.settle(clients, base, base, survivor_idx=[0], dropped_idx=[1])
    assert dyn.debt(1) == 32 and dyn.debt(0) == 0
    # round 2: the debtor's grad_accum is boosted by ceil(32/32)=1
    adj = dyn.adjust_knobs(clients, base)
    assert adj[0].grad_accum == 1 and adj[1].grad_accum == 2
    # dropping again adds only the BASE budget (no compounding)...
    dyn.settle(clients, base, adj, survivor_idx=[0], dropped_idx=[1])
    assert dyn.debt(1) == 64
    # ...and the boost stays capped
    adj = dyn.adjust_knobs(clients, base)
    assert adj[1].grad_accum == 1 + 2
    # surviving with an uncapped boost repays the full debt
    dyn.settle(clients, base, adj, survivor_idx=[0, 1], dropped_idx=[])
    assert dyn.debt(1) == 0


def test_capped_carry_boost_keeps_remainder_owed():
    """When max_carry_accum caps the boost, the unpaid remainder stays
    on the ledger instead of being silently forgiven."""
    dyn = FleetDynamics(sampler=FullParticipation(), max_carry_accum=2)
    dyn.reset()
    clients = _fleet(2)
    kn = Knobs(k=2, s=4, b=8, q=0, grad_accum=1)
    base = [kn, kn]
    heavy = [dataclasses.replace(kn, grad_accum=8)] * 2
    # client 1 drops a ga=8 round -> owes 4*8*8 = 256 sequences
    dyn.settle(clients, heavy, heavy, [0], [1])
    assert dyn.debt(1) == 256
    adj = dyn.adjust_knobs(clients, base)
    assert adj[1].grad_accum == 1 + 2            # capped below ceil(256/32)=8
    # surviving repays only the 2*32 = 64 boosted sequences
    dyn.settle(clients, base, adj, [0, 1], [])
    assert dyn.debt(1) == 256 - 64
    # successive participations drain the remainder to zero
    for _ in range(3):
        adj = dyn.adjust_knobs(clients, base)
        dyn.settle(clients, base, adj, [0, 1], [])
    assert dyn.debt(1) == 0


def test_carryover_disabled():
    dyn = FleetDynamics(sampler=FullParticipation(),
                        carryover_tokens=False)
    clients = _fleet(2)
    kn = Knobs(k=2, s=4, b=8, q=0)
    dyn.settle(clients, [kn, kn], [kn, kn], [0], [1])
    assert dyn.debt(1) == 0
    assert dyn.adjust_knobs(clients, [kn, kn])[1] == kn


def test_make_dynamics_unknown_component():
    fl = get_fl_config()
    with pytest.raises(ValueError):
        make_dynamics(fl, sampler="psychic")
    with pytest.raises(ValueError):
        make_dynamics(fl, availability="sometimes")
    with pytest.raises(ValueError):
        make_dynamics(fl, stragglers="quantum")


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_setup():
    ds = load_corpus(target_bytes=60_000)
    cfg = get_config("charlm-shakespeare").replace(
        vocab_size=max(ds.vocab_size, 64), num_layers=3, d_model=48,
        num_heads=4, num_kv_heads=4, head_dim=12, d_ff=96)
    fl = get_fl_config().replace(
        rounds=3, num_clients=6, clients_per_round=3, s_base=3, b_base=8,
        seq_len=16, eval_batches=1, eval_batch_size=8)
    fl = fl.replace(duals=dataclasses.replace(fl.duals, s_min=2, b_min=4))
    return ds, cfg, fl


@pytest.fixture(scope="module")
def tiny_model(tiny_setup):
    _, cfg, _ = tiny_setup
    return build(cfg)


def test_default_dynamics_reproduces_static_fleet(tiny_setup, tiny_model):
    """dynamics=None and an explicit default bundle yield identical
    histories (same sampling stream, same losses, same knobs)."""
    ds, cfg, fl = tiny_setup
    fl2 = fl.replace(rounds=2)
    res_a = FederatedEngine(tiny_model, fl2, ds, strategy="cafl").run()
    res_b = FederatedEngine(tiny_model, fl2, ds, strategy="cafl",
                            dynamics=FleetDynamics.default(fl2)).run()
    for ra, rb in zip(res_a.history, res_b.history):
        assert ra.participants == rb.participants and ra.dropped == []
        assert ra.knobs == rb.knobs and ra.duals == rb.duals
        assert ra.val_loss == pytest.approx(rb.val_loss, abs=1e-6)
        assert ra.train_loss == pytest.approx(rb.train_loss, abs=1e-6)


def test_cafl_with_dropout_keeps_finite_duals(tiny_setup, tiny_model):
    """Smoke: churn + deadline stragglers under CAFL-L — duals stay
    finite and non-negative, records report participation faithfully."""
    ds, cfg, fl = tiny_setup
    dyn = FleetDynamics(
        sampler=UniformSampler(fl.clients_per_round),
        availability=BernoulliChurn(0.8),
        stragglers=DeadlineStragglers.for_config(fl, deadline=1.2,
                                                 jitter=0.6))
    plans = []

    class PlanCatcher(RoundCallback):
        def on_round_composed(self, engine, plan):
            plans.append(plan)

    res = FederatedEngine(tiny_model, fl, ds, strategy="cafl", dynamics=dyn,
                          callbacks=[PlanCatcher()]).run()
    assert len(plans) == fl.rounds
    saw_drop = False
    for r, plan in zip(res.history, plans):
        assert plan.round == r.round
        assert set(r.participants) | set(r.dropped) == set(plan.sampled)
        assert set(r.participants).isdisjoint(r.dropped)
        assert r.num_available == len(plan.available)
        assert set(plan.sampled) <= set(plan.available)
        saw_drop |= bool(r.dropped)
        assert np.isfinite(r.val_loss)
        for lam in r.duals.values():
            assert np.isfinite(lam) and lam >= 0.0
    assert saw_drop, "deadline=1.2 with jitter should drop someone"


def test_zero_survivor_round_is_safe(tiny_setup, tiny_model):
    """A round where every sampled client misses the deadline leaves the
    params untouched and the record well-formed."""
    ds, cfg, fl = tiny_setup
    fl1 = fl.replace(rounds=1)
    dyn = FleetDynamics(sampler=UniformSampler(fl1.clients_per_round),
                        stragglers=DeadlineStragglers(deadline=0.0,
                                                      jitter=0.0))
    lines = []
    from repro.fl import LoggingCallback
    res = FederatedEngine(tiny_model, fl1, ds, strategy="cafl", dynamics=dyn,
                          callbacks=[LoggingCallback(lines.append)]).run()
    r = res.history[0]
    assert r.participants == [] and len(r.dropped) == fl1.clients_per_round
    assert r.train_loss == 0.0 and all(v == 0.0 for v in r.usage.values())
    assert all(lam == 0.0 for lam in r.duals.values())   # no update fired
    assert np.isfinite(r.val_loss)
    assert len(lines) == 1 and "drop=3" in lines[0]


def test_no_clients_reachable_round(tiny_setup, tiny_model):
    ds, cfg, fl = tiny_setup
    fl1 = fl.replace(rounds=1)
    dyn = FleetDynamics(sampler=UniformSampler(fl1.clients_per_round),
                        availability=BernoulliChurn(0.0))
    lines = []
    from repro.fl import LoggingCallback
    res = FederatedEngine(tiny_model, fl1, ds, strategy="fedavg",
                          dynamics=dyn,
                          callbacks=[LoggingCallback(lines.append)]).run()
    r = res.history[0]
    assert r.knobs == {} and r.num_available == 0 and r.participants == []
    assert "no clients reachable" in lines[0]


@pytest.mark.parametrize("alpha", [0.05, 0.1, 1.0])
def test_extreme_dirichlet_shards_nonempty(tiny_setup, alpha):
    """Extreme Dirichlet draws used to truncate some shard to zero
    length; the partition guard must keep every client's shard
    non-empty (so its batch stream can always index it)."""
    from repro.data.federated import FederatedData
    ds, cfg, fl = tiny_setup
    for seed in range(10):
        data = FederatedData(ds.train, num_clients=16, seed=seed,
                             noniid_alpha=alpha)
        sizes = [data.shard_size(i) for i in range(16)]
        assert min(sizes) >= 1, f"empty shard at seed={seed}"
        assert sum(sizes) == len(ds.train)


def test_batch_stream_isolation_under_sampling(tiny_setup):
    """A client's batch sequence depends only on its own draw count —
    not on which other clients were sampled around it."""
    from repro.data.federated import FederatedData
    ds, cfg, fl = tiny_setup
    a = FederatedData(ds.train, fl.num_clients, seed=fl.seed)
    b = FederatedData(ds.train, fl.num_clients, seed=fl.seed)
    # interleave other clients' draws in one copy only
    for other in (1, 2, 5):
        b.batch(other, 4, 8)
    for _ in range(3):
        ba = a.batch(3, 4, 8)
        bb = b.batch(3, 4, 8)
        for key in ba:
            np.testing.assert_array_equal(ba[key], bb[key])
