"""Render markdown tables for EXPERIMENTS.md from results/ and the
committed BENCH_<area>.json perf baselines (typed ``repro.bench``
records — the bench section never scrapes CSV text).

    PYTHONPATH=src python -m benchmarks.gen_report \
        [--section dryrun|roofline|paper|bench]
"""
from __future__ import annotations

import argparse
import glob
import os

from benchmarks.common import load_dryrun, load_fl
from benchmarks.run import REPO_ROOT

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["paligemma-3b", "recurrentgemma-2b", "minitron-8b", "gemma2-9b",
              "xlstm-1.3b", "phi3.5-moe-42b-a6.6b", "qwen2-72b",
              "mistral-large-123b", "deepseek-v3-671b", "seamless-m4t-medium"]


def fmt_e(x):
    return f"{x:.2e}" if x is not None else "—"


def dryrun_table() -> str:
    recs = load_dryrun()
    lines = ["| arch | shape | mesh | status | lower(s) | compile(s) | "
             "mem/dev(GB) | fits 16GB | HLO bytes |",
             "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("singlepod", "multipod"):
                key = f"{arch}__{shape}__{mesh}"
                r = recs.get(key)
                if r is None:
                    lines.append(f"| {arch} | {shape} | {mesh} | PENDING | | | | | |")
                    continue
                if r.get("status") != "ok":
                    err = r.get("error", "").splitlines()[-1][:60] if r.get("error") else "?"
                    lines.append(f"| {arch} | {shape} | {mesh} | ERROR {err} | | | | | |")
                    continue
                m = r.get("memory", {})
                lines.append(
                    f"| {arch} | {shape} | {mesh} | ok | {r.get('lower_s', 0):.1f} "
                    f"| {r.get('compile_s', 0):.1f} | "
                    f"{m.get('per_device_total_gb', 0):.2f} | "
                    f"{'yes' if m.get('fits_v5e_16gb') else 'NO'} | "
                    f"{r.get('hlo_bytes', 0)//1000}k |")
    return "\n".join(lines)


def roofline_table(mesh: str = "singlepod") -> str:
    recs = load_dryrun()
    lines = ["| arch | shape | t_compute(s) | t_memory(s) | t_collective(s) | "
             "dominant | MODEL/HLO flops | coll GB/dev | note |",
             "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get(f"{arch}__{shape}__{mesh}")
            if not r or r.get("status") != "ok":
                continue
            rl = r["roofline"]
            ufr = rl.get("useful_flops_ratio")
            ufr_s = f"{ufr:.2f}" if ufr else "—"
            note = ""
            if r["cost"].get("dot_misses"):
                note += f"dot_misses={r['cost']['dot_misses']} "
            if r["cost"].get("unknown_trip_counts"):
                note += f"unk_trips={r['cost']['unknown_trip_counts']}"
            lines.append(
                f"| {arch} | {shape} | {fmt_e(rl['t_compute_s'])} | "
                f"{fmt_e(rl['t_memory_s'])} | {fmt_e(rl['t_collective_s'])} | "
                f"**{rl['dominant']}** | {ufr_s} | "
                f"{rl['collective_bytes_per_device']/1e9:.1f} | {note} |")
    return "\n".join(lines)


def paper_table() -> str:
    fa, ca = load_fl("fedavg"), load_fl("cafl")
    if not fa or not ca:
        return "(FL results pending)"
    from benchmarks.table1 import PAPER
    lines = ["| metric | budget | FedAvg (ours) | FedAvg (paper) | "
             "CAFL-L (ours) | CAFL-L (paper) |", "|---|---|---|---|---|---|"]
    keymap = {"energy": "Energy", "comm_mb": "Comm (MB)", "temp": "Temp",
              "memory": "Memory", "val_loss": "Val. loss"}
    for k, label in keymap.items():
        budget = PAPER["budget"].get(k, "—")
        lines.append(
            f"| {label} | {budget} | {fa['summary'][k]:.4g} | "
            f"{PAPER['fedavg'][k]:.4g} | {ca['summary'][k]:.4g} | "
            f"{PAPER['cafl'][k]:.4g} |")
    fs, cs = fa["summary"], ca["summary"]
    lines.append("")
    lines.append(f"Improvements vs FedAvg (ours / paper): "
                 f"energy {100*(1-cs['energy']/fs['energy']):.0f}%/70% · "
                 f"comm {100*(1-cs['comm_mb']/fs['comm_mb']):.0f}%/95% · "
                 f"memory {100*(1-cs['memory']/fs['memory']):.0f}%/23% · "
                 f"val-loss +{100*(cs['val_loss']/fs['val_loss']-1):.0f}%/+9%")
    return "\n".join(lines)


def bench_table(baseline_dir: str = REPO_ROOT) -> str:
    """Perf-trajectory table from the committed BENCH_<area>.json
    snapshots (typed records, not CSV)."""
    from repro.bench import Snapshot

    paths = sorted(glob.glob(os.path.join(baseline_dir, "BENCH_*.json")))
    if not paths:
        return "(no BENCH_*.json baselines — run " \
               "`python -m benchmarks.run --record`)"
    blocks = []
    for path in paths:
        snap = Snapshot.load(path)
        fp = snap.fingerprint
        lines = [f"**{snap.area}** @{snap.scale} — jax {fp.jax_version} / "
                 f"{fp.backend} ({fp.device_kind}, {fp.cpu_count} cpu)",
                 "",
                 "| benchmark | metric | value | direction | noise band | n |",
                 "|---|---|---|---|---|---|"]
        for rec in snap.records:
            for m in rec.metrics:
                band = f"rtol={m.rtol:g}" + (f", atol={m.atol:g}"
                                             if m.atol else "")
                lines.append(
                    f"| {rec.benchmark} | {m.name} | {m.value:.4g} {m.unit} "
                    f"| {m.direction} is better | {band} | {m.n} |")
            if rec.context:
                ctx = ", ".join(f"{k}={v}" for k, v in rec.context.items())
                lines.append(f"| {rec.benchmark} | *(context)* | {ctx} "
                             f"| | | |")
        blocks.append("\n".join(lines))
    return "\n\n".join(blocks)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all")
    args = ap.parse_args()
    if args.section in ("dryrun", "all"):
        print("### Dry-run matrix\n")
        print(dryrun_table())
    if args.section in ("roofline", "all"):
        print("\n### Roofline (single-pod)\n")
        print(roofline_table())
    if args.section in ("paper", "all"):
        print("\n### Paper Table 1\n")
        print(paper_table())
    if args.section in ("bench", "all"):
        print("\n### Perf trajectory (committed baselines)\n")
        print(bench_table())


if __name__ == "__main__":
    main()
