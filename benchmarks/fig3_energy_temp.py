"""Paper Fig. 3: energy & temperature control (CAFL-L stays near budget,
avoiding energy/thermal runaway)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, load_fl


def rows():
    out = []
    for method in ("fedavg", "cafl"):
        data = load_fl(method)
        if not data:
            return [("fig3.missing_results", 0.0, "run repro.launch.train")]
        hist = data["history"]
        e = [r["ratios"]["energy"] for r in hist]
        t = [r["ratios"]["temp"] for r in hist]
        out.append((f"fig3.{method}.energy_ratio_tail", 0.0,
                    f"{np.mean(e[-10:]):.2f}x"))
        out.append((f"fig3.{method}.temp_ratio_tail", 0.0,
                    f"{np.mean(t[-10:]):.2f}x"))
        step = max(1, len(hist) // 12)
        out.append((f"fig3.{method}.energy_trace", 0.0,
                    " ".join(f"{r['round']}:{r['ratios']['energy']:.2f}"
                             for r in hist[::step])))
        out.append((f"fig3.{method}.temp_trace", 0.0,
                    " ".join(f"{r['round']}:{r['ratios']['temp']:.2f}"
                             for r in hist[::step])))
        # beyond-paper honesty metric: energy proxy including grad-accum
        out.append((f"fig3.{method}.energy_true_tail", 0.0,
                    f"{np.mean([r['energy_true'] for r in hist[-10:]]):.3g}"))
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
