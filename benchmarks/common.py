"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import os
import time

RESULTS = os.environ.get("RESULTS_DIR", "results")


def load_fl(method: str):
    # prefer the extended (warm-start continued) run when present
    for suffix in ("_ext", ""):
        path = os.path.join(RESULTS, f"fl_{method}{suffix}.json")
        if os.path.exists(path):
            with open(path) as f:
                return json.load(f)
    return None


def load_dryrun():
    out = {}
    d = os.path.join(RESULTS, "dryrun")
    if not os.path.isdir(d):
        return out
    for fn in sorted(os.listdir(d)):
        if fn.endswith(".json"):
            with open(os.path.join(d, fn)) as f:
                rec = json.load(f)
            out[fn[:-5]] = rec
    return out


def timeit(fn, *args, n_warmup: int = 2, n_iter: int = 10) -> float:
    """Median wall time per call in microseconds."""
    import jax
    for _ in range(n_warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(n_iter):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
