"""Shared helpers for the benchmark harness.

All CSV output — `python -m benchmarks.run` aggregate runs and each
module's standalone ``main()`` alike — goes through ``emit`` /
``emit_snapshot`` here, so the two invocation paths print identical
rows (one ``name,us_per_call,derived`` header per process).
"""
from __future__ import annotations

import json
import os

RESULTS = os.environ.get("RESULTS_DIR", "results")

CSV_HEADER = "name,us_per_call,derived"
_header_emitted = False


def load_fl(method: str):
    # prefer the extended (warm-start continued) run when present
    for suffix in ("_ext", ""):
        path = os.path.join(RESULTS, f"fl_{method}{suffix}.json")
        if os.path.exists(path):
            with open(path) as f:
                return json.load(f)
    return None


def load_dryrun():
    out = {}
    d = os.path.join(RESULTS, "dryrun")
    if not os.path.isdir(d):
        return out
    for fn in sorted(os.listdir(d)):
        if fn.endswith(".json"):
            with open(os.path.join(d, fn)) as f:
                rec = json.load(f)
            out[fn[:-5]] = rec
    return out


def timeit(fn, *args, n_warmup: int = 2, n_iter: int = 10) -> float:
    """Median wall time per call in microseconds (legacy shim over
    ``repro.bench.time_callable``)."""
    from repro.bench import time_callable
    return time_callable(fn, *args, warmup=n_warmup,
                         repeats=n_iter).median_us


def emit(rows):
    """Print legacy-format CSV rows, emitting the header exactly once
    per process regardless of how many modules emit."""
    global _header_emitted
    if not _header_emitted:
        print(CSV_HEADER)
        _header_emitted = True
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def snapshot_rows(snap):
    """Flatten a ``repro.bench`` snapshot into legacy CSV rows: one row
    per metric (timed ``us`` metrics fill the ``us_per_call`` column),
    plus one row per context string so the old derived info stays
    greppable."""
    rows = []
    for rec in snap.records:
        for m in rec.metrics:
            us = m.value if m.unit == "us" else 0.0
            derived = (f"n={m.n}" if m.unit == "us"
                       else f"{m.value:.4g}{m.unit}")
            rows.append((f"{rec.benchmark}.{m.name}", us, derived))
        for key, val in rec.context.items():
            rows.append((f"{rec.benchmark}.{key}", 0.0, val))
    return rows


def emit_snapshot(snap):
    emit(snapshot_rows(snap))


def run_area_cli(area: str, argv=None):
    """Standalone-module entry: run one registry area at ``--scale``
    and return the snapshot (optionally writing it with ``--out``)."""
    import argparse
    import sys

    from repro.bench import run_area

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="full",
                    choices=("tiny", "smoke", "full"))
    ap.add_argument("--out", default=None,
                    help="also write the snapshot JSON here")
    args = ap.parse_args(argv)
    snap = run_area(area, scale=args.scale,
                    log=lambda m: print(m, file=sys.stderr))
    if args.out:
        snap.save(args.out)
    return snap
