"""Paper Table 1: quantitative comparison averaged over final rounds.

Reads results/fl_{fedavg,cafl}.json (produced by repro.launch.train) and
prints the reproduction next to the paper's numbers.
"""
from __future__ import annotations

from benchmarks.common import emit, load_fl

PAPER = {
    "budget": {"energy": 1.20e6, "comm_mb": 0.60, "temp": 1.00, "memory": 0.26},
    "fedavg": {"energy": 4.52e6, "comm_mb": 5.18, "temp": 0.62, "memory": 0.31,
               "val_loss": 1.93},
    "cafl": {"energy": 1.35e6, "comm_mb": 0.28, "temp": 0.57, "memory": 0.24,
             "val_loss": 2.10},
}


def rows():
    out = []
    fa = load_fl("fedavg")
    ca = load_fl("cafl")
    if not fa or not ca:
        return [("table1.missing_results", 0.0, "run repro.launch.train first")]
    for method, data in (("fedavg", fa), ("cafl", ca)):
        s = data["summary"]
        for key in ("energy", "comm_mb", "memory", "temp", "val_loss"):
            paper_v = PAPER[method][key if key != "comm_mb" else "comm_mb"]
            ours = s[key]
            out.append((f"table1.{method}.{key}", 0.0,
                        f"ours={ours:.4g} paper={paper_v:.4g}"))
    # headline improvements (paper: 70% energy, 95% comm, 23% memory, +9% loss)
    fs, cs = fa["summary"], ca["summary"]
    out.append(("table1.improvement.energy_pct", 0.0,
                f"{100*(1-cs['energy']/fs['energy']):.1f}% (paper 70%)"))
    out.append(("table1.improvement.comm_pct", 0.0,
                f"{100*(1-cs['comm_mb']/fs['comm_mb']):.1f}% (paper 95%)"))
    out.append(("table1.improvement.memory_pct", 0.0,
                f"{100*(1-cs['memory']/fs['memory']):.1f}% (paper 23%)"))
    out.append(("table1.improvement.temp_pct", 0.0,
                f"{100*(1-cs['temp']/fs['temp']):.1f}% (paper 8%)"))
    out.append(("table1.val_loss_gap_pct", 0.0,
                f"+{100*(cs['val_loss']/fs['val_loss']-1):.1f}% (paper +9%)"))
    out.append(("table1.actual_wire_mb.cafl", 0.0,
                f"{cs['wire_mb_actual']:.3f} (measured bytes incl. scales)"))
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
