"""Timed microbenchmarks (CPU wall-clock): quantization round-trip,
blockwise attention, charlm train step, FL LocalTrain round. These are the
only true `us_per_call` rows — the table/figure benchmarks are analyses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit


def rows():
    out = []
    rng = np.random.default_rng(0)

    # quantization round-trip (the CAFL-L wire hot spot), ref path on CPU
    from repro.kernels import ops
    x = jnp.asarray(rng.normal(size=(1 << 20,)).astype(np.float32))
    for bits in (8, 2):
        f = jax.jit(lambda v, b=bits: ops.quantize_dequantize(v, bits=b))
        us = timeit(f, x)
        gbps = x.size * 4 / (us / 1e6) / 1e9
        out.append((f"kernel.quantize_roundtrip.{bits}bit.1M", us,
                    f"{gbps:.2f}GB/s"))

    # blockwise attention (the model hot path the Pallas kernel mirrors)
    from repro.models.layers import blockwise_attention
    q = jnp.asarray(rng.normal(size=(1, 1024, 8, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1024, 8, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 1024, 8, 64)).astype(np.float32))
    f = jax.jit(lambda a, b, c: blockwise_attention(
        a, b, c, window=None, softcap=None, q_chunk=256))
    us = timeit(f, q, k, v, n_iter=5)
    flops = 2 * 2 * 1024 * 1024 // 2 * 8 * 64  # ~causal qk+pv
    out.append(("kernel.blockwise_attention.1k", us,
                f"{flops/(us/1e6)/1e9:.1f}GFLOP/s"))

    # charlm train step (paper model)
    from repro.configs import get_config
    from repro.models import build
    cfg = get_config("charlm-shakespeare")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((32, 32), jnp.int32),
             "targets": jnp.zeros((32, 32), jnp.int32)}
    gf = jax.jit(lambda p, b: jax.value_and_grad(
        model.train_loss, has_aux=True)(p, b)[0][0])
    us = timeit(gf, params, batch, n_iter=5)
    out.append(("charlm.grad_step.b32s32", us,
                f"{32*32/(us/1e6):.0f}tok/s"))
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
