"""Timed kernel microbenchmarks (CPU wall-clock): quantization
round-trip, blockwise attention, charlm train step — registered on the
``repro.bench`` harness (area ``kernels``) so their timings and derived
throughputs are typed, snapshotted to ``BENCH_kernels.json``, and
ratcheted by ``python -m benchmarks.run --check``.

    PYTHONPATH=src:. python benchmarks/kernel_bench.py [--scale smoke|full|tiny]

Wall-clock metrics carry generous noise bands (they move across
machines — the snapshot's fingerprint says where the baseline was
measured); the derived GB/s / GFLOP/s / tok/s throughputs are their
inverses and ratchet with matching bands.
"""
from __future__ import annotations

from repro.bench import MetricSpec, benchmark, time_callable

AREA = "kernels"

# Wall-clock noise bands: a timed metric may run up to 2x slower
# (rtol=1.0) before the ratchet fails it; throughput, its inverse, may
# halve (rtol=0.5 against the higher-is-better direction).
_US = dict(unit="us", direction="lower", rtol=1.0)
_THROUGHPUT = dict(direction="higher", rtol=0.5)


@benchmark(
    "kernel.quantize_roundtrip", AREA,
    metrics=[MetricSpec("roundtrip_8bit_us", **_US),
             MetricSpec("bandwidth_8bit_gb_s", unit="GB/s", **_THROUGHPUT),
             MetricSpec("roundtrip_2bit_us", **_US),
             MetricSpec("bandwidth_2bit_gb_s", unit="GB/s", **_THROUGHPUT)],
    presets={"full": {"size": 1 << 20, "repeats": 10},
             "smoke": {"size": 1 << 18, "repeats": 5},
             "tiny": {"size": 1 << 14, "repeats": 3}},
    description="quantize->dequantize round-trip, the CAFL-L wire hot spot "
                "(ref path on CPU)")
def quantize_roundtrip(params):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(params["size"],)).astype(np.float32))
    out = {"context": {"elements": params["size"]}}
    for bits in (8, 2):
        f = jax.jit(lambda v, b=bits: ops.quantize_dequantize(v, bits=b))
        stats = time_callable(f, x, repeats=params["repeats"])
        out[f"roundtrip_{bits}bit_us"] = stats
        out[f"bandwidth_{bits}bit_gb_s"] = (
            x.size * 4 / (stats.median_us / 1e6) / 1e9)
    return out


@benchmark(
    "kernel.blockwise_attention", AREA,
    metrics=[MetricSpec("forward_us", **_US),
             MetricSpec("gflop_s", unit="GFLOP/s", **_THROUGHPUT)],
    presets={"full": {"seq": 1024, "q_chunk": 256, "repeats": 5},
             "smoke": {"seq": 512, "q_chunk": 128, "repeats": 5},
             "tiny": {"seq": 128, "q_chunk": 64, "repeats": 2}},
    description="blockwise attention forward, the model hot path the "
                "Pallas kernel mirrors")
def blockwise_attention_bench(params):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models.layers import blockwise_attention

    seq, heads, head_dim = params["seq"], 8, 64
    rng = np.random.default_rng(0)
    q, k, v = (jnp.asarray(rng.normal(size=(1, seq, heads, head_dim))
                           .astype(np.float32)) for _ in range(3))
    f = jax.jit(lambda a, b, c: blockwise_attention(
        a, b, c, window=None, softcap=None, q_chunk=params["q_chunk"]))
    stats = time_callable(f, q, k, v, repeats=params["repeats"])
    flops = 2 * 2 * seq * seq // 2 * heads * head_dim  # ~causal qk+pv
    return {"forward_us": stats,
            "gflop_s": flops / (stats.median_us / 1e6) / 1e9,
            "context": {"shape": f"1x{seq}x{heads}x{head_dim}"}}


@benchmark(
    "charlm.grad_step", AREA,
    metrics=[MetricSpec("grad_step_us", **_US),
             MetricSpec("tokens_per_s", unit="tok/s", **_THROUGHPUT)],
    presets={"full": {"batch": 32, "seq": 32, "repeats": 5},
             "smoke": {"batch": 16, "seq": 32, "repeats": 5},
             "tiny": {"batch": 4, "seq": 16, "repeats": 2}},
    description="value_and_grad step of the paper's char-LM")
def charlm_grad_step(params):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import build

    cfg = get_config("charlm-shakespeare")
    model = build(cfg)
    p = model.init(jax.random.PRNGKey(0))
    b, s = params["batch"], params["seq"]
    batch = {"tokens": jnp.zeros((b, s), jnp.int32),
             "targets": jnp.zeros((b, s), jnp.int32)}
    gf = jax.jit(lambda pp, bb: jax.value_and_grad(
        model.train_loss, has_aux=True)(pp, bb)[0][0])
    stats = time_callable(gf, p, batch, repeats=params["repeats"])
    return {"grad_step_us": stats,
            "tokens_per_s": b * s / (stats.median_us / 1e6),
            "context": {"batch": f"b{b}s{s}"}}


def main(argv=None):
    from benchmarks.common import emit_snapshot, run_area_cli
    emit_snapshot(run_area_cli(AREA, argv))


if __name__ == "__main__":
    main()
