"""Roofline table (assignment §Roofline): three terms per
(arch x input-shape x mesh) from the compiled dry-run, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs ratio, and per-device memory."""
from __future__ import annotations

from benchmarks.common import emit, load_dryrun


def rows():
    recs = load_dryrun()
    if not recs:
        return [("roofline.missing_results", 0.0,
                 "run python -m repro.launch.dryrun --all --mesh both")]
    out = []
    n_ok = n_err = 0
    for key, rec in recs.items():
        if rec.get("status") != "ok":
            n_err += 1
            out.append((f"roofline.{key}", 0.0, "ERROR"))
            continue
        n_ok += 1
        r = rec["roofline"]
        m = rec.get("memory", {})
        ufr = r.get("useful_flops_ratio")
        out.append((
            f"roofline.{key}", 0.0,
            f"tc={r['t_compute_s']:.3e}s tm={r['t_memory_s']:.3e}s "
            f"tcoll={r['t_collective_s']:.3e}s dom={r['dominant']} "
            f"useful={ufr:.2f} " if ufr else
            f"tc={r['t_compute_s']:.3e}s tm={r['t_memory_s']:.3e}s "
            f"tcoll={r['t_collective_s']:.3e}s dom={r['dominant']} "))
        out[-1] = (out[-1][0], 0.0, out[-1][2] +
                   f"mem/dev={m.get('per_device_total_gb', 0):.2f}GB")
    out.append(("roofline.summary", 0.0, f"{n_ok} ok, {n_err} errors"))
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
