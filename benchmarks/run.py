"""Benchmark entry point — a thin shim over the ``repro.bench``
harness plus the analysis modules (paper tables/figures, roofline).

    PYTHONPATH=src python -m benchmarks.run [--only table1,fl_engine,...]
                                            [--scale tiny|smoke|full]
                                            [--record] [--check]

Modes:

- default: run the selected analysis modules and registry areas, print
  ``name,us_per_call,derived`` CSV (one emitter, shared with each
  module's standalone ``main()``).
- ``--record``: run the registry areas and (re)write the committed
  ``BENCH_<area>.json`` baselines.
- ``--check``: run the registry areas, diff against the committed
  baselines (direction-aware, per-metric noise tolerance), write the
  fresh snapshots to ``--out`` for artifact upload, and exit non-zero
  on any regression — the CI ratchet.

``--only`` names that match no analysis module, registry area, or
benchmark are an error (exit 2), not a silent no-op.
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback

from benchmarks import common

#: rows()-protocol modules: analyses over results/, not timed registry
#: benchmarks (they stay outside the ratchet).
ANALYSIS_MODULES = ["table1", "fig2_constraints", "fig3_energy_temp",
                    "fig4_convergence", "roofline"]

#: registry-bearing modules; importing them populates ``repro.bench``.
REGISTRY_MODULES = ["kernel_bench", "fl_engine_bench", "wire_bench"]

#: old ``--only`` spellings for the ported modules keep working.
LEGACY_ALIASES = {"kernel_bench": "kernels", "fl_engine_bench": "fl_engine",
                  "wire_bench": "wire"}

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load_registry():
    for name in REGISTRY_MODULES:
        __import__(f"benchmarks.{name}")


def select(only):
    """Resolve ``--only`` prefixes to (analysis modules, registry
    areas). Raises SystemExit(2) on a prefix matching nothing."""
    from repro.bench import all_benchmarks, areas

    if not only:
        return list(ANALYSIS_MODULES), areas()
    mods, sel_areas = [], []
    bench_area = {b.name: b.area for b in all_benchmarks()}
    for prefix in only.split(","):
        prefix = LEGACY_ALIASES.get(prefix, prefix)
        hit = False
        for m in ANALYSIS_MODULES:
            if m.startswith(prefix) and m not in mods:
                mods.append(m)
                hit = True
        for a in areas():
            if a.startswith(prefix) and a not in sel_areas:
                sel_areas.append(a)
                hit = True
        for bname, barea in bench_area.items():
            if bname.startswith(prefix) and barea not in sel_areas:
                sel_areas.append(barea)
                hit = True
        if not hit:
            known = ANALYSIS_MODULES + areas() + sorted(bench_area)
            raise SystemExit(
                f"--only {prefix!r} matches no analysis module, benchmark "
                f"area, or benchmark name; known: {', '.join(known)}")
    return mods, sel_areas


def run_analysis(mods) -> int:
    failures = 0
    for name in mods:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["rows"])
            common.emit(mod.rows())
        except Exception:
            failures += 1
            print(f"{name}.EXCEPTION,0.0,\"{traceback.format_exc(limit=1)}\"",
                  file=sys.stderr)
    return failures


def check_areas(snapshots, baseline_dir, tol_scale: float = 1.0):
    """Diff fresh area snapshots against committed baselines. Returns
    (reports, ok) — ``ok`` is False on any regression, missing
    ratcheted metric, or absent baseline file."""
    from repro.bench import Snapshot, compare_snapshots, snapshot_filename

    reports, ok = [], True
    for area, fresh in snapshots.items():
        path = os.path.join(baseline_dir, snapshot_filename(area))
        if not os.path.exists(path):
            print(f"[{area}] no baseline at {path} — run "
                  f"`python -m benchmarks.run --record` and commit it",
                  file=sys.stderr)
            ok = False
            continue
        report = compare_snapshots(Snapshot.load(path), fresh,
                                   tol_scale=tol_scale)
        reports.append(report)
        ok = ok and report.ok
    return reports, ok


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated prefixes of analysis modules, "
                         "registry areas, or benchmark names")
    ap.add_argument("--scale", default="smoke",
                    choices=("tiny", "smoke", "full"),
                    help="registry preset (committed baselines are smoke)")
    ap.add_argument("--record", action="store_true",
                    help="write BENCH_<area>.json baselines")
    ap.add_argument("--check", action="store_true",
                    help="compare a fresh run against the committed "
                         "baselines; exit non-zero on regressions")
    ap.add_argument("--baseline-dir", default=REPO_ROOT,
                    help="where BENCH_<area>.json baselines live")
    ap.add_argument("--out", default="bench-out",
                    help="--check: directory for the fresh snapshots "
                         "(CI uploads these as artifacts)")
    ap.add_argument("--tol-scale", type=float, default=1.0,
                    help="multiply every noise band")
    args = ap.parse_args(argv)
    if args.record and args.check:
        ap.error("--record and --check are exclusive")

    load_registry()
    from repro.bench import run_area

    mods, sel_areas = select(args.only)
    log = lambda m: print(m, file=sys.stderr)

    snapshots = {a: run_area(a, scale=args.scale, log=log)
                 for a in sel_areas}
    for snap in snapshots.values():
        common.emit_snapshot(snap)

    if args.record:
        from repro.bench import snapshot_filename
        for area, snap in snapshots.items():
            path = os.path.join(args.baseline_dir, snapshot_filename(area))
            snap.save(path)
            log(f"[bench] wrote {path}")
        sys.exit(0)

    if args.check:
        from repro.bench import snapshot_filename
        os.makedirs(args.out, exist_ok=True)
        for area, snap in snapshots.items():
            snap.save(os.path.join(args.out, snapshot_filename(area)))
        reports, ok = check_areas(snapshots, args.baseline_dir,
                                  tol_scale=args.tol_scale)
        for report in reports:
            print(report.render())
        sys.exit(0 if ok else 1)

    sys.exit(1 if run_analysis(mods) else 0)


if __name__ == "__main__":
    main()
