"""Benchmark harness entry point — one module per paper table/figure plus
the roofline table and timed kernel microbenchmarks.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig2,...]

Prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import argparse
import sys
import traceback

MODULES = ["table1", "fig2_constraints", "fig3_energy_temp",
           "fig4_convergence", "roofline", "kernel_bench",
           "fl_engine_bench"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of modules")
    args = ap.parse_args()
    mods = MODULES if not args.only else [
        m for m in MODULES if any(m.startswith(p) for p in args.only.split(","))]
    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["rows"])
            for row_name, us, derived in mod.rows():
                print(f"{row_name},{us:.1f},{derived}")
        except Exception:
            failures += 1
            print(f"{name}.EXCEPTION,0.0,\"{traceback.format_exc(limit=1)}\"",
                  file=sys.stderr)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
