"""Wire-path benchmarks: fused quantize + top-k sparsify and the
fixed-point masked-sum cohort fold — registered on the ``repro.bench``
harness (area ``wire``) so their timings, throughputs, and the wire
compression ratio are typed, snapshotted to ``BENCH_wire.json``, and
ratcheted by ``python -m benchmarks.run --check``.

    PYTHONPATH=src:. python benchmarks/wire_bench.py [--scale smoke|full|tiny]

The masked-sum rows pin the point of the kernel path: the fused
one-pass fold over the stacked cohort (``ops.masked_sum_u64``; the
Pallas limb kernel on TPU, a single vectorized pass on CPU) beats the
per-arrival sequential accumulation ``MaskedSumAggregator`` previously
ran — ``fused_speedup`` ratchets that win. The sparse-wire row
ratchets the *bytes* win (deterministic, tight band): top-k ships a
fraction of the dense tuple.
"""
from __future__ import annotations

from repro.bench import MetricSpec, benchmark, time_callable

AREA = "wire"

_US = dict(unit="us", direction="lower", rtol=1.0)
_THROUGHPUT = dict(direction="higher", rtol=0.5)


@benchmark(
    "wire.quantize_topk", AREA,
    metrics=[MetricSpec("dense_roundtrip_us", **_US),
             MetricSpec("topk_roundtrip_us", **_US),
             MetricSpec("wire_in_gb_s", unit="GB/s", **_THROUGHPUT),
             MetricSpec("sparse_wire_reduction", unit="x",
                        direction="higher", rtol=0.05)],
    presets={"full": {"size": 1 << 20, "topk": 32, "repeats": 10},
             "smoke": {"size": 1 << 18, "topk": 32, "repeats": 15},
             "tiny": {"size": 1 << 14, "topk": 32, "repeats": 3}},
    description="fused quantize + per-block top-k sparsify round-trip "
                "and the dense->sparse wire-bytes ratio")
def quantize_topk(params):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import compression
    from repro.kernels import ops

    size, k = params["size"], params["topk"]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(size,)).astype(np.float32))
    dense = jax.jit(lambda v: ops.quantize_dequantize(v, bits=8))
    sparse = jax.jit(lambda v: ops.quantize_dequantize(v, bits=8, topk=k))
    t_dense = time_callable(dense, x, repeats=params["repeats"])
    t_topk = time_callable(sparse, x, repeats=params["repeats"])
    reduction = (compression.wire_bytes(x, 1)
                 / compression.wire_bytes(x, 1, topk=k))
    return {"dense_roundtrip_us": t_dense,
            "topk_roundtrip_us": t_topk,
            "wire_in_gb_s": size * 4 / (t_dense.median_us / 1e6) / 1e9,
            "sparse_wire_reduction": reduction,
            "context": {"elements": size, "topk": f"{k}/256"}}


@benchmark(
    "wire.masked_sum", AREA,
    metrics=[MetricSpec("cohort_seq_us", **_US),
             MetricSpec("cohort_fused_us", **_US),
             MetricSpec("fused_speedup", unit="x", **_THROUGHPUT),
             MetricSpec("agg_gb_s", unit="GB/s", **_THROUGHPUT)],
    presets={"full": {"clients": 64, "size": 1 << 20, "repeats": 7},
             "smoke": {"clients": 32, "size": 1 << 17, "repeats": 7},
             "tiny": {"clients": 4, "size": 1 << 13, "repeats": 3}},
    description="secagg cohort fold: per-arrival sequential uint64 "
                "accumulation vs the fused one-pass masked-sum kernel path")
def masked_sum(params):
    import numpy as np

    from repro.kernels import ops

    c, n = params["clients"], params["size"]
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 2 ** 64, size=(c, n), dtype=np.uint64)

    def sequential():
        # the aggregator's old inner loop: one modular add per arrival
        total = vals[0]
        for i in range(1, c):
            total = total + vals[i]
        return total

    t_seq = time_callable(sequential, repeats=params["repeats"], block=False)
    t_fused = time_callable(ops.masked_sum_u64, vals,
                            repeats=params["repeats"], block=False)
    assert np.array_equal(ops.masked_sum_u64(vals), sequential())
    return {"cohort_seq_us": t_seq,
            "cohort_fused_us": t_fused,
            "fused_speedup": t_seq.median_us / t_fused.median_us,
            "agg_gb_s": c * n * 8 / (t_fused.median_us / 1e6) / 1e9,
            "context": {"cohort": f"{c}x{n}"}}


def main(argv=None):
    from benchmarks.common import emit_snapshot, run_area_cli
    emit_snapshot(run_area_cli(AREA, argv))


if __name__ == "__main__":
    main()
