"""Executor micro-benchmark: sequential Python loop vs the batched
(jit + vmap-of-scan) LocalTrain path, same tiny char-LM round.

    PYTHONPATH=src:. python benchmarks/fl_engine_bench.py

Emits wall-clock per round (post-warmup median) for each executor and
the speedup, in the same CSV row format as the other benchmarks.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import emit


def rows():
    from repro.configs import get_config, get_fl_config
    from repro.core.client import ClientRunner
    from repro.core.policy import fedavg_knobs
    from repro.core.resources import calibrate
    from repro.data import load_corpus
    from repro.data.federated import FederatedData
    from repro.fl import ClientInfo, DeviceProfile, make_executor
    from repro.models import build

    ds = load_corpus(target_bytes=120_000)
    cfg = get_config("charlm-shakespeare").replace(
        vocab_size=max(ds.vocab_size, 64), num_layers=3, d_model=96,
        num_heads=4, num_kv_heads=4, head_dim=24, d_ff=192)
    fl = get_fl_config().replace(num_clients=8, clients_per_round=6,
                                 s_base=10, b_base=16, seq_len=32)
    fl = fl.replace(duals=dataclasses.replace(fl.duals, s_min=4, b_min=4))
    model = build(cfg)
    import jax
    params = model.init(jax.random.PRNGKey(0))
    from repro.core.freezing import count_params
    resources = calibrate(count_params(params), fl)
    data = FederatedData(ds.train, fl.num_clients, seed=fl.seed)
    knobs = fedavg_knobs(fl)
    profile = DeviceProfile("default", fl.budgets, resources=resources)
    clients = [ClientInfo(i, profile, data.shard_size(i))
               for i in range(fl.clients_per_round)]
    assignments = [(ci, knobs) for ci in clients]

    out = []
    timings = {}
    for name in ("sequential", "batched"):
        runner = ClientRunner(model, fl, data, resources)
        executor = make_executor(name, runner)
        executor.run_round(params, assignments)       # warmup / compile
        times = []
        for _ in range(3):
            t0 = time.perf_counter()
            executor.run_round(params, assignments)
            times.append(time.perf_counter() - t0)
        times.sort()
        med = times[len(times) // 2]
        timings[name] = med
        out.append((f"fl.executor.{name}.round", med * 1e6,
                    f"{fl.clients_per_round}clients*s{knobs.s}*b{knobs.b}"))
    out.append(("fl.executor.batched_speedup", 0.0,
                f"{timings['sequential'] / timings['batched']:.2f}x"))
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
