"""Executor micro-benchmark: sequential Python loop vs the batched
(jit + vmap-of-scan) LocalTrain path, same tiny char-LM round — plus a
fleet-dynamics configuration (uniform K-of-N sampling with deadline
stragglers) showing the engine-level round cost of partial
participation vs the full static fleet, a sync-vs-FedBuff
aggregator comparison under stragglers (rounds/sec and
rounds-to-target-loss: the barrier discards deadline-missers, the
buffered async path applies them late), a virtual wall-clock
comparison (``time_mode="wall_clock"``: simulated *seconds* to a
target loss for the wait-for-all barrier vs deadline-discard vs
FedBuff — the axis rounds-to-target cannot rank, since the three
policies' rounds cost different amounts of simulated time), and a
dual-controller comparison (deadzone vs adaptive vs PI) on the
calibrated proxy control loop: rounds until every constraint first
enters its deadzone band, and the tail violation ratio each law
settles at.

    PYTHONPATH=src:. python benchmarks/fl_engine_bench.py

Emits wall-clock per round (post-warmup median) for each executor and
the speedup, in the same CSV row format as the other benchmarks.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import emit


def rows():
    from repro.configs import get_config, get_fl_config
    from repro.core.client import ClientRunner
    from repro.core.policy import fedavg_knobs
    from repro.core.resources import calibrate
    from repro.data import load_corpus
    from repro.data.federated import FederatedData
    from repro.fl import ClientInfo, DeviceProfile, make_executor
    from repro.models import build

    ds = load_corpus(target_bytes=120_000)
    cfg = get_config("charlm-shakespeare").replace(
        vocab_size=max(ds.vocab_size, 64), num_layers=3, d_model=96,
        num_heads=4, num_kv_heads=4, head_dim=24, d_ff=192)
    fl = get_fl_config().replace(num_clients=8, clients_per_round=6,
                                 s_base=10, b_base=16, seq_len=32)
    fl = fl.replace(duals=dataclasses.replace(fl.duals, s_min=4, b_min=4))
    model = build(cfg)
    import jax
    params = model.init(jax.random.PRNGKey(0))
    from repro.core.freezing import count_params
    resources = calibrate(count_params(params), fl)
    data = FederatedData(ds.train, fl.num_clients, seed=fl.seed)
    knobs = fedavg_knobs(fl)
    profile = DeviceProfile("default", fl.budgets, resources=resources)
    clients = [ClientInfo(i, profile, data.shard_size(i))
               for i in range(fl.clients_per_round)]
    assignments = [(ci, knobs) for ci in clients]

    out = []
    timings = {}
    for name in ("sequential", "batched"):
        runner = ClientRunner(model, fl, data, resources)
        executor = make_executor(name, runner)
        executor.run_round(params, assignments)       # warmup / compile
        times = []
        for _ in range(3):
            t0 = time.perf_counter()
            executor.run_round(params, assignments)
            times.append(time.perf_counter() - t0)
        times.sort()
        med = times[len(times) // 2]
        timings[name] = med
        out.append((f"fl.executor.{name}.round", med * 1e6,
                    f"{fl.clients_per_round}clients*s{knobs.s}*b{knobs.b}"))
    out.append(("fl.executor.batched_speedup", 0.0,
                f"{timings['sequential'] / timings['batched']:.2f}x"))
    out += _dynamics_rows(model, fl, ds)
    out += _aggregator_rows(model, fl, ds)
    out += _wall_clock_rows(model, fl, ds)
    out += _controller_rows()
    return out


def _dynamics_rows(model, fl, ds):
    """Engine-level rounds: static full cohort vs K-of-N sampling with
    deadline stragglers (survivor-only execution means dropped clients
    cost the simulator nothing). Reported as the mean round time
    *including* jit retraces — under dynamics the survivor-group size
    and CAFL knob shapes change between rounds, so retracing is part of
    the scenario's real cost, not warmup to be excluded."""
    from repro.fl import (DeadlineStragglers, FederatedEngine, FleetDynamics,
                          FullParticipation, TimingCallback, UniformSampler)

    fl_bench = fl.replace(rounds=4, eval_batches=1, eval_batch_size=16,
                          clients_per_round=4)
    scenarios = {
        "full": FleetDynamics(sampler=FullParticipation()),
        "sampled": FleetDynamics(
            sampler=UniformSampler(fl_bench.clients_per_round),
            stragglers=DeadlineStragglers.for_config(fl_bench, deadline=2.0,
                                                     jitter=0.3)),
    }
    out = []
    for name, dyn in scenarios.items():
        timing = TimingCallback()
        res = FederatedEngine(model, fl_bench, ds, strategy="cafl",
                              executor="batched", dynamics=dyn,
                              callbacks=[timing]).run()
        seconds = timing.round_seconds[1:]           # drop first compile
        mean = sum(seconds) / len(seconds)
        parts = sum(len(r.participants) for r in res.history)
        drops = sum(len(r.dropped) for r in res.history)
        out.append((f"fl.engine.{name}.round_mean", mean * 1e6,
                    f"{parts}reported+{drops}dropped,incl-retraces"))
    return out


def _aggregator_rows(model, fl, ds):
    """Server-update policies under stragglers: the sync barrier vs
    FedBuff buffered async, same fleet and deadline. Reported as mean
    round wall-clock (rounds/sec, retraces included — late-report
    execution changes group shapes) plus rounds-to-target-loss, the
    metric the async path actually buys: late reports are applied with
    a staleness discount instead of discarded, so the same cohort
    budget reaches the target in fewer rounds."""
    from repro.fl import (DeadlineStragglers, FedBuffAggregator,
                          FederatedEngine, FleetDynamics, TimingCallback,
                          UniformSampler)

    fl_bench = fl.replace(rounds=6, eval_batches=1, eval_batch_size=16,
                          clients_per_round=4)

    def dyn():
        return FleetDynamics(
            sampler=UniformSampler(fl_bench.clients_per_round),
            stragglers=DeadlineStragglers.for_config(fl_bench, deadline=1.1,
                                                     jitter=0.3))

    runs = {}
    out = []
    for name, agg in (("sync", "sync"),
                      ("fedbuff", FedBuffAggregator(buffer_size=3))):
        timing = TimingCallback()
        res = FederatedEngine(model, fl_bench, ds, strategy="fedavg",
                              executor="batched", dynamics=dyn(),
                              aggregator=agg, callbacks=[timing]).run()
        runs[name] = res
        seconds = timing.round_seconds[1:]           # drop first compile
        mean = sum(seconds) / len(seconds)
        applied = sum(r.reports_applied for r in res.history)
        late = sum(len(r.late_arrivals) for r in res.history)
        out.append((f"fl.aggregator.{name}.round_mean", mean * 1e6,
                    f"{applied}applied({late}late),{1.0 / mean:.2f}rounds/s"))
    # rounds to the sync run's final loss: the async path's win metric
    target = runs["sync"].history[-1].val_loss
    for name, res in runs.items():
        hit = next((r.round for r in res.history if r.val_loss <= target),
                   None)
        out.append((f"fl.aggregator.{name}.rounds_to_target", 0.0,
                    f"target={target:.3f},"
                    f"{'hit@%d' % hit if hit else 'miss@%d' % fl_bench.rounds}"))
    return out


def _wall_clock_rows(model, fl, ds):
    """The virtual wall clock's headline metric: *simulated seconds* to
    a target loss under ``time_mode="wall_clock"``, for the three
    server policies the async story compares — a wait-for-all barrier
    (generous deadline: nothing lost, rounds cost the slow tier's full
    compute time), the deadline-discard barrier (tight deadline: rounds
    cost one deadline, stragglers' work is thrown away), and FedBuff
    (tight deadline, rounds end at buffer-fill events, stragglers
    deliver late at their simulated arrival time). Rounds-to-target
    cannot rank these fairly — their rounds cost different amounts of
    simulated time; seconds-to-target is the axis the paper's
    latency/thermal story actually cares about."""
    from repro.fl import (DeadlineStragglers, FedBuffAggregator,
                          FederatedEngine, FleetClass, FleetDynamics,
                          UniformSampler, make_fleet, seconds_to_target)

    fl_bench = fl.replace(rounds=6, eval_batches=1, eval_batch_size=16,
                          clients_per_round=4)
    profiles, cp = make_fleet(fl_bench, [
        FleetClass("fast", fraction=0.5),
        FleetClass("slow", fraction=0.5, compute_scale=2.0)])

    def dyn(deadline):
        return FleetDynamics(
            sampler=UniformSampler(fl_bench.clients_per_round),
            stragglers=DeadlineStragglers.for_config(fl_bench,
                                                     deadline=deadline,
                                                     jitter=0.3))

    scenarios = {
        "sync": ("sync", 4.0),                 # wait-for-all barrier
        "deadline_discard": ("sync", 1.1),     # tight barrier, discards
        "fedbuff": (FedBuffAggregator(buffer_size=3), 1.1),
    }
    runs = {}
    out = []
    for name, (agg, deadline) in scenarios.items():
        res = FederatedEngine(model, fl_bench, ds, strategy="fedavg",
                              executor="batched", profiles=profiles,
                              client_profiles=cp, dynamics=dyn(deadline),
                              aggregator=agg).run(time_mode="wall_clock")
        runs[name] = res
        sim = res.history[-1].sim_time
        out.append((f"fl.clock.{name}.sim_seconds_total", 0.0,
                    f"{sim:.2f}du,{len(res.history)}rounds,"
                    f"{sim / len(res.history):.2f}du/round"))
    # seconds to the weakest policy's final loss (deadline units: 1.0 =
    # one baseline round on calibration silicon); the start-of-round
    # charge convention lives in repro.fl.clock.seconds_to_target
    target = max(res.history[-1].val_loss for res in runs.values())
    for name, res in runs.items():
        hit = seconds_to_target(res, target)
        out.append((f"fl.clock.{name}.seconds_to_target", 0.0,
                    f"target={target:.3f},"
                    + (f"hit@{hit:.2f}du" if hit is not None
                       else f"miss@{res.history[-1].sim_time:.2f}du")))
    return out


def _controller_rows():
    """Dual-controller comparison on the paper's calibrated proxy
    control loop (``repro.constraints.proxy_control_loop`` — no NN; the
    constraint dynamics are host-side float math, so the *law* is
    what's measured, not the executor). Two metrics per controller:
    rounds until the worst constraint ratio first enters the deadzone
    satisfaction band (<= 1 + delta), and the tail mean of that worst
    ratio (steady-state violation). FedAvg's fixed knobs start ~5x over
    the comm budget, so faster laws close the gap in fewer rounds."""
    from repro.configs import get_fl_config
    from repro.constraints import (proxy_control_loop, rounds_to_band,
                                   tail_worst_ratio)

    fl = get_fl_config()
    rounds, tail = 80, 10
    band = 1.0 + fl.duals.deadzone
    out = []
    for name in ("deadzone", "adaptive", "pi"):
        history = proxy_control_loop(fl, controller=name, rounds=rounds)
        hit = rounds_to_band(history, band)
        out.append((f"fl.controller.{name}.rounds_to_satisfaction", 0.0,
                    f"{'hit@%d' % hit if hit else 'miss@%d' % rounds},"
                    f"band<={band:.2f}"))
        out.append((f"fl.controller.{name}.tail_violation", 0.0,
                    f"worst_ratio={tail_worst_ratio(history, tail):.3f},"
                    f"tail{tail}"))
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
