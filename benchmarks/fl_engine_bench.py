"""Federated-engine benchmarks on the ``repro.bench`` harness (area
``fl_engine``), snapshotted to ``BENCH_fl_engine.json``:

- ``fl.executor`` — sequential Python loop vs the batched
  (jit + vmap-of-scan) LocalTrain path on the same tiny char-LM round;
  the speedup is a typed ``batched_speedup`` metric (higher is better —
  it regresses *downward*).
- ``fl.dynamics`` — engine-level round cost of K-of-N sampling with
  deadline stragglers vs the full static fleet, retraces included
  (survivor-group shapes change between rounds; that cost is the
  scenario's, not warmup).
- ``fl.aggregator`` — sync barrier vs FedBuff buffered async under
  stragglers: mean round wall-clock plus ``rounds_to_target`` (the
  metric async actually buys; a miss records as rounds+1 so later
  regressions stay visible).
- ``fl.wall_clock`` — simulated *seconds* to a target loss under
  ``time_mode="wall_clock"`` for wait-for-all / deadline-discard /
  FedBuff: deterministic given the seed, so these ``du`` metrics
  ratchet tightly.
- ``fl.controller`` — dual-controller laws (deadzone/adaptive/PI) on
  the calibrated proxy control loop: rounds until the deadzone band and
  tail violation ratio; host-side float math, tightest bands of all.

    PYTHONPATH=src:. python benchmarks/fl_engine_bench.py [--scale smoke|full|tiny]
"""
from __future__ import annotations

import dataclasses
import time

from repro.bench import MetricSpec, TimingStats, benchmark

AREA = "fl_engine"

# Wall-clock metrics move across machines: 2x band. Simulated /
# derived metrics are seed-deterministic: tight bands (the small atol
# absorbs cross-BLAS loss wiggle flipping a hit by one round).
_US = dict(unit="us", direction="lower", rtol=1.0)

_MODEL_KEYS = ("corpus_bytes", "num_layers", "d_model", "num_heads",
               "head_dim", "d_ff", "num_clients", "clients_per_round",
               "s_base", "b_base", "seq_len")

_FULL_MODEL = {"corpus_bytes": 120_000, "num_layers": 3, "d_model": 96,
               "num_heads": 4, "head_dim": 24, "d_ff": 192,
               "num_clients": 8, "clients_per_round": 6,
               "s_base": 10, "b_base": 16, "seq_len": 32}
_SMOKE_MODEL = {"corpus_bytes": 60_000, "num_layers": 2, "d_model": 64,
                "num_heads": 4, "head_dim": 16, "d_ff": 128,
                "num_clients": 6, "clients_per_round": 4,
                "s_base": 6, "b_base": 8, "seq_len": 32}
_TINY_MODEL = {"corpus_bytes": 30_000, "num_layers": 2, "d_model": 32,
               "num_heads": 2, "head_dim": 16, "d_ff": 64,
               "num_clients": 4, "clients_per_round": 2,
               "s_base": 3, "b_base": 4, "seq_len": 16}


def _setup(params):
    """Shared model/config/data setup for the engine benchmarks."""
    from repro.configs import get_config, get_fl_config
    from repro.data import load_corpus
    from repro.models import build

    ds = load_corpus(target_bytes=params["corpus_bytes"])
    cfg = get_config("charlm-shakespeare").replace(
        vocab_size=max(ds.vocab_size, 64), num_layers=params["num_layers"],
        d_model=params["d_model"], num_heads=params["num_heads"],
        num_kv_heads=params["num_heads"], head_dim=params["head_dim"],
        d_ff=params["d_ff"])
    fl = get_fl_config().replace(
        num_clients=params["num_clients"],
        clients_per_round=params["clients_per_round"],
        s_base=params["s_base"], b_base=params["b_base"],
        seq_len=params["seq_len"])
    fl = fl.replace(duals=dataclasses.replace(fl.duals, s_min=4, b_min=4))
    return build(cfg), fl, ds


def _round_mean_us(timing, rounds):
    """Mean round seconds as a pseudo-TimingStats (first round dropped
    as compile when more than one was timed), in microseconds."""
    seconds = timing.round_seconds[1:] or timing.round_seconds
    mean = sum(seconds) / len(seconds)
    lo, hi = min(seconds), max(seconds)
    return TimingStats(median_us=mean * 1e6, iqr_us=(hi - lo) * 1e6,
                       n=len(seconds))


@benchmark(
    "fl.executor", AREA,
    metrics=[MetricSpec("sequential_round_us", **_US),
             MetricSpec("batched_round_us", **_US),
             MetricSpec("batched_speedup", unit="x", direction="higher",
                        rtol=0.35, atol=0.15)],
    presets={"full": {**_FULL_MODEL, "repeats": 3},
             "smoke": {**_SMOKE_MODEL, "repeats": 3},
             "tiny": {**_TINY_MODEL, "repeats": 2}},
    description="sequential vs batched (jit+vmap-of-scan) LocalTrain round")
def executor_bench(params):
    from repro.core.client import ClientRunner
    from repro.core.policy import fedavg_knobs
    from repro.core.resources import calibrate
    from repro.data.federated import FederatedData
    from repro.fl import ClientInfo, DeviceProfile, make_executor

    model, fl, ds = _setup(params)
    import jax
    model_params = model.init(jax.random.PRNGKey(0))
    from repro.core.freezing import count_params
    resources = calibrate(count_params(model_params), fl)
    data = FederatedData(ds.train, fl.num_clients, seed=fl.seed)
    knobs = fedavg_knobs(fl)
    profile = DeviceProfile("default", fl.budgets, resources=resources)
    clients = [ClientInfo(i, profile, data.shard_size(i))
               for i in range(fl.clients_per_round)]
    assignments = [(ci, knobs) for ci in clients]

    out = {"context": {"cohort":
                       f"{fl.clients_per_round}clients*s{knobs.s}*b{knobs.b}"}}
    medians = {}
    for name in ("sequential", "batched"):
        runner = ClientRunner(model, fl, data, resources)
        executor = make_executor(name, runner)
        executor.run_round(model_params, assignments)    # warmup / compile
        times = []
        for _ in range(params["repeats"]):
            t0 = time.perf_counter()
            executor.run_round(model_params, assignments)
            times.append(time.perf_counter() - t0)
        times.sort()
        med = times[len(times) // 2]
        medians[name] = med
        out[f"{name}_round_us"] = TimingStats(
            median_us=med * 1e6, iqr_us=(times[-1] - times[0]) * 1e6,
            n=len(times))
    out["batched_speedup"] = medians["sequential"] / medians["batched"]
    return out


@benchmark(
    "fl.dynamics", AREA,
    metrics=[MetricSpec("full_round_mean_us", **_US),
             MetricSpec("sampled_round_mean_us", **_US)],
    presets={"full": {**_FULL_MODEL, "rounds": 4, "cohort": 4,
                      "deadline": 2.0},
             "smoke": {**_SMOKE_MODEL, "rounds": 3, "cohort": 3,
                       "deadline": 2.0},
             "tiny": {**_TINY_MODEL, "rounds": 2, "cohort": 2,
                      "deadline": 2.0}},
    description="static full cohort vs K-of-N sampling with deadline "
                "stragglers, mean round time incl. retraces")
def dynamics_bench(params):
    from repro.fl import (DeadlineStragglers, FederatedEngine, FleetDynamics,
                          FullParticipation, TimingCallback, UniformSampler)

    model, fl, ds = _setup(params)
    fl_bench = fl.replace(rounds=params["rounds"], eval_batches=1,
                          eval_batch_size=16,
                          clients_per_round=params["cohort"])
    scenarios = {
        "full": FleetDynamics(sampler=FullParticipation()),
        "sampled": FleetDynamics(
            sampler=UniformSampler(fl_bench.clients_per_round),
            stragglers=DeadlineStragglers.for_config(
                fl_bench, deadline=params["deadline"], jitter=0.3)),
    }
    out = {"context": {}}
    for name, dyn in scenarios.items():
        timing = TimingCallback()
        res = FederatedEngine(model, fl_bench, ds, strategy="cafl",
                              executor="batched", dynamics=dyn,
                              callbacks=[timing]).run()
        out[f"{name}_round_mean_us"] = _round_mean_us(timing,
                                                      fl_bench.rounds)
        parts = sum(len(r.participants) for r in res.history)
        drops = sum(len(r.dropped) for r in res.history)
        out["context"][name] = f"{parts}reported+{drops}dropped,incl-retraces"
    return out


@benchmark(
    "fl.aggregator", AREA,
    metrics=[MetricSpec("sync_round_mean_us", **_US),
             MetricSpec("fedbuff_round_mean_us", **_US),
             MetricSpec("sync_rounds_to_target", unit="rounds",
                        direction="lower", rtol=0.0, atol=1.0),
             MetricSpec("fedbuff_rounds_to_target", unit="rounds",
                        direction="lower", rtol=0.0, atol=1.0)],
    presets={"full": {**_FULL_MODEL, "rounds": 6, "cohort": 4,
                      "deadline": 1.1, "buffer_size": 3},
             "smoke": {**_SMOKE_MODEL, "rounds": 4, "cohort": 3,
                       "deadline": 1.1, "buffer_size": 2},
             "tiny": {**_TINY_MODEL, "rounds": 2, "cohort": 2,
                      "deadline": 1.1, "buffer_size": 2}},
    description="sync barrier vs FedBuff under stragglers: round cost and "
                "rounds-to-target-loss (miss records as rounds+1)")
def aggregator_bench(params):
    from repro.fl import (DeadlineStragglers, FedBuffAggregator,
                          FederatedEngine, FleetDynamics, TimingCallback,
                          UniformSampler)

    model, fl, ds = _setup(params)
    fl_bench = fl.replace(rounds=params["rounds"], eval_batches=1,
                          eval_batch_size=16,
                          clients_per_round=params["cohort"])

    def dyn():
        return FleetDynamics(
            sampler=UniformSampler(fl_bench.clients_per_round),
            stragglers=DeadlineStragglers.for_config(
                fl_bench, deadline=params["deadline"], jitter=0.3))

    runs, out = {}, {"context": {}}
    for name, agg in (("sync", "sync"),
                      ("fedbuff",
                       FedBuffAggregator(buffer_size=params["buffer_size"]))):
        timing = TimingCallback()
        res = FederatedEngine(model, fl_bench, ds, strategy="fedavg",
                              executor="batched", dynamics=dyn(),
                              aggregator=agg, callbacks=[timing]).run()
        runs[name] = res
        out[f"{name}_round_mean_us"] = _round_mean_us(timing,
                                                      fl_bench.rounds)
        applied = sum(r.reports_applied for r in res.history)
        late = sum(len(r.late_arrivals) for r in res.history)
        out["context"][name] = f"{applied}applied({late}late)"
    # rounds to the sync run's final loss: the async path's win metric
    target = runs["sync"].history[-1].val_loss
    out["context"]["target"] = f"{target:.4f}"
    for name, res in runs.items():
        hit = next((r.round for r in res.history if r.val_loss <= target),
                   None)
        out[f"{name}_rounds_to_target"] = float(
            hit if hit is not None else fl_bench.rounds + 1)
    return out


@benchmark(
    "fl.wall_clock", AREA,
    metrics=[MetricSpec(f"{p}_{m}", unit="du", direction="lower",
                        rtol=0.25, atol=a)
             for p in ("sync", "deadline_discard", "fedbuff")
             for m, a in (("du_per_round", 0.1),
                          ("seconds_to_target", 1.0))],
    presets={"full": {**_FULL_MODEL, "rounds": 6, "cohort": 4,
                      "buffer_size": 3},
             "smoke": {**_SMOKE_MODEL, "rounds": 4, "cohort": 3,
                       "buffer_size": 2},
             "tiny": {**_TINY_MODEL, "rounds": 2, "cohort": 2,
                      "buffer_size": 2}},
    description="simulated seconds to target loss (wall_clock mode): "
                "wait-for-all vs deadline-discard vs FedBuff; a miss "
                "records as the run's total simulated time + 1du")
def wall_clock_bench(params):
    from repro.fl import (DeadlineStragglers, FedBuffAggregator,
                          FederatedEngine, FleetClass, FleetDynamics,
                          UniformSampler, make_fleet, seconds_to_target)

    model, fl, ds = _setup(params)
    fl_bench = fl.replace(rounds=params["rounds"], eval_batches=1,
                          eval_batch_size=16,
                          clients_per_round=params["cohort"])
    profiles, cp = make_fleet(fl_bench, [
        FleetClass("fast", fraction=0.5),
        FleetClass("slow", fraction=0.5, compute_scale=2.0)])

    def dyn(deadline):
        return FleetDynamics(
            sampler=UniformSampler(fl_bench.clients_per_round),
            stragglers=DeadlineStragglers.for_config(fl_bench,
                                                     deadline=deadline,
                                                     jitter=0.3))

    scenarios = {
        "sync": ("sync", 4.0),                 # wait-for-all barrier
        "deadline_discard": ("sync", 1.1),     # tight barrier, discards
        "fedbuff": (FedBuffAggregator(buffer_size=params["buffer_size"]),
                    1.1),
    }
    runs, out = {}, {"context": {}}
    for name, (agg, deadline) in scenarios.items():
        res = FederatedEngine(model, fl_bench, ds, strategy="fedavg",
                              executor="batched", profiles=profiles,
                              client_profiles=cp, dynamics=dyn(deadline),
                              aggregator=agg).run(time_mode="wall_clock")
        runs[name] = res
        sim = res.history[-1].sim_time
        out[f"{name}_du_per_round"] = sim / len(res.history)
        out["context"][name] = f"{sim:.2f}du,{len(res.history)}rounds"
    # seconds to the weakest policy's final loss (deadline units: 1.0 =
    # one baseline round on calibration silicon)
    target = max(res.history[-1].val_loss for res in runs.values())
    out["context"]["target"] = f"{target:.4f}"
    for name, res in runs.items():
        hit = seconds_to_target(res, target)
        out[f"{name}_seconds_to_target"] = (
            hit if hit is not None else res.history[-1].sim_time + 1.0)
    return out


@benchmark(
    "fl.controller", AREA,
    metrics=[MetricSpec(f"{c}_{m}", unit=u, direction="lower",
                        rtol=r, atol=a)
             for c in ("deadzone", "adaptive", "pi")
             for m, u, r, a in (("rounds_to_satisfaction", "rounds",
                                 0.0, 2.0),
                                ("tail_violation", "ratio", 0.05, 0.01))],
    presets={"full": {"rounds": 80, "tail": 10},
             "smoke": {"rounds": 80, "tail": 10},
             "tiny": {"rounds": 40, "tail": 5}},
    description="dual-controller laws on the calibrated proxy loop: rounds "
                "until the deadzone band, tail violation ratio (host-side "
                "float math; a miss records as rounds+1)")
def controller_bench(params):
    from repro.configs import get_fl_config
    from repro.constraints import (proxy_control_loop, rounds_to_band,
                                   tail_worst_ratio)

    fl = get_fl_config()
    rounds, tail = params["rounds"], params["tail"]
    band = 1.0 + fl.duals.deadzone
    out = {"context": {"band": f"<={band:.2f}"}}
    for name in ("deadzone", "adaptive", "pi"):
        history = proxy_control_loop(fl, controller=name, rounds=rounds)
        hit = rounds_to_band(history, band)
        out[f"{name}_rounds_to_satisfaction"] = float(
            hit if hit is not None else rounds + 1)
        out[f"{name}_tail_violation"] = tail_worst_ratio(history, tail)
    return out


@benchmark(
    "fl.memory_static", AREA,
    metrics=[MetricSpec("undonated_peak_bytes", unit="B",
                        direction="lower", rtol=0.05),
             MetricSpec("donated_peak_bytes", unit="B",
                        direction="lower", rtol=0.05),
             MetricSpec("donation_saving", unit="x", direction="higher",
                        rtol=0.05)],
    presets={"full": {}, "smoke": {}, "tiny": {}},
    description="static (jaxpr cost model) peak of the client update "
                "step with vs without opt-state/grad donation — the "
                "PR-9 donation win, ratcheted so it cannot silently "
                "regress")
def memory_static_bench(params):
    from repro.analysis.trace import cost_of_jaxpr, traced_entries

    t = {x.entry.name: x for x in traced_entries()}["fl.client_update_step"]
    undonated = cost_of_jaxpr(t.closed_jaxpr).peak_bytes
    donated = t.cost.peak_bytes
    return {
        "context": {"entry": t.entry.name,
                    "aliased": f"{t.aliased_outputs}/{t.donatable_leaves}"},
        "undonated_peak_bytes": float(undonated),
        "donated_peak_bytes": float(donated),
        "donation_saving": undonated / donated,
    }


def main(argv=None):
    from benchmarks.common import emit_snapshot, run_area_cli
    emit_snapshot(run_area_cli(AREA, argv))


if __name__ == "__main__":
    main()
