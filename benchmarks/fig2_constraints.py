"""Paper Fig. 2: per-round memory & communication constraint satisfaction.

Emits round-by-round usage/budget ratios for both methods (the plotted
quantity) and the violation summary the paper quotes (FedAvg up to 1.1x
memory / 5.2x comm; CAFL-L within bounds by ~round 50).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, load_fl


def rows():
    out = []
    for method in ("fedavg", "cafl"):
        data = load_fl(method)
        if not data:
            return [("fig2.missing_results", 0.0, "run repro.launch.train")]
        hist = data["history"]
        mem = [r["ratios"]["memory"] for r in hist]
        comm = [r["ratios"]["comm"] for r in hist]
        out.append((f"fig2.{method}.mem_ratio_max", 0.0, f"{max(mem):.2f}x"))
        out.append((f"fig2.{method}.comm_ratio_max", 0.0, f"{max(comm):.2f}x"))
        tail = slice(-10, None)
        out.append((f"fig2.{method}.mem_ratio_tail", 0.0,
                    f"{np.mean(mem[tail]):.2f}x"))
        out.append((f"fig2.{method}.comm_ratio_tail", 0.0,
                    f"{np.mean(comm[tail]):.2f}x"))
        # trace CSV (round:ratio pairs, decimated)
        step = max(1, len(hist) // 12)
        trace_m = " ".join(f"{r['round']}:{r['ratios']['memory']:.2f}"
                           for r in hist[::step])
        trace_c = " ".join(f"{r['round']}:{r['ratios']['comm']:.2f}"
                           for r in hist[::step])
        out.append((f"fig2.{method}.mem_trace", 0.0, trace_m))
        out.append((f"fig2.{method}.comm_trace", 0.0, trace_c))
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
