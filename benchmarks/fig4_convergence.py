"""Paper Fig. 4: convergence — validation loss traces for both methods
(paper: FedAvg 1.93 vs CAFL-L 2.10, a +9% gap)."""
from __future__ import annotations

from benchmarks.common import emit, load_fl


def rows():
    out = []
    finals = {}
    for method in ("fedavg", "cafl"):
        data = load_fl(method)
        if not data:
            return [("fig4.missing_results", 0.0, "run repro.launch.train")]
        hist = data["history"]
        finals[method] = hist[-1]["val_loss"]
        step = max(1, len(hist) // 12)
        out.append((f"fig4.{method}.val_loss_trace", 0.0,
                    " ".join(f"{r['round']}:{r['val_loss']:.3f}"
                             for r in hist[::step])))
        out.append((f"fig4.{method}.val_loss_final", 0.0,
                    f"{hist[-1]['val_loss']:.4f}"))
        out.append((f"fig4.{method}.train_loss_final", 0.0,
                    f"{hist[-1]['train_loss']:.4f}"))
    gap = 100 * (finals["cafl"] / finals["fedavg"] - 1)
    out.append(("fig4.val_loss_gap_pct", 0.0,
                f"+{gap:.1f}% (paper +9%: 2.10 vs 1.93)"))
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
