"""Async fleet: FedBuff buffered aggregation vs the deadline-discard
barrier, on the PR 2 straggler model — in rounds AND simulated seconds.

Half the fleet runs 2x slower silicon than the 1.1x round deadline
allows, so under the synchronous barrier its work is *discarded* every
round and its token budget carried as debt — and the carry-over boost
(extra grad-accum at the next participation) makes a one-time misser
slower still, a death spiral that starves the barrier down to a
handful of applied reports. A FedBuff aggregator instead executes the
deadline-missers, lets their reports arrive in the round their
simulated wall clock lands in, and folds them into the next buffered
update with a staleness discount: nearly every client-round is applied
(only reports due past the run horizon are still discarded), and the
run keeps improving after the sync baseline stalls — fewer rounds to
any loss target at or below the sync final.

The second half re-runs both policies under ``time_mode="wall_clock"``
(repro.fl.clock), where the comparison is finally on the axis the
paper cares about: *simulated seconds*. A deadline-discard round
always costs one full deadline (the server waits for stragglers that
never report); a FedBuff round ends at its buffer-fill event and late
reports land at their actual arrival times, so the async path is
faster per round AND wastes no client work — it reaches the same loss
target in fewer simulated seconds, not just fewer rounds.

    PYTHONPATH=src python examples/async_fleet.py

(REPRO_EXAMPLE_ROUNDS caps the round budget for CI smoke runs.)
"""
import dataclasses
import os

from repro.configs import get_config, get_fl_config
from repro.data import load_corpus
from repro.fl import (DeadlineStragglers, FedBuffAggregator, FederatedEngine,
                      FleetClass, FleetDynamics, UniformSampler, make_fleet,
                      seconds_to_target)
from repro.models import build

ROUNDS = int(os.environ.get("REPRO_EXAMPLE_ROUNDS", "10"))

ds = load_corpus(target_bytes=120_000)
cfg = get_config("charlm-shakespeare").replace(
    vocab_size=max(ds.vocab_size, 64), num_layers=3, d_model=96,
    num_heads=4, num_kv_heads=4, head_dim=24, d_ff=192)
fl = get_fl_config().replace(rounds=ROUNDS, num_clients=8,
                             clients_per_round=4, s_base=10, b_base=16,
                             seq_len=32, eval_batches=2, eval_batch_size=32)
fl = fl.replace(duals=dataclasses.replace(fl.duals, s_min=4, b_min=4))

# two tiers: the slow half's 2x silicon never makes the 1.1x deadline
profiles, client_profiles = make_fleet(fl, [
    FleetClass("fast", fraction=0.5),
    FleetClass("slow", fraction=0.5, compute_scale=2.0),
])


def dynamics():
    return FleetDynamics(
        sampler=UniformSampler(fl.clients_per_round),
        stragglers=DeadlineStragglers.for_config(fl, deadline=1.1,
                                                 jitter=0.2))


model = build(cfg)
results = {}
for name, agg in (("sync", "sync"),
                  ("fedbuff", FedBuffAggregator(buffer_size=3))):
    print(f"=== {name} ===")
    res = FederatedEngine(model, fl, ds, strategy="fedavg",
                          executor="batched", profiles=profiles,
                          client_profiles=client_profiles,
                          dynamics=dynamics(), aggregator=agg).run()
    results[name] = res
    used = sum(r.reports_applied for r in res.history)
    lost = sum(len(r.dropped) for r in res.history)
    late = sum(len(r.late_arrivals) for r in res.history)
    for r in res.history:
        print(f"  round {r.round:2d} val={r.val_loss:.4f} "
              f"applied={r.reports_applied} late={len(r.late_arrivals)} "
              f"lost={len(r.dropped)} stale={r.mean_staleness:.2f}")
    print(f"  client-rounds: {used} applied ({late} of them late), "
          f"{lost} discarded")


def rounds_to(res, target):
    for r in res.history:
        if r.val_loss <= target:
            return r.round
    return None


# rounds-to-target-loss: target = just below where the discard
# baseline ends up (its loss plateaus once the debt spiral has starved
# the barrier of reporters)
target = 0.99 * results["sync"].history[-1].val_loss
print(f"\nrounds to reach 99% of the sync run's final loss "
      f"({target:.4f}):")
for name, res in results.items():
    hit = rounds_to(res, target)
    print(f"  {name:8s} {hit if hit is not None else f'>{ROUNDS} (never)'}")
buff_hit = rounds_to(results["fedbuff"], target)
sync_hit = rounds_to(results["sync"], target)
if buff_hit is not None and (sync_hit is None or buff_hit < sync_hit):
    print("\nFedBuff got there first: the slow tier's late reports were "
          "applied (staleness-discounted) instead of thrown away at the "
          "barrier, so the same cohort budget kept improving the model "
          "after the discard baseline stalled.")

# --- the same comparison on the virtual wall clock -----------------------
# time_mode="wall_clock": rounds begin when the previous barrier/buffer
# event completes, so the two policies' rounds now cost what they
# simulate — a discard-barrier round burns one full deadline waiting
# for reports that never come, a FedBuff round ends at its buffer fill.
print("\n=== wall clock (simulated seconds; 1.0 = one baseline round) ===")
wall = {}
for name, agg in (("sync", "sync"),
                  ("fedbuff", FedBuffAggregator(buffer_size=3))):
    res = FederatedEngine(model, fl, ds, strategy="fedavg",
                          executor="batched", profiles=profiles,
                          client_profiles=client_profiles,
                          dynamics=dynamics(),
                          aggregator=agg).run(time_mode="wall_clock")
    wall[name] = res
    total = res.history[-1].sim_time
    print(f"  {name:8s} {len(res.history)} rounds in {total:.2f} simulated "
          f"seconds ({total / len(res.history):.2f}/round), "
          f"final val={res.history[-1].val_loss:.4f}")


wall_target = 0.99 * wall["sync"].history[-1].val_loss
print(f"\nsimulated seconds to reach 99% of the discard baseline's final "
      f"loss ({wall_target:.4f}):")
for name, res in wall.items():
    hit = seconds_to_target(res, wall_target)
    print(f"  {name:8s} "
          f"{f'{hit:.2f}s' if hit is not None else 'never (budget spent)'}")
b_s = seconds_to_target(wall["fedbuff"], wall_target)
s_s = seconds_to_target(wall["sync"], wall_target)
if b_s is not None and (s_s is None or b_s < s_s):
    print("\nFedBuff wins in *seconds*, not just rounds: its rounds end "
          "at buffer events instead of deadline expiries, and the slow "
          "tier's reports land at their real arrival times — the latency "
          "claim the round-count simulation could never show.")
