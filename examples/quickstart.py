"""Quickstart: train the paper's char-LM locally for a few steps, then
sample text through the serving path (prefill + decode).

    PYTHONPATH=src python examples/quickstart.py
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import load_corpus, sample_batch
from repro.models import build
from repro.optim import adamw, apply_updates

# CI smoke budget: REPRO_EXAMPLE_ROUNDS=2 trims steps and sampling
_BUDGET = os.environ.get("REPRO_EXAMPLE_ROUNDS")
STEPS = 60 if _BUDGET is None else max(5, int(_BUDGET) * 5)
NEW_TOKENS = 200 if _BUDGET is None else 40


def main():
    ds = load_corpus()
    cfg = get_config("charlm-shakespeare").replace(
        vocab_size=max(ds.vocab_size, 64))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw(3e-3)
    opt_state = opt.init(params)

    grad_fn = jax.jit(lambda p, b: jax.value_and_grad(
        model.train_loss, has_aux=True)(p, b))
    rng = np.random.default_rng(0)
    print(f"training {STEPS} steps on", len(ds.train), "chars ...")
    for step in range(STEPS):
        batch = {k: jnp.asarray(v)
                 for k, v in sample_batch(ds.train, rng, 32, 64).items()}
        (loss, _), grads = grad_fn(params, batch)
        ups, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, ups)
        if step % 10 == 0:
            print(f"  step {step:3d} loss {float(loss):.3f}")

    # sample through the serving path
    prompt = "HAMLET:\n"
    toks = jnp.asarray(ds.encode(prompt))[None, :]
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_new_tokens=NEW_TOKENS))(
            params, {"tokens": toks})
    step_fn = jax.jit(model.decode_step)
    out = list(np.asarray(toks[0]))
    key = jax.random.PRNGKey(1)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    for _ in range(NEW_TOKENS):
        out.append(int(tok[0, 0]))
        logits, cache = step_fn(params, cache, tok)
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(sub, logits[:, -1] / 0.8)[:, None]
    print("\n--- sample ---")
    print(ds.decode(out))


if __name__ == "__main__":
    main()
