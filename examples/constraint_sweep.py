"""Budget sweep: how the CAFL-L policy operating point moves as each
budget is tightened (pure control-loop simulation — no NN, instant).

    PYTHONPATH=src python examples/constraint_sweep.py
"""
from repro.configs import get_fl_config
from repro.core.duals import DualState, dual_update, usage_ratios
from repro.core.policy import policy
from repro.core.resources import calibrate

fl = get_fl_config()
P = 1.9e6
res = calibrate(P, fl)


def p_active(k):
    return P * (0.94 * k / fl.k_base + 0.06)


def steady_state(fl_cfg, rounds=150, tail=30):
    """Tail-averaged operating point (duals oscillate around thresholds)."""
    duals = DualState()
    kns, ratios = [], []
    for t in range(rounds):
        kn = policy(duals, fl_cfg)
        u = res.usage(p_active(kn.k), kn)
        duals = dual_update(duals, u, fl_cfg.budgets, fl_cfg.duals)
        if t >= rounds - tail:
            kns.append(kn)
            ratios.append(usage_ratios(u, fl_cfg.budgets))
    import numpy as np
    mean_r = {k: float(np.mean([r[k] for r in ratios])) for k in ratios[0]}
    mean_kn = {f: float(np.mean([getattr(k, f) for k in kns]))
               for f in ("k", "s", "b", "q", "grad_accum")}
    return mean_kn, mean_r


print(f"{'budget scale':>14s} | {'mean knobs (k,s,b,q,ga)':>28s} | mean ratios E/C/M/T")
for resource in ("comm", "energy", "memory"):
    for scale in (2.0, 1.0, 0.5, 0.25):
        budgets = fl.budgets.scaled(**{resource: scale})
        kn, r = steady_state(fl.replace(budgets=budgets))
        print(f"{resource}x{scale:<5g} | k={kn['k']:.1f} s={kn['s']:4.1f} "
              f"b={kn['b']:4.1f} q={kn['q']:.1f} ga={kn['grad_accum']:4.1f} | "
              f"{r['energy']:.2f}/{r['comm']:.2f}/{r['memory']:.2f}/{r['temp']:.2f}")
print("\nTighter comm budgets push q (compression); tighter energy budgets "
      "cut s; the token budget (Eq. 8) raises grad_accum to compensate.")
