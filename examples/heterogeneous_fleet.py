"""Heterogeneous fleet: CAFL-L with per-device-class budgets and duals.

Half the fleet is a high-end tier (1.5x budgets), half a low-end tier
(0.5x budgets, 1.5x energy/heat per token). The engine keeps one dual
state per tier, so the policy lands on a different operating point for
each device class — the scenario the monolithic loop could not express.

    PYTHONPATH=src python examples/heterogeneous_fleet.py
"""
import dataclasses
import os

from repro.configs import get_config, get_fl_config
from repro.data import load_corpus
from repro.fl import FederatedEngine, FleetClass, make_fleet
from repro.models import build

ROUNDS = int(os.environ.get("REPRO_EXAMPLE_ROUNDS", "8"))

ds = load_corpus(target_bytes=120_000)
cfg = get_config("charlm-shakespeare").replace(
    vocab_size=max(ds.vocab_size, 64), num_layers=3, d_model=96,
    num_heads=4, num_kv_heads=4, head_dim=24, d_ff=192)
fl = get_fl_config().replace(rounds=ROUNDS, num_clients=8, clients_per_round=4,
                             s_base=10, b_base=16, seq_len=32,
                             eval_batches=2, eval_batch_size=32)
fl = fl.replace(duals=dataclasses.replace(fl.duals, s_min=4, b_min=4))

profiles, client_profiles = make_fleet(fl, [
    FleetClass("highend", fraction=0.5, budget_scale=1.5),
    FleetClass("lowend", fraction=0.5, budget_scale=0.5, compute_scale=1.5),
])

model = build(cfg)
engine = FederatedEngine(model, fl, ds, strategy="cafl", executor="batched",
                         profiles=profiles, client_profiles=client_profiles)
res = engine.run()

print(f"{'round':>5s} | {'tier':>8s} | knobs (k,s,b,q,ga) | ratios E/C/M/T")
for r in res.history:
    for name, slot in sorted(r.per_profile.items()):
        kn, rat = slot["knobs"], slot["ratios"]
        print(f"{r.round:5d} | {name:>8s} | "
              f"({kn['k']},{kn['s']:2d},{kn['b']:2d},{kn['q']},"
              f"{kn['grad_accum']}) | "
              f"{rat['energy']:.2f}/{rat['comm']:.2f}/"
              f"{rat['memory']:.2f}/{rat['temp']:.2f}")
print("\nThe low-end tier's duals bite first: its policy freezes more "
      "layers, cuts local steps, and engages compression while the "
      "high-end tier keeps training near the baseline operating point.")
