"""Constraint controllers: pluggable dual laws + dual-aware deadline
control (the ``repro.constraints`` stack on a live engine).

Part 1 (instant, proxy-only): the same calibrated constraint loop under
the three shipped ``DualController`` laws — the paper's deadzone
subgradient needs tens of rounds to walk a 5x comm blowout down to its
budget; the violation-scaled adaptive step and the PI law close it in a
couple.

Part 2 (tiny engine runs): a fleet whose baseline round exactly misses
a 0.7x-round straggler deadline. Under the paper knob policy every
sampled client drops, so no report ever reaches the server and the dual
update *starves* — the duals stay frozen at zero while the fleet burns
budget, and the knobs that would have made clients faster never engage.
``DeadlineAwareKnobPolicy`` watches the reported fraction, widens the
deadline toward the arrival times the engine observed (plus headroom),
and the Lagrangian loop comes back to life.

    PYTHONPATH=src python examples/constraint_controllers.py

(REPRO_EXAMPLE_ROUNDS caps the engine round budget for CI smoke runs.)
"""
import dataclasses
import os

from repro.configs import get_config, get_fl_config
from repro.constraints import (proxy_control_loop, rounds_to_band,
                               tail_worst_ratio)
from repro.data import load_corpus
from repro.fl import (DeadlineStragglers, FederatedEngine, FleetDynamics,
                      UniformSampler)
from repro.models import build

ROUNDS = int(os.environ.get("REPRO_EXAMPLE_ROUNDS", "6"))

# --- part 1: dual-controller laws on the calibrated proxy loop -----------
fl0 = get_fl_config()
band = 1.0 + fl0.duals.deadzone
print("controller comparison (proxy loop, worst constraint ratio):")
for name in ("deadzone", "adaptive", "pi"):
    history = proxy_control_loop(fl0, controller=name, rounds=60)
    hit = rounds_to_band(history, band)
    print(f"  {name:9s} rounds to enter the {band:.2f} band: "
          f"{hit if hit else '>60'}   tail worst ratio: "
          f"{tail_worst_ratio(history):.2f}")

# --- part 2: dual-aware deadline control on a live engine ----------------
ds = load_corpus(target_bytes=60_000)
cfg = get_config("charlm-shakespeare").replace(
    vocab_size=max(ds.vocab_size, 64), num_layers=3, d_model=48,
    num_heads=4, num_kv_heads=4, head_dim=12, d_ff=96)
fl = get_fl_config().replace(
    rounds=ROUNDS, num_clients=4, clients_per_round=2, s_base=3, b_base=8,
    seq_len=16, eval_batches=1, eval_batch_size=8)
fl = fl.replace(duals=dataclasses.replace(fl.duals, s_min=2, b_min=4))
model = build(cfg)


def dynamics():
    # baseline knobs take exactly 1.0 round of wall clock; the 0.7x
    # deadline is unmeetable, so without deadline control nobody ever
    # reports (jitter 0 keeps it deterministic; carry-over off keeps
    # the clock equal to the knob time)
    return FleetDynamics(
        sampler=UniformSampler(fl.clients_per_round),
        stragglers=DeadlineStragglers.for_config(fl, deadline=0.7,
                                                 jitter=0.0),
        carryover_tokens=False)


print(f"\ndual-aware deadline control ({ROUNDS} engine rounds, "
      f"deadline 0.7x round):")
for label, fl_run in (("paper policy", fl),
                      ("deadline_aware", fl.replace(
                          knob_policy="deadline_aware"))):
    dyn = dynamics()
    res_run = FederatedEngine(model, fl_run, ds, strategy="cafl",
                              dynamics=dyn).run()
    reported = sum(len(r.participants) for r in res_run.history)
    dual_rounds = sum(1 for r in res_run.history
                      if any(lam > 0.0 for lam in r.duals.values()))
    last = res_run.history[-1]
    print(f"  {label:15s} reports={reported:3d}  "
          f"rounds with live duals={dual_rounds}/{ROUNDS}  "
          f"final deadline={dyn.stragglers.deadline:.2f}  "
          f"final lam_E={last.duals['energy']:.2f}")

print("\nThe paper stack never widens the deadline: zero reports, zero "
      "dual movement, frozen knobs. The deadline-aware policy reads the "
      "observed arrival times, widens the deadline just past them, and "
      "the dual update resumes — the constraint loop then shrinks the "
      "knobs, which shortens the rounds it just made feasible.")
