"""Serving-path demo on a reduced assigned architecture: batched prefill
+ sliding-window decode (the long_500k mechanism) on RecurrentGemma.

    PYTHONPATH=src python examples/serve.py [--arch recurrentgemma-2b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.frontend and cfg.frontend.kind == "vision":
        batch["patch_embeds"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.frontend.num_prefix_tokens,
                  cfg.frontend.embed_dim)), jnp.float32)
    if cfg.encdec:
        batch["src_embeds"] = jnp.asarray(rng.normal(
            size=(args.batch, 32, cfg.frontend.embed_dim)), jnp.float32)

    t0 = time.time()
    logits, cache = jax.jit(lambda p, b: model.prefill(
        p, b, max_new_tokens=args.new_tokens))(params, batch)
    jax.block_until_ready(logits)
    print(f"[{args.arch}] prefill {args.batch}x{args.prompt_len} "
          f"in {time.time()-t0:.2f}s -> logits {logits.shape}")

    step = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t0 = time.time()
    for i in range(args.new_tokens):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"decoded {args.new_tokens} tokens/seq in {dt:.2f}s "
          f"({args.batch*args.new_tokens/dt:.1f} tok/s); "
          f"cache is O(window) for local-attn/recurrent blocks")


if __name__ == "__main__":
    main()
