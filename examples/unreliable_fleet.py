"""Unreliable fleet: CAFL-L under K-of-N sampling, churn and stragglers.

The realistic on-device condition the paper's experiments abstract
away: a two-tier fleet where low-end devices are reachable only ~60% of
rounds (Bernoulli churn), the server samples K of the available
clients, and a round deadline drops anything slower than 2x a baseline
round — the slow tier's 2.5x silicon plus log-normal jitter makes it
the usual victim (note Eq. 8's grad-accum overshoot also inflates round
time once the duals shrink s and b, so a deadline below ~1.5 starves
even the fast tier). Dropped clients' token budgets carry to
their next participation as extra gradient accumulation, and the duals
only ever see the usage of clients that actually reported.

    PYTHONPATH=src python examples/unreliable_fleet.py
"""
import dataclasses
import os

from repro.configs import get_config, get_fl_config
from repro.data import load_corpus
from repro.fl import (BernoulliChurn, DeadlineStragglers, FederatedEngine,
                      FleetClass, FleetDynamics, UniformSampler, make_fleet)
from repro.models import build

ROUNDS = int(os.environ.get("REPRO_EXAMPLE_ROUNDS", "8"))

ds = load_corpus(target_bytes=120_000)
cfg = get_config("charlm-shakespeare").replace(
    vocab_size=max(ds.vocab_size, 64), num_layers=3, d_model=96,
    num_heads=4, num_kv_heads=4, head_dim=24, d_ff=192)
fl = get_fl_config().replace(rounds=ROUNDS, num_clients=8, clients_per_round=4,
                             s_base=10, b_base=16, seq_len=32,
                             eval_batches=2, eval_batch_size=32)
fl = fl.replace(duals=dataclasses.replace(fl.duals, s_min=4, b_min=4))

profiles, client_profiles = make_fleet(fl, [
    FleetClass("highend", fraction=0.5, budget_scale=1.5),
    FleetClass("lowend", fraction=0.5, budget_scale=0.5,
               compute_scale=2.5, availability=0.6),
])

dynamics = FleetDynamics(
    sampler=UniformSampler(fl.clients_per_round),
    availability=BernoulliChurn(p=1.0),        # scaled by tier availability
    stragglers=DeadlineStragglers.for_config(fl, deadline=2.0, jitter=0.35),
)

model = build(cfg)
engine = FederatedEngine(model, fl, ds, strategy="cafl", executor="batched",
                         profiles=profiles, client_profiles=client_profiles,
                         dynamics=dynamics)
res = engine.run()

print(f"{'round':>5s} | {'avail':>5s} | {'reported':>16s} | "
      f"{'dropped':>10s} | val")
for r in res.history:
    part = ",".join(str(c) for c in r.participants) or "-"
    drop = ",".join(str(c) for c in r.dropped) or "-"
    print(f"{r.round:5d} | {r.num_available:5d} | {part:>16s} | "
          f"{drop:>10s} | {r.val_loss:.4f}")

n_drops = sum(len(r.dropped) for r in res.history)
n_parts = sum(len(r.participants) for r in res.history)
print(f"\n{n_parts} client-rounds reported, {n_drops} dropped at the "
      f"deadline; every dual update saw survivors only, and each dropped "
      f"client returned with its lost token budget re-credited as extra "
      f"grad-accum.")
