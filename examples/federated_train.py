"""CAFL-L vs FedAvg on a small federated char-LM (a scaled-down version of
the paper's experiment that runs in ~2 minutes on CPU), driven through the
composable engine API: strategy x executor x callbacks.

    PYTHONPATH=src python examples/federated_train.py
"""
import dataclasses
import os

from repro.configs import get_config, get_fl_config
from repro.data import load_corpus
from repro.fl import FederatedEngine, LoggingCallback
from repro.models import build

ROUNDS = int(os.environ.get("REPRO_EXAMPLE_ROUNDS", "6"))

ds = load_corpus(target_bytes=120_000)
cfg = get_config("charlm-shakespeare").replace(
    vocab_size=max(ds.vocab_size, 64), num_layers=3, d_model=96,
    num_heads=4, num_kv_heads=4, head_dim=24, d_ff=192)
fl = get_fl_config().replace(rounds=ROUNDS, num_clients=8, clients_per_round=3,
                             s_base=10, b_base=16, seq_len=32,
                             eval_batches=2, eval_batch_size=32)
fl = fl.replace(duals=dataclasses.replace(fl.duals, s_min=4, b_min=4))

model = build(cfg)
results = {}
for method in ("fedavg", "cafl"):
    print(f"=== {method} ===")
    # "batched" stacks same-knob clients into one jitted vmap'd LocalTrain;
    # "sequential" reproduces the seed loop exactly.
    engine = FederatedEngine(model, fl, ds, strategy=method,
                             executor="batched",
                             callbacks=[LoggingCallback()])
    results[method] = engine.run()

print("\nsummary (tail means):")
for name, res in results.items():
    s = res.summary(tail=3)
    print(f" {name:7s} E={s['energy']:.3g} C={s['comm_mb']:.3f}MB "
          f"M={s['memory']:.3f} T={s['temp']:.3f} val={s['val_loss']:.3f}")
print("\nCAFL-L keeps usage at/below budget while FedAvg violates comm "
      "and memory — see benchmarks/table1.py for the full-paper run, and "
      "examples/heterogeneous_fleet.py for per-device-class budgets.")
