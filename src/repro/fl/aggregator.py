"""Server-update policies: *when* client reports become server updates.

PR 1/2 hard-wired the synchronous round barrier (collect every
survivor, average once per round) into ``FederatedEngine.run``. This
module makes the server-update path a first-class, pluggable axis: the
engine turns every finished client into a ``ClientReport`` event
(delta, weight, arrival time, staleness, device profile) and feeds it
to an ``Aggregator``, which decides when those reports are combined
into ``ServerUpdate``s:

    submit(report) -> Optional[ServerUpdate]   per-arrival (async paths)
    flush(rnd)     -> Optional[ServerUpdate]   end-of-round barrier
    state_snapshot()                           observability

Four policies ship:

    SyncAggregator        the paper's barrier — buffer the round, apply
                          once (bit-for-bit the PR 1/2 behaviour; the
                          golden trajectories pin it)
    FedBuffAggregator     buffered async (Nguyen et al., "Federated
                          Learning with Buffered Asynchronous
                          Aggregation"): apply every K arrivals with
                          staleness-discounted deltas; deadline-missers
                          deliver late instead of being discarded
    StalenessWeighted-    the barrier, but late reports are folded into
    Aggregator            a later round's update under a composable
                          ``StalenessPolicy`` discount
    MaskedSumAggregator   pairwise-mask secure-aggregation simulation
                          (Bonawitz et al., "Practical Secure
                          Aggregation"): fixed-point masked sums whose
                          mask reconstruction stays *exact* under any
                          PR 2 churn/deadline dropout pattern

*How* deltas are combined stays with ``FederatedStrategy.aggregate``
(pure delta combination); the engine binds it via ``reset(combine)``
so ``ServerOpt`` and weighted variants compose with every policy.

Determinism contract (checked by ``repro.analysis.sched``): float
combines are order-sensitive (reassociation changes bits), so every
policy folds its buffered reports in *canonical report order* —
``(round_trained, arrival_time, client_id)``, a total order over any
report set — before touching the combine. That makes the applied
update a pure function of the report *set*, never of delivery order;
each class declares how via ``commutativity``:

    "exact"      order-free by construction (uint64 masked sums are
                 associative/commutative mod 2^64)
    "canonical"  floats folded in canonical order (sync, staleness)
    "tiebreak"   the *buffer composition* depends on delivery order
                 (FedBuff fills every K arrivals), which the engine
                 makes deterministic via ``TimedReport.sort_key``;
                 each fill's fold is canonical-ordered
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import Knobs
from repro.fl.device import ClientInfo

Combine = Callable[[Sequence, Optional[List[float]]], Any]


def report_order_key(report: "ClientReport") -> Tuple[int, float, int]:
    """The canonical total order over client reports: params version
    first (oldest work folds first), then simulated arrival, then the
    client id as the final tie-break. No two distinct reports compare
    equal — client ids are unique within a fold — so a sort under this
    key is schedule-independent."""
    return (report.round_trained, report.arrival_time,
            report.client.client_id)


def canonical_order(reports: Sequence["ClientReport"]
                    ) -> List["ClientReport"]:
    """Sort reports into canonical order (``report_order_key``) so any
    float fold over them is a function of the report *set*, not of the
    delivery schedule. Every aggregator calls this before combining."""
    return sorted(reports, key=report_order_key)


# ---------------------------------------------------------------------------
# events
# ---------------------------------------------------------------------------


@dataclass
class ClientReport:
    """One finished LocalTrain, as the server receives it.

    ``weight`` is the client's example count (shard size) — the single
    place it is routed from; every aggregator hands it to the combine
    function, which renormalizes over whatever subset is present.
    ``staleness`` is ``round_submitted - round_trained``: 0 for clients
    that made the deadline, >0 for late reports delivered by an
    ``accepts_late`` aggregator.
    """
    client: ClientInfo
    delta: Any                    # masked, wire-compressed update tree
    weight: float                 # client example count (|D_i|)
    knobs: Knobs                  # knobs actually trained (incl. carry boost)
    policy_knobs: Knobs           # the strategy's policy knobs (no boost)
    round_trained: int            # params version the delta was computed on
    arrival_time: float = 0.0     # straggler wall-clock draw (0 if untimed)
    round_submitted: int = -1     # set when the server takes delivery
    staleness: int = 0            # round_submitted - round_trained
    train_loss: float = 0.0
    wire_mb_actual: float = 0.0
    params_active: float = 0.0
    usage: Dict[str, float] = field(default_factory=dict)
    energy_true: float = 0.0


@dataclass(frozen=True)
class ServerUpdate:
    """One application of client work to the server params."""
    delta: Any                          # tree to add to params
    reports: Tuple[ClientReport, ...]   # the reports folded in
    round: int                          # server round it was applied
    mean_staleness: float = 0.0


# ---------------------------------------------------------------------------
# staleness policies
# ---------------------------------------------------------------------------


class StalenessPolicy:
    """Maps a report's staleness (rounds late) to a discount in (0, 1].
    Discounts must be non-increasing in staleness and 1.0 at 0."""

    name = "base"

    def discount(self, staleness: int) -> float:
        raise NotImplementedError


class PolynomialStaleness(StalenessPolicy):
    """FedBuff's s(tau) = (1 + tau)^(-alpha); alpha=0 disables."""

    name = "polynomial"

    def __init__(self, alpha: float = 0.5):
        assert alpha >= 0.0
        self.alpha = alpha

    def discount(self, staleness: int) -> float:
        assert staleness >= 0
        return float((1.0 + staleness) ** (-self.alpha))


class ConstantStaleness(StalenessPolicy):
    """Fresh reports count fully; any late report a constant factor."""

    name = "constant"

    def __init__(self, factor: float = 0.5):
        assert 0.0 < factor <= 1.0
        self.factor = factor

    def discount(self, staleness: int) -> float:
        assert staleness >= 0
        return 1.0 if staleness == 0 else self.factor


def make_staleness_policy(spec) -> StalenessPolicy:
    if isinstance(spec, StalenessPolicy):
        return spec
    name = spec.lower()
    if name in ("polynomial", "poly"):
        return PolynomialStaleness()
    if name == "constant":
        return ConstantStaleness()
    if name == "none":
        return PolynomialStaleness(alpha=0.0)
    raise ValueError(f"unknown staleness policy {spec!r}; "
                     f"options: polynomial, constant, none")


def _scale_delta(delta, factor: float):
    if factor == 1.0:
        return delta
    return jax.tree.map(lambda l: l.astype(jnp.float32) * factor, delta)


# ---------------------------------------------------------------------------
# the protocol
# ---------------------------------------------------------------------------


class Aggregator:
    """Server-update policy. The engine drives one instance per run:

        reset(combine)          bind the strategy's pure combine fn
        begin_round(rnd, cohort)  the sampled cohort is fixed (secure
                                aggregation needs it to agree masks)
        submit(report)          one report arrived; may emit an update
        flush(rnd)              the round barrier; may emit an update

    ``accepts_late = True`` tells the engine to *execute* deadline
    missers and deliver their reports in the round their simulated
    wall clock lands in, instead of discarding them.

    ``applies_mid_round = True`` marks policies whose ``submit`` can
    emit an update before the round barrier (FedBuff). Under
    ``time_mode="wall_clock"`` such an update is the "buffer completes"
    event that *ends* the round: the next round begins at its simulated
    time, so buffered-async rounds are exactly as long as their fills.

    ``commutativity`` is the policy's certificate under report-order
    permutation (see the module docstring): "exact", "canonical" or
    "tiebreak". ``repro.analysis.sched`` reads it to decide whether two
    HB-unordered deliveries into the same aggregator state are benign;
    a policy that declares none is flagged as a schedule race.
    """

    name = "base"
    accepts_late = False
    applies_mid_round = False
    commutativity: Optional[str] = None

    def __init__(self):
        self._combine: Optional[Combine] = None
        self._applied = 0

    def reset(self, combine: Combine) -> None:
        self._combine = combine
        self._applied = 0

    def begin_round(self, rnd: int, cohort: Sequence[ClientInfo]) -> None:
        pass

    def submit(self, report: ClientReport) -> Optional[ServerUpdate]:
        raise NotImplementedError

    def flush(self, rnd: int) -> Optional[ServerUpdate]:
        return None

    def finalize(self, rnd: int) -> Optional[ServerUpdate]:
        """Training is over: drain whatever the policy still buffers so
        executed work is never silently discarded. Barrier aggregators
        have nothing left after ``flush``; FedBuff applies its partial
        buffer."""
        return None

    def state_snapshot(self) -> Dict[str, Any]:
        return {"name": self.name, "updates_applied": self._applied}

    # -- helpers -------------------------------------------------------------
    def _emit(self, rnd: int, reports: Sequence[ClientReport],
              delta) -> ServerUpdate:
        self._applied += 1
        # canonical order all the way out: ServerUpdate.reports and the
        # staleness fold are schedule-independent like the delta itself
        reports = canonical_order(reports)
        stale = (float(np.mean([r.staleness for r in reports]))
                 if reports else 0.0)
        return ServerUpdate(delta=delta, reports=tuple(reports), round=rnd,
                            mean_staleness=stale)


class SyncAggregator(Aggregator):
    """The paper's round barrier: buffer every report of the round and
    apply one combined update at ``flush``. Default; stream- and
    bit-identical to the PR 1/2 engine (golden trajectories pin it)."""

    name = "sync"
    commutativity = "canonical"

    def __init__(self):
        super().__init__()
        self._buf: List[ClientReport] = []

    def reset(self, combine):
        super().reset(combine)
        self._buf = []

    def submit(self, report):
        self._buf.append(report)
        return None

    def flush(self, rnd):
        if not self._buf:
            return None
        reports, self._buf = self._buf, []
        reports = canonical_order(reports)
        delta = self._combine([r.delta for r in reports],
                              [r.weight for r in reports])
        return self._emit(rnd, reports, delta)

    def state_snapshot(self):
        return {**super().state_snapshot(), "buffered": len(self._buf)}


class StalenessWeightedAggregator(Aggregator):
    """The barrier, minus the discard: deadline-missers deliver in the
    round their wall clock lands in and are folded into that round's
    update under a ``StalenessPolicy`` discount.

    ``mode="scale"`` (default) multiplies the late delta itself by the
    discount — an absolute attenuation that works under any combine,
    including the paper's unweighted mean. ``mode="weight"`` multiplies
    the report's example-count weight instead — a relative reweighting
    that only bites with weight-respecting combines (FedAvg
    ``weighted=True``)."""

    name = "staleness"
    accepts_late = True
    commutativity = "canonical"

    def __init__(self, policy: Optional[StalenessPolicy] = None,
                 mode: str = "scale"):
        super().__init__()
        assert mode in ("scale", "weight")
        self.policy = policy or PolynomialStaleness()
        self.mode = mode
        self._buf: List[ClientReport] = []

    def reset(self, combine):
        super().reset(combine)
        self._buf = []

    def submit(self, report):
        self._buf.append(report)
        return None

    def flush(self, rnd):
        if not self._buf:
            return None
        reports, self._buf = self._buf, []
        reports = canonical_order(reports)
        discounts = [self.policy.discount(r.staleness) for r in reports]
        if self.mode == "scale":
            deltas = [_scale_delta(r.delta, d)
                      for r, d in zip(reports, discounts)]
            weights = [r.weight for r in reports]
        else:
            deltas = [r.delta for r in reports]
            weights = [r.weight * d for r, d in zip(reports, discounts)]
        return self._emit(rnd, reports, self._combine(deltas, weights))

    def state_snapshot(self):
        return {**super().state_snapshot(), "buffered": len(self._buf),
                "policy": self.policy.name, "mode": self.mode}


class FedBuffAggregator(Aggregator):
    """Buffered asynchronous aggregation (FedBuff): every report lands
    in a buffer; once ``buffer_size`` reports have arrived the server
    applies their combined, staleness-discounted update immediately —
    mid-round, without waiting for the barrier. Late reporters are
    *used* (discounted by ``policy``) instead of discarded; the buffer
    persists across round boundaries, so ``flush`` is a no-op."""

    name = "fedbuff"
    accepts_late = True
    applies_mid_round = True
    commutativity = "tiebreak"

    def __init__(self, buffer_size: int = 4,
                 policy: Optional[StalenessPolicy] = None):
        super().__init__()
        assert buffer_size >= 1
        self.buffer_size = buffer_size
        self.policy = policy or PolynomialStaleness()
        self._buf: List[ClientReport] = []

    def reset(self, combine):
        super().reset(combine)
        self._buf = []

    def submit(self, report):
        self._buf.append(report)
        if len(self._buf) < self.buffer_size:
            return None
        return self._apply_buffer(report.round_submitted)

    def finalize(self, rnd):
        """Drain the partial buffer at run end: those clients trained,
        were accounted as participants, and repaid debt — their work
        must reach the model."""
        if not self._buf:
            return None
        return self._apply_buffer(rnd)

    def _apply_buffer(self, rnd):
        reports, self._buf = self._buf, []
        reports = canonical_order(reports)
        # staleness is measured at APPLY time (FedBuff's tau): a report
        # that sat in the buffer across rounds aged while earlier fills
        # moved the params, so its discount must keep accruing
        for r in reports:
            r.staleness = max(r.staleness, rnd - r.round_trained)
        deltas = [_scale_delta(r.delta, self.policy.discount(r.staleness))
                  for r in reports]
        delta = self._combine(deltas, [r.weight for r in reports])
        return self._emit(rnd, reports, delta)

    def state_snapshot(self):
        return {**super().state_snapshot(), "buffered": len(self._buf),
                "buffer_size": self.buffer_size, "policy": self.policy.name}


class MaskedSumAggregator(Aggregator):
    """Pairwise-mask secure-aggregation simulation (Bonawitz et al.).

    Every sampled client's weighted delta is quantized to a fixed-point
    grid (``scale_bits`` fractional bits) and blinded with one pairwise
    mask per cohort partner: client ``min(i,j)`` adds ``m_ij``, client
    ``max(i,j)`` subtracts it, all mod 2^64. The server only ever sums
    masked vectors — modular integer arithmetic, so cancellation is
    *exact*, not approximate. When a sampled client drops (churn or
    deadline), the server reconstructs the dropped client's pairwise
    masks (standing in for the protocol's secret-share recovery) and
    removes them, so the unmasked total equals the plain fixed-point
    weighted sum of the reporters bit-for-bit under every dropout
    combination.

    The unmasked mean then flows through the strategy's combine as a
    single pre-combined delta, so ``ServerOpt`` still composes. The
    default is the paper's unweighted mean — the same combination rule
    every other aggregator defaults to, so swapping ``"sync"`` for
    ``"masked"`` changes only *how securely*, not *what* is computed;
    ``use_weights=True`` gives the |D_i|-weighted variant.

    ``path`` picks the cohort-fold backend: ``"kernel"`` (default)
    buffers each client's masked uint64 vector and folds the stacked
    cohort through ``repro.kernels.ops.masked_sum`` (the Pallas
    fixed-point masked-sum kernel — one bandwidth-bound pass) at
    flush; ``"numpy"`` keeps the sequential per-arrival uint64
    accumulation as the exactness oracle. Modular sums are
    associative, so the two paths are bit-identical under every
    dropout combination.
    """

    name = "masked"
    commutativity = "exact"

    def __init__(self, scale_bits: int = 32, use_weights: bool = False,
                 seed: int = 0, path: str = "kernel"):
        super().__init__()
        # the *weighted* fixed-point values must fit int64 with headroom
        # for the cohort sum; _quantize guards this at runtime, since
        # the bound depends on the weights (shard sizes) actually seen
        assert 1 <= scale_bits <= 52
        assert path in ("kernel", "numpy"), path
        self.scale = float(2 ** scale_bits)
        self.use_weights = use_weights
        self.seed = seed
        self.path = path
        self._round = 0
        self._cohort: List[int] = []
        self._reporters: List[ClientReport] = []
        self._sum: Optional[List[np.ndarray]] = None
        self._pending: List[List[np.ndarray]] = []
        self._treedef = None
        self._reconstructed = 0

    def reset(self, combine):
        super().reset(combine)
        self._cohort, self._reporters, self._sum = [], [], None
        self._pending = []
        self._reconstructed = 0

    def begin_round(self, rnd, cohort):
        self._round = rnd
        self._cohort = [ci.client_id for ci in cohort]
        self._reporters = []
        self._sum = None
        self._pending = []
        self._treedef = None

    # -- fixed-point + masks -------------------------------------------------
    def _weight(self, report: ClientReport) -> float:
        return report.weight if self.use_weights else 1.0

    def _quantize(self, delta, weight: float) -> Tuple[List[np.ndarray], Any]:
        leaves, treedef = jax.tree.flatten(delta)
        # np.int64 casts of out-of-range floats are silent garbage, so
        # the exactness guarantee needs an explicit overflow guard: each
        # weighted value must leave room for the whole cohort to sum
        # without leaving int64 (drop scale_bits or pre-scale weights
        # when this trips)
        limit = 2.0 ** 62 / max(1, len(self._cohort))
        q = []
        for leaf in leaves:
            vals = np.rint(np.asarray(leaf, np.float64) * weight * self.scale)
            assert np.all(np.abs(vals) < limit), \
                (f"masked-sum fixed point overflow: |delta * weight| * "
                 f"2^scale_bits exceeds int64 headroom ({self.scale:g} * "
                 f"weight {weight:g}); lower scale_bits or the weights")
            q.append(vals.astype(np.int64).view(np.uint64))
        return q, treedef

    def _pair_masks(self, a: int, b: int,
                    like: List[np.ndarray]) -> List[np.ndarray]:
        lo, hi = (a, b) if a < b else (b, a)
        rng = np.random.default_rng([self.seed, self._round, lo, hi])
        return [rng.integers(0, 2 ** 64, size=l.shape, dtype=np.uint64)
                for l in like]

    def _add_masks(self, vec: List[np.ndarray], me: int, partner: int,
                   sign: int) -> List[np.ndarray]:
        masks = self._pair_masks(me, partner, vec)
        flip = 1 if me < partner else -1
        if sign * flip > 0:
            return [v + m for v, m in zip(vec, masks)]
        return [v - m for v, m in zip(vec, masks)]

    # -- protocol ------------------------------------------------------------
    def submit(self, report):
        assert report.client.client_id in self._cohort, \
            "masked sums need the cohort fixed before reports arrive"
        vec, treedef = self._quantize(report.delta, self._weight(report))
        me = report.client.client_id
        for partner in self._cohort:
            if partner != me:
                vec = self._add_masks(vec, me, partner, sign=+1)
        if self.path == "kernel":
            # buffer the masked vector; the cohort folds in one kernel
            # pass at flush instead of C sequential accumulations
            self._pending.append(vec)
            self._treedef = treedef
        elif self._sum is None:
            self._sum, self._treedef = vec, treedef
        else:
            self._sum = [a + b for a, b in zip(self._sum, vec)]
        self._reporters.append(report)
        return None

    def _kernel_fold(self) -> List[np.ndarray]:
        """Fold the buffered cohort mod 2^64 via the masked-sum kernel."""
        from repro.kernels import ops
        shapes = [v.shape for v in self._pending[0]]
        sizes = [v.size for v in self._pending[0]]
        stacked = np.stack([np.concatenate([l.reshape(-1) for l in vec])
                            for vec in self._pending])
        tot = ops.masked_sum_u64(stacked)
        out, off = [], 0
        for shape, size in zip(shapes, sizes):
            out.append(tot[off:off + size].reshape(shape))
            off += size
        return out

    def flush(self, rnd):
        if not self._reporters:
            return None
        total = self._kernel_fold() if self.path == "kernel" else self._sum
        reported = {r.client.client_id for r in self._reporters}
        for dropped in (c for c in self._cohort if c not in reported):
            # mask recovery: remove the masks reporters shared with the
            # dropped client (the live pairs already cancelled in-sum)
            for alive in sorted(reported):
                total = self._add_masks(total, alive, dropped, sign=-1)
                self._reconstructed += 1
        # the masked fold itself is exact mod 2^64 in any order; the
        # float weight total still folds canonically so the dequantized
        # mean is schedule-independent bit-for-bit too
        reports = canonical_order(self._reporters)
        tot_w = sum(self._weight(r) for r in reports)
        leaves = [jnp.asarray(
            (x.view(np.int64).astype(np.float64)
             / (self.scale * tot_w)).astype(np.float32))
            for x in total]
        mean = jax.tree.unflatten(self._treedef, leaves)
        self._reporters, self._sum, self._pending = [], None, []
        # the masked protocol fixes the combination to a weighted mean;
        # hand it through combine as one delta so ServerOpt composes
        return self._emit(rnd, reports, self._combine([mean], [1.0]))

    def state_snapshot(self):
        return {**super().state_snapshot(), "cohort": len(self._cohort),
                "pending": len(self._reporters), "path": self.path,
                "masks_reconstructed": self._reconstructed}


# ---------------------------------------------------------------------------
# trace-analysis entry points (repro.analysis.trace)
# ---------------------------------------------------------------------------

#: cohort size the combine entries are traced at (TRACE003 scales its
#: dense-materialization threshold with this)
TRACE_COHORT = 4


def _combine_build(weighted: bool):
    def build():
        from repro.core.aggregation import aggregate
        delta = {"w": jnp.zeros((64, 64), jnp.float32),
                 "b": jnp.zeros((64,), jnp.float32)}
        deltas = tuple(jax.tree.map(jnp.array, delta)
                       for _ in range(TRACE_COHORT))
        weights = ([1.0, 2.0, 3.0, 4.0] if weighted else None)

        def combine(*ds):
            return aggregate(list(ds), weights)

        return combine, deltas
    return build


def trace_entry_points() -> List[object]:
    """Declared traceable surfaces: the pure delta combines every
    aggregator policy funnels through (O(P) incremental folds — the
    TRACE003 rule proves no O(C*P) stack sneaks back in)."""
    from repro.analysis.trace.registry import EntryPoint
    path = "src/repro/fl/aggregator.py"
    return [
        EntryPoint(name="fl.aggregate_sync", path=path, line=246,
                   build=_combine_build(False), cohort=TRACE_COHORT,
                   note=f"unweighted mean combine, C={TRACE_COHORT}"),
        EntryPoint(name="fl.aggregate_weighted", path=path, line=246,
                   build=_combine_build(True), cohort=TRACE_COHORT,
                   note=f"|D_i|-weighted combine, C={TRACE_COHORT}"),
    ]


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------

AGGREGATORS = ("sync", "fedbuff", "staleness", "masked")


def make_aggregator(spec, fl=None, **kw) -> Aggregator:
    """Resolve an aggregator spec: an instance passes through; strings
    name a policy ("sync", "fedbuff", "staleness", "masked"). ``fl``
    sizes FedBuff's default buffer at half the sampled cohort."""
    if isinstance(spec, Aggregator):
        return spec
    name = spec.lower()
    if name == "sync":
        return SyncAggregator(**kw)
    if name == "fedbuff":
        if "buffer_size" not in kw and fl is not None:
            kw["buffer_size"] = max(2, (fl.clients_per_round + 1) // 2)
        return FedBuffAggregator(**kw)
    if name in ("staleness", "staleness_weighted"):
        return StalenessWeightedAggregator(**kw)
    if name in ("masked", "masked_sum", "secagg"):
        return MaskedSumAggregator(**kw)
    raise ValueError(f"unknown aggregator {spec!r}; "
                     f"options: {', '.join(AGGREGATORS)}")
