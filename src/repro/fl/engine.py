"""The composable federated engine (Algorithm 1 as pure control flow).

``FederatedEngine`` wires four independently replaceable pieces:

    strategy  — FederatedStrategy: knobs / aggregation / dual state
    executor  — ClientExecutor: how LocalTrain actually runs (sequential
                Python loop vs one jitted vmap over stacked clients)
    profiles  — DeviceProfile map: per-device-class budgets + resource
                models (the paper's homogeneous fleet is the default)
    callbacks — RoundCallback hooks for logging / checkpoints / timing

``repro.core.server.run_federated`` is a thin wrapper over this class
that preserves the seed API exactly.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence, Union

import jax
import numpy as np

from repro.configs.base import FLConfig
from repro.core import aggregation
from repro.core.client import ClientRunner
from repro.core.duals import RESOURCES, DualState, usage_ratios
from repro.core.resources import ResourceModel, calibrate
from repro.core.server import FLResult, RoundRecord, make_eval_fn
from repro.data.federated import FederatedData
from repro.data.shakespeare import CharDataset
from repro.fl.callbacks import RoundCallback
from repro.fl.device import (DEFAULT_PROFILE, ClientInfo, DeviceProfile,
                             uniform_fleet)
from repro.fl.executor import ClientExecutor, make_executor
from repro.fl.strategy import FederatedStrategy, make_strategy
from repro.models.zoo import Model

ExecutorSpec = Union[str, Callable[[ClientRunner], ClientExecutor]]


class FederatedEngine:
    def __init__(self, model: Model, fl: FLConfig, dataset: CharDataset,
                 strategy: Union[str, FederatedStrategy, None] = None,
                 executor: Optional[ExecutorSpec] = None,
                 profiles: Optional[Dict[str, DeviceProfile]] = None,
                 client_profiles: Optional[Sequence[str]] = None,
                 callbacks: Sequence[RoundCallback] = (),
                 resources: Optional[ResourceModel] = None,
                 init_duals: Optional[DualState] = None):
        self.model = model
        self.fl = fl
        self.dataset = dataset
        if strategy is None:
            strategy = fl.method
        self.strategy = (make_strategy(strategy, fl, init_duals=init_duals)
                         if isinstance(strategy, str) else strategy)
        self._executor_spec: ExecutorSpec = executor or fl.executor
        if profiles is None:
            profiles, client_profiles = uniform_fleet(fl)
        assert client_profiles is not None and \
            len(client_profiles) == fl.num_clients, \
            "client_profiles must name a profile for every client"
        self._profiles_raw = profiles
        self._client_profiles = list(client_profiles)
        self.callbacks = list(callbacks)
        self._base_resources = resources

        self.data = FederatedData(dataset.train, fl.num_clients, seed=fl.seed,
                                  noniid_alpha=fl.noniid_alpha)
        self.params = None            # live during run(); callbacks read it
        self.profiles: Dict[str, DeviceProfile] = {}

    # ------------------------------------------------------------------
    def _setup(self, init_params):
        fl = self.fl
        params = init_params if init_params is not None else \
            self.model.init(jax.random.PRNGKey(fl.seed))
        # calibrate proxies at the baseline operating point (all layers
        # active) and specialize per device profile
        base = self._base_resources
        if base is None:
            from repro.core.freezing import count_params
            base = calibrate(count_params(params), fl)
        self.profiles = {name: p.with_resources(base)
                         for name, p in self._profiles_raw.items()}
        runner = ClientRunner(self.model, fl, self.data, base)
        executor = (make_executor(self._executor_spec, runner)
                    if isinstance(self._executor_spec, str)
                    else self._executor_spec(runner))
        return params, runner, executor

    def _client_info(self, cid: int) -> ClientInfo:
        profile = self.profiles[self._client_profiles[cid]]
        return ClientInfo(client_id=cid, profile=profile,
                          shard_size=self.data.shard_size(cid))

    def _emit(self, hook: str, *args) -> None:
        for cb in self.callbacks:
            getattr(cb, hook)(self, *args)

    # ------------------------------------------------------------------
    def run(self, rounds: Optional[int] = None, init_params=None) -> FLResult:
        fl = self.fl
        rounds = rounds or fl.rounds
        rng = np.random.default_rng(fl.seed)
        params, runner, executor = self._setup(init_params)
        evaluate = make_eval_fn(self.model, self.dataset, fl)
        result = FLResult(method=self.strategy.name)
        heterogeneous = len(self.profiles) > 1

        self.params = params
        self._emit("on_train_start")
        for t in range(1, rounds + 1):
            t0 = time.time()
            self._emit("on_round_start", t)
            val_loss = evaluate(params)
            cids = rng.choice(fl.num_clients, size=fl.clients_per_round,
                              replace=False)
            clients = [self._client_info(int(c)) for c in cids]
            knobs = self.strategy.configure_round(t, clients)

            outs = executor.run_round(params, list(zip(clients, knobs)))

            weights = [float(ci.shard_size) for ci in clients]
            delta = self.strategy.aggregate([o.delta for o in outs], weights)
            params = aggregation.apply_delta(params, delta)
            self.params = params

            usages = [ci.profile.resources.usage(o.params_active, kn)
                      for ci, kn, o in zip(clients, knobs, outs)]
            energy_true = [
                ci.profile.resources.usage(o.params_active, kn,
                                           include_accum=True)["energy"]
                for ci, kn, o in zip(clients, knobs, outs)]
            usage = {r: float(np.mean([u[r] for u in usages]))
                     for r in RESOURCES}
            ratios = usage_ratios(usage, fl.budgets)
            duals_by_profile = self.strategy.update_state(usages, clients)

            record = RoundRecord(
                round=t, val_loss=val_loss, knobs=knobs[0].as_dict(),
                usage=usage, ratios=ratios,
                duals=_default_duals(duals_by_profile),
                train_loss=float(np.mean([o.train_loss for o in outs])),
                wire_mb_actual=float(np.mean([o.wire_mb_actual
                                              for o in outs])),
                energy_true=float(np.mean(energy_true)),
                seconds=time.time() - t0,
                per_profile=_per_profile_record(
                    clients, knobs, usages, duals_by_profile)
                if heterogeneous else {})
            result.history.append(record)
            self._emit("on_round_end", record)

        result.final_params = params
        result.history[-1].val_loss = evaluate(params)
        self._emit("on_train_end", result)
        return result


def _default_duals(duals_by_profile: Dict[str, Dict[str, float]]
                   ) -> Dict[str, float]:
    """The record's back-compat scalar dual dict: the default profile's
    duals, the sole profile's, or zeros (fedavg keeps no duals)."""
    if DEFAULT_PROFILE in duals_by_profile:
        return dict(duals_by_profile[DEFAULT_PROFILE])
    if duals_by_profile:
        return dict(next(iter(duals_by_profile.values())))
    return {r: 0.0 for r in RESOURCES}


def _per_profile_record(clients: List[ClientInfo], knobs, usages,
                        duals_by_profile) -> Dict[str, Dict]:
    out: Dict[str, Dict] = {}
    for ci, kn, u in zip(clients, knobs, usages):
        name = ci.profile.name
        slot = out.setdefault(name, {"clients": 0, "knobs": kn.as_dict(),
                                     "usage": {r: 0.0 for r in RESOURCES}})
        slot["clients"] += 1
        for r in RESOURCES:
            slot["usage"][r] += u[r]
    for name, slot in out.items():
        n = slot["clients"]
        slot["usage"] = {r: v / n for r, v in slot["usage"].items()}
        profile = next(ci.profile for ci in clients
                       if ci.profile.name == name)
        slot["ratios"] = usage_ratios(slot["usage"], profile.budgets)
        if name in duals_by_profile:
            slot["duals"] = dict(duals_by_profile[name])
    return out
