"""The composable federated engine (Algorithm 1 as pure control flow).

``FederatedEngine`` wires six independently replaceable pieces:

    strategy   — FederatedStrategy: knobs / pure delta combination /
                 dual state. A CAFLL strategy carries its own pluggable
                 constraint stack (repro.constraints): the engine asks
                 it what to *measure* (strategy.constraints), feeds the
                 per-report measurements back for the dual update, then
                 emits on_dual_update with the per-constraint reports
                 and lets the strategy observe the round (plan, reports,
                 dynamics) so knob policies can steer server-side knobs
                 like the straggler deadline
    executor   — ClientExecutor: how LocalTrain actually runs
                 (sequential Python loop vs one jitted vmap over
                 stacked clients)
    profiles   — DeviceProfile map: per-device-class budgets + resource
                 models (the paper's homogeneous fleet is the default)
    dynamics   — FleetDynamics: availability gating x client sampling x
                 deadline stragglers (the default bundle reproduces the
                 always-available uniform-K-of-N loop bit-for-bit)
    aggregator — Aggregator: *when* client reports become server
                 updates (sync barrier / FedBuff buffered async /
                 staleness-discounted late delivery / masked sums)
    callbacks  — RoundCallback hooks for logging / checkpoints / timing

The loop is event-driven over client reports: every finished client
becomes a ``ClientReport`` (delta, weight, arrival time, staleness,
profile) fed to ``aggregator.submit``; the aggregator decides when a
``ServerUpdate`` is applied. With an ``accepts_late`` aggregator,
clients that miss the round deadline are still executed and their
report is delivered in the round their ``StragglerModel`` wall-clock
draw lands in, with ``staleness = delivery_round - training_round`` —
late work is *used* instead of discarded. While the report is in
flight the client is busy (off the sampling roster); at run end the
engine drains any partial async buffer (``Aggregator.finalize``).
Only truly lost clients (no arrival time, a barrier aggregator, or a
delivery past the run horizon) feed the dropout ledger.

The engine runs in one of two *time modes* (``repro.fl.clock``):

    time_mode="rounds"      the seed semantics — the loop advances in
                            abstract rounds, late reports deliver a
                            ``ceil(t/deadline) - 1`` round delay after
                            their training round. Bit-for-bit identical
                            to the pre-clock engine (golden-pinned).
    time_mode="wall_clock"  a ``SimClock`` advances on events: a round
                            begins when the previous barrier/buffer
                            event completes, barrier rounds last until
                            their survivors finished (or the deadline,
                            when someone missed it), a buffered-async
                            round ends at its first mid-round server
                            update, and late reports land at their
                            simulated *arrival time* instead of a round
                            delay. ``run(horizon_seconds=...)`` replaces
                            the fixed round count with a simulated-
                            seconds budget.

``repro.core.server.run_federated`` is a thin wrapper over this class
that preserves the seed API exactly.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence, Union

import jax
import numpy as np

from repro.configs.base import FLConfig
from repro.constraints import ConstraintSet, paper_constraints
from repro.core import aggregation
from repro.core.client import ClientRunner
from repro.core.duals import DualState
from repro.core.resources import ResourceModel, calibrate
from repro.core.server import FLResult, RoundRecord, make_eval_fn
from repro.data.federated import FederatedData
from repro.data.shakespeare import CharDataset
from repro.fl.aggregator import (Aggregator, ClientReport, ServerUpdate,
                                 canonical_order, make_aggregator)
from repro.fl.callbacks import RoundCallback
from repro.fl.clock import (TIME_MODES, EventQueue, RoundTimeModel, SimClock,
                            make_round_time)
from repro.fl.device import (DEFAULT_PROFILE, ClientInfo, DeviceProfile,
                             uniform_fleet)
from repro.fl.dynamics import FleetDynamics, RoundPlan
from repro.fl.executor import ClientExecutor, make_executor
from repro.fl.strategy import FederatedStrategy, make_strategy
from repro.models.zoo import Model

ExecutorSpec = Union[str, Callable[[ClientRunner], ClientExecutor]]


class FederatedEngine:
    def __init__(self, model: Model, fl: FLConfig, dataset: CharDataset,
                 strategy: Union[str, FederatedStrategy, None] = None,
                 executor: Optional[ExecutorSpec] = None,
                 profiles: Optional[Dict[str, DeviceProfile]] = None,
                 client_profiles: Optional[Sequence[str]] = None,
                 dynamics: Optional[FleetDynamics] = None,
                 aggregator: Union[str, Aggregator, None] = None,
                 callbacks: Sequence[RoundCallback] = (),
                 resources: Optional[ResourceModel] = None,
                 init_duals: Optional[DualState] = None,
                 round_time: Union[str, RoundTimeModel, None] = None,
                 event_queue: Optional[Callable[[], EventQueue]] = None):
        self.model = model
        self.fl = fl
        self.dataset = dataset
        if strategy is None:
            strategy = fl.method
        self.strategy = (make_strategy(strategy, fl, init_duals=init_duals)
                         if isinstance(strategy, str) else strategy)
        self._executor_spec: ExecutorSpec = executor or fl.executor
        if profiles is None:
            profiles, client_profiles = uniform_fleet(fl)
        assert client_profiles is not None and \
            len(client_profiles) == fl.num_clients, \
            "client_profiles must name a profile for every client"
        self._profiles_raw = profiles
        self._client_profiles = list(client_profiles)
        self.dynamics = dynamics or FleetDynamics.default(fl)
        self.aggregator = make_aggregator(aggregator or fl.aggregator, fl)
        self.callbacks = list(callbacks)
        self._base_resources = resources
        self.round_time = make_round_time(round_time, fl)
        # wall-clock event-queue factory: the schedule sanitizer
        # (repro.analysis.sched) swaps in a queue that stamps
        # adversarial tie-breaks; None keeps the plain EventQueue
        self.event_queue_factory = event_queue

        self.data = FederatedData(dataset.train, fl.num_clients, seed=fl.seed,
                                  noniid_alpha=fl.noniid_alpha)
        self.params = None            # live during run(); callbacks read it
        self.profiles: Dict[str, DeviceProfile] = {}
        self.time_mode = fl.time_mode  # resolved per run()
        self.clock: Optional[SimClock] = None
        self._runner_cache = None     # (params0, runner, executor)

    # ------------------------------------------------------------------
    def _setup(self, init_params):
        fl = self.fl
        params = init_params if init_params is not None else \
            self.model.init(jax.random.PRNGKey(fl.seed))
        # calibrate proxies at the baseline operating point (all layers
        # active) and specialize per device profile
        base = self._base_resources
        if base is None:
            from repro.core.freezing import count_params
            base = calibrate(count_params(params), fl)
        self.profiles = {name: p.with_resources(base)
                         for name, p in self._profiles_raw.items()}
        # the runner/executor pair is stateless across runs (it holds
        # only jit caches) — reuse it so repeated run() calls on one
        # engine (the schedule sanitizer replays a run many times) pay
        # compilation once
        if self._runner_cache is None:
            runner = ClientRunner(self.model, fl, self.data, base)
            executor = (make_executor(self._executor_spec, runner)
                        if isinstance(self._executor_spec, str)
                        else self._executor_spec(runner))
            self._runner_cache = (runner, executor)
        runner, executor = self._runner_cache
        return params, runner, executor

    def _client_info(self, cid: int) -> ClientInfo:
        profile = self.profiles[self._client_profiles[cid]]
        return ClientInfo(client_id=cid, profile=profile,
                          shard_size=self.data.shard_size(cid))

    def _emit(self, hook: str, *args) -> None:
        for cb in self.callbacks:
            getattr(cb, hook)(self, *args)

    def _report(self, ci: ClientInfo, kn, policy_kn, out, rnd: int,
                arrival: float) -> ClientReport:
        """Wrap one executor result as the server-side report event.
        ``weight`` routes the client's example count into aggregation —
        the single source every combine path reads it from."""
        usage = ci.profile.resources.usage(out.params_active, kn)
        energy = ci.profile.resources.usage(out.params_active, kn,
                                            include_accum=True)["energy"]
        return ClientReport(client=ci, delta=out.delta,
                            weight=float(ci.shard_size), knobs=kn,
                            policy_knobs=policy_kn, round_trained=rnd,
                            arrival_time=arrival,
                            train_loss=out.train_loss,
                            wire_mb_actual=out.wire_mb_actual,
                            params_active=out.params_active,
                            usage=usage, energy_true=energy)

    # ------------------------------------------------------------------
    def run(self, rounds: Optional[int] = None, init_params=None,
            time_mode: Optional[str] = None,
            horizon_seconds: Optional[float] = None) -> FLResult:
        """Run the federated loop.

        ``time_mode`` overrides ``fl.time_mode`` ("rounds" default;
        "wall_clock" advances a ``SimClock`` on events). A
        ``horizon_seconds`` budget (argument or ``fl.horizon_seconds``)
        implies wall-clock mode and replaces the fixed round count: the
        loop runs until the clock passes the horizon, and late reports
        that could only land beyond it are lost, exactly like rounds
        past ``rounds`` in the seed semantics. Explicit arguments beat
        the config: ``run(time_mode="rounds")`` stays in rounds mode
        even when ``fl.horizon_seconds`` is set (the config horizon is
        ignored), while combining an explicit horizon *argument* with
        an explicit non-wall-clock mode is a contradiction and raises.
        """
        fl = self.fl
        if time_mode is None:
            # no explicit mode: the config decides, and a horizon
            # (argument or config) implies wall clock
            if horizon_seconds is None:
                horizon_seconds = fl.horizon_seconds
            time_mode = ("wall_clock" if horizon_seconds is not None
                         else fl.time_mode)
        else:
            # explicit mode wins over the config horizon
            if horizon_seconds is None and time_mode == "wall_clock":
                horizon_seconds = fl.horizon_seconds
            if horizon_seconds is not None and time_mode != "wall_clock":
                raise ValueError(
                    f"horizon_seconds requires time_mode='wall_clock', "
                    f"got {time_mode!r}")
        if time_mode not in TIME_MODES:
            raise ValueError(f"unknown time_mode {time_mode!r}; "
                             f"options: {', '.join(TIME_MODES)}")
        wall = time_mode == "wall_clock"
        self.time_mode = time_mode
        explicit_rounds = rounds is not None
        rounds = rounds or fl.rounds
        # a horizon run is bounded by simulated seconds, not the round
        # count — unless the caller ALSO passed an explicit round count,
        # which stays a hard cap (arguments beat the config here too).
        # The backstop only stops a zero-length-round bug from spinning
        # forever (round durations are validated positive below).
        max_rounds = (rounds if horizon_seconds is None or explicit_rounds
                      else 100_000)
        rng = np.random.default_rng(fl.seed)
        params, runner, executor = self._setup(init_params)
        evaluate = make_eval_fn(self.model, self.dataset, fl)
        result = FLResult(method=self.strategy.name)
        heterogeneous = len(self.profiles) > 1
        # what the server measures each round: the strategy's constraint
        # set when it carries one (CAFLL), else the paper's four proxies
        cset: ConstraintSet = (getattr(self.strategy, "constraints", None)
                               or paper_constraints())

        dynamics = self.dynamics
        dynamics.reset()
        self.strategy.reset()
        agg = self.aggregator
        agg.reset(self.strategy.aggregate)
        fleet = [self._client_info(c) for c in range(fl.num_clients)]
        clock = self.clock = SimClock()
        rtm = self.round_time
        server_cost = getattr(rtm, "server_seconds", 0.0)
        # in-flight late reports. rounds mode: delivery round -> reports,
        # plus the busy map (client_id -> delivery round). wall-clock
        # mode: an arrival-time event queue plus the busy set (freed the
        # moment the report is delivered). Either way a straggler is
        # still *training* until its wall clock ends, so it cannot be
        # offered to the sampler again before its report lands —
        # otherwise a 2x slow device would contribute 2x concurrent
        # client-rounds
        pending: Dict[int, List[ClientReport]] = {}
        busy_until: Dict[int, int] = {}
        pending_q = (self.event_queue_factory()
                     if self.event_queue_factory is not None
                     else EventQueue())
        busy: set = set()

        self.params = params
        self._emit("on_train_start")
        t = 0
        while t < max_rounds:
            if wall and horizon_seconds is not None and result.history \
                    and clock.now >= horizon_seconds:
                break
            t += 1
            t0 = time.time()
            round_start = clock.now
            self._emit("on_round_start", t)
            val_loss = evaluate(params)

            # --- round composition: gate, sample, deadline -------------
            if wall:
                roster = ([ci for ci in fleet if ci.client_id not in busy]
                          if busy else fleet)
            else:
                # sorted: dict order here is insertion (= past delivery)
                # order; expiry must not depend on it
                for cid in sorted(c for c, due in busy_until.items()
                                  if due < t):
                    del busy_until[cid]
                roster = ([ci for ci in fleet
                           if ci.client_id not in busy_until]
                          if busy_until else fleet)
            avail, clients = dynamics.compose(
                t, roster, rng, self.strategy.duals_snapshot())
            base_knobs = self.strategy.configure_round(t, clients)
            knobs = dynamics.adjust_knobs(clients, base_knobs)
            surv_idx, drop_idx, times = dynamics.finish(t, clients, knobs,
                                                        rng)
            # the deadline in force DURING this round (a deadline-aware
            # knob policy may widen it in observe_round, which must only
            # affect the next round's duration)
            deadline = getattr(dynamics.stragglers, "deadline", None)
            # deadline-missers split into late (report still arrives,
            # if the aggregator takes it and the run is still going at
            # delivery time) vs lost (discarded for good: no arrival
            # clock, a barrier aggregator, or due past the horizon —
            # work the simulation would pay for but could never apply)
            late_idx: List[int] = []
            lost_idx: List[int] = []
            due_round: Dict[int, int] = {}
            if wall:
                # a late report lands at its absolute arrival time; it
                # is lost only when that time is past the horizon (with
                # a round-count budget the end time is unknown, so the
                # report stays in flight and undelivered leftovers are
                # counted lost at run end)
                for i in drop_idx:
                    if agg.accepts_late and times and (
                            horizon_seconds is None
                            or round_start + times[i] <= horizon_seconds):
                        late_idx.append(i)
                    else:
                        lost_idx.append(i)
            else:
                for i in drop_idx:
                    delay = (dynamics.stragglers.late_rounds(times[i])
                             if agg.accepts_late and times else None)
                    if delay is not None and t + delay <= rounds:
                        late_idx.append(i)
                        due_round[i] = t + delay
                    else:
                        lost_idx.append(i)
            survivors = [clients[i] for i in surv_idx]
            plan = RoundPlan(
                round=t,
                available=tuple(ci.client_id for ci in avail),
                sampled=tuple(ci.client_id for ci in clients),
                survivors=tuple(ci.client_id for ci in survivors),
                dropped=tuple(clients[i].client_id for i in drop_idx),
                times=tuple(times),
                late=tuple(clients[i].client_id for i in late_idx))
            self._emit("on_round_composed", plan)
            if lost_idx:
                self.strategy.on_dropout([clients[i] for i in lost_idx])
            agg.begin_round(t, clients)

            # --- LocalTrain: survivors report now, late clients'
            # reports are queued for the round their clock lands in ----
            exec_idx = list(surv_idx) + late_idx
            outs = (executor.run_round(
                params, [(clients[i], knobs[i]) for i in exec_idx])
                if exec_idx else [])
            reports = {
                i: self._report(clients[i], knobs[i], base_knobs[i], o, t,
                                times[i] if times else 0.0)
                for i, o in zip(exec_idx, outs)}
            if not wall:
                for i in late_idx:
                    pending.setdefault(due_round[i], []).append(reports[i])
                    busy_until[clients[i].client_id] = due_round[i]

            # --- deliver reports; the aggregator decides when they
            # become server updates ------------------------------------
            # the barrier's duration: min(deadline, slowest survivor)
            # under a straggler clock, the knob-derived cohort time
            # otherwise (see RoundTimeModel)
            base_dur = rtm.round_seconds(clients, knobs, times, surv_idx,
                                         deadline)
            if wall and base_dur <= 0.0:
                # a custom model returning non-positive durations would
                # spin the horizon loop into the round backstop and
                # return a normal-looking result well short of the
                # horizon — fail loudly instead (KnobRoundTime enforces
                # this itself via its idle floor)
                raise ValueError(
                    f"{type(rtm).__name__}.round_seconds returned "
                    f"{base_dur!r}; wall-clock rounds need positive "
                    f"durations")
            applied: List[ServerUpdate] = []

            def _apply(update, params):
                params = aggregation.apply_delta(params, update.delta)
                self.params = params
                applied.append(update)
                self._emit("on_server_update", update)
                return params

            if wall:
                round_end_cap = round_start + base_dur
                # earlier rounds' in-flight reports landing inside this
                # round's window — popped BEFORE this round's missers
                # join the queue, so a deadline-misser can never be
                # delivered in its own round (e.g. through the server-
                # cost tail of the cap); like rounds mode, a miss is
                # always at least one round late
                due = pending_q.pop_until(round_end_cap)
                for i in late_idx:
                    pending_q.push(round_start + times[i], reports[i])
                    busy.add(clients[i].client_id)
                events = [pending_q.stamp(
                    round_start + (times[i] if times
                                   else rtm.client_seconds(clients[i],
                                                           knobs[i])),
                    reports[i]) for i in surv_idx]
                events = sorted(events + due, key=lambda e: e.sort_key())
                arrived = []
                inbox: List[ClientReport] = []
                round_end = round_end_cap
                cut = None
                for k, ev in enumerate(events):
                    rep = ev.report
                    clock.advance_to(ev.arrival,
                                     f"deliver:c{rep.client.client_id}")
                    if rep.round_trained < t:
                        arrived.append(rep)
                    busy.discard(rep.client.client_id)
                    rep.round_submitted = t
                    rep.staleness = t - rep.round_trained
                    inbox.append(rep)
                    update = agg.submit(rep)
                    if update is not None:
                        params = _apply(update, params)
                        if agg.applies_mid_round:
                            # the buffer event completes this round:
                            # deliveries after it belong to the next
                            # round's inbox (their owners stay busy)
                            round_end = ev.arrival + server_cost
                            cut = k + 1
                            break
                if cut is not None:
                    for ev in events[cut:]:
                        pending_q.push_event(ev)
                        busy.add(ev.report.client.client_id)
                else:
                    update = agg.flush(t)
                    if update is not None:
                        params = _apply(update, params)
                clock.advance_to(round_end, f"round_end:{t}")
            else:
                arrived = sorted(pending.pop(t, ()),
                                 key=lambda r: (r.round_trained,
                                                r.arrival_time))
                inbox = arrived + [reports[i] for i in surv_idx]
                for rep in inbox:
                    rep.round_submitted = t
                    rep.staleness = t - rep.round_trained
                    update = agg.submit(rep)
                    if update is not None:
                        params = _apply(update, params)
                update = agg.flush(t)
                if update is not None:
                    params = _apply(update, params)
                # pure accounting in rounds mode: the clock advances by
                # the same barrier duration wall-clock mode would bill,
                # so sim_time / round_seconds stay comparable across
                # modes without touching the seed loop semantics
                clock.advance_to(round_start + base_dur, f"round_end:{t}")
            dynamics.settle(clients, base_knobs, knobs,
                            list(surv_idx) + late_idx, lost_idx)

            # --- constraint accounting over the reports delivered -----
            # folded over the *canonical* report order, not the
            # delivery order: the float means (and through them the
            # dual trajectory) are a function of the report set, so a
            # schedule permutation that only reorders simultaneous
            # deliveries cannot move a single bit of the accounting.
            # `inbox` itself keeps delivery order — participants /
            # late_arrivals are schedule telemetry and record it.
            stats = canonical_order(inbox)
            usages = [cset.measure(rep) for rep in stats]
            if stats:
                usage = {n: float(np.mean([u[n] for u in usages]))
                         for n in cset.names}
                train_loss = float(np.mean([rep.train_loss
                                            for rep in stats]))
                wire_mb = float(np.mean([rep.wire_mb_actual
                                         for rep in stats]))
                energy = float(np.mean([rep.energy_true for rep in stats]))
            else:               # everyone dropped / nobody reachable
                usage = cset.zero_usage()
                train_loss = wire_mb = energy = 0.0
            ratios = cset.ratios(usage, fl.budgets)
            duals_by_profile = self.strategy.update_state(
                usages, [rep.client for rep in stats])
            creports = self.strategy.constraint_reports()
            if creports:
                self._emit("on_dual_update", t, creports)
            # round telemetry back to the strategy (knob policies may
            # steer server-side knobs, e.g. widen the straggler
            # deadline, before the next round is composed)
            self.strategy.observe_round(plan, inbox, dynamics)

            # record the strategy's policy knobs, not any one client's
            # private carry boost (that stays visible via RoundPlan)
            duals_rec = _default_duals(duals_by_profile, cset.names)
            record = RoundRecord(
                round=t, val_loss=val_loss,
                knobs=base_knobs[0].as_dict() if base_knobs else {},
                usage=usage, ratios=ratios,
                duals=duals_rec,
                constraints={n: {"ratio": ratios[n],
                                 "lam": duals_rec.get(n, 0.0),
                                 "violated": ratios[n] > 1.0}
                             for n in cset.names},
                train_loss=train_loss,
                wire_mb_actual=wire_mb,
                energy_true=energy,
                seconds=time.time() - t0,
                sim_time=clock.now,
                round_seconds=clock.now - round_start,
                per_profile=_per_profile_record(
                    [rep.client for rep in stats],
                    [rep.policy_knobs for rep in stats], usages,
                    duals_by_profile, cset)
                if heterogeneous and stats else {},
                participants=[rep.client.client_id for rep in inbox],
                dropped=[clients[i].client_id for i in lost_idx],
                num_available=len(avail),
                updates_applied=len(applied),
                reports_applied=sum(len(u.reports) for u in applied),
                mean_staleness=(float(np.mean([rep.staleness
                                               for rep in stats]))
                                if stats else 0.0),
                late_arrivals=[rep.client.client_id for rep in arrived])
            result.history.append(record)
            self._emit("on_round_end", record)

        # drain whatever the policy still buffers (e.g. FedBuff's
        # partial buffer): those clients were executed, accounted and
        # debt-settled, so their work must reach the final params
        update = agg.finalize(t)
        if update is not None:
            params = aggregation.apply_delta(params, update.delta)
            self.params = params
            self._emit("on_server_update", update)
            last = result.history[-1]
            last.updates_applied += 1
            last.reports_applied += len(update.reports)
        if wall and len(pending_q):
            # in-flight reports whose arrival never fell inside a round:
            # the run ended first. The work was executed and accounted,
            # but — like rounds-mode losses past the horizon — it never
            # reaches the model; the final record owns the loss.
            leftovers = pending_q.drain()
            if result.history:
                last = result.history[-1]
                last.dropped = (list(last.dropped)
                                + [ev.report.client.client_id
                                   for ev in leftovers])
            self.strategy.on_dropout([ev.report.client for ev in leftovers])

        result.final_params = params
        result.history[-1].val_loss = evaluate(params)
        self._emit("on_train_end", result)
        return result


def _default_duals(duals_by_profile: Dict[str, Dict[str, float]],
                   names) -> Dict[str, float]:
    """The record's back-compat scalar dual dict: the default profile's
    duals, the sole profile's, or zeros (fedavg keeps no duals)."""
    if DEFAULT_PROFILE in duals_by_profile:
        return dict(duals_by_profile[DEFAULT_PROFILE])
    if duals_by_profile:
        return dict(next(iter(duals_by_profile.values())))
    return {n: 0.0 for n in names}


def _per_profile_record(clients: List[ClientInfo], knobs, usages,
                        duals_by_profile,
                        cset: ConstraintSet) -> Dict[str, Dict]:
    """Per-device-profile round record: usage means grouped by profile
    as one masked array reduction over the (client, constraint) usage
    matrix — the grouping is O(profiles) Python, never O(clients)."""
    profiles = {ci.profile.name: ci.profile for ci in clients}
    name_arr = np.asarray([ci.profile.name for ci in clients])
    usage_mat = np.asarray([[u[n] for n in cset.names] for u in usages],
                           dtype=np.float64)
    out: Dict[str, Dict] = {}
    for pname in sorted(profiles):
        mask = name_arr == pname
        mean = usage_mat[mask].mean(axis=0)
        usage = {n: float(v) for n, v in zip(cset.names, mean)}
        slot = {"clients": int(mask.sum()),
                "knobs": knobs[int(np.argmax(mask))].as_dict(),
                "usage": usage,
                "ratios": cset.ratios(usage, profiles[pname].budgets)}
        if pname in duals_by_profile:
            slot["duals"] = dict(duals_by_profile[pname])
        out[pname] = slot
    return out
