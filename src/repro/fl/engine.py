"""The composable federated engine (Algorithm 1 as pure control flow).

``FederatedEngine`` wires five independently replaceable pieces:

    strategy  — FederatedStrategy: knobs / aggregation / dual state
    executor  — ClientExecutor: how LocalTrain actually runs (sequential
                Python loop vs one jitted vmap over stacked clients)
    profiles  — DeviceProfile map: per-device-class budgets + resource
                models (the paper's homogeneous fleet is the default)
    dynamics  — FleetDynamics: availability gating x client sampling x
                deadline stragglers (the default bundle reproduces the
                always-available uniform-K-of-N loop bit-for-bit)
    callbacks — RoundCallback hooks for logging / checkpoints / timing

Round composition is per-round state, not a static list: the engine
asks ``dynamics`` who is reachable, who is picked, and who reported
before the deadline; only the *survivors* feed aggregation (weights
renormalized over them) and the CAFL-L dual update, and dropped
clients' token budgets are carried to their next participation.

``repro.core.server.run_federated`` is a thin wrapper over this class
that preserves the seed API exactly.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence, Union

import jax
import numpy as np

from repro.configs.base import FLConfig
from repro.core import aggregation
from repro.core.client import ClientRunner
from repro.core.duals import RESOURCES, DualState, usage_ratios
from repro.core.resources import ResourceModel, calibrate
from repro.core.server import FLResult, RoundRecord, make_eval_fn
from repro.data.federated import FederatedData
from repro.data.shakespeare import CharDataset
from repro.fl.callbacks import RoundCallback
from repro.fl.device import (DEFAULT_PROFILE, ClientInfo, DeviceProfile,
                             uniform_fleet)
from repro.fl.dynamics import FleetDynamics, RoundPlan
from repro.fl.executor import ClientExecutor, make_executor
from repro.fl.strategy import FederatedStrategy, make_strategy
from repro.models.zoo import Model

ExecutorSpec = Union[str, Callable[[ClientRunner], ClientExecutor]]


class FederatedEngine:
    def __init__(self, model: Model, fl: FLConfig, dataset: CharDataset,
                 strategy: Union[str, FederatedStrategy, None] = None,
                 executor: Optional[ExecutorSpec] = None,
                 profiles: Optional[Dict[str, DeviceProfile]] = None,
                 client_profiles: Optional[Sequence[str]] = None,
                 dynamics: Optional[FleetDynamics] = None,
                 callbacks: Sequence[RoundCallback] = (),
                 resources: Optional[ResourceModel] = None,
                 init_duals: Optional[DualState] = None):
        self.model = model
        self.fl = fl
        self.dataset = dataset
        if strategy is None:
            strategy = fl.method
        self.strategy = (make_strategy(strategy, fl, init_duals=init_duals)
                         if isinstance(strategy, str) else strategy)
        self._executor_spec: ExecutorSpec = executor or fl.executor
        if profiles is None:
            profiles, client_profiles = uniform_fleet(fl)
        assert client_profiles is not None and \
            len(client_profiles) == fl.num_clients, \
            "client_profiles must name a profile for every client"
        self._profiles_raw = profiles
        self._client_profiles = list(client_profiles)
        self.dynamics = dynamics or FleetDynamics.default(fl)
        self.callbacks = list(callbacks)
        self._base_resources = resources

        self.data = FederatedData(dataset.train, fl.num_clients, seed=fl.seed,
                                  noniid_alpha=fl.noniid_alpha)
        self.params = None            # live during run(); callbacks read it
        self.profiles: Dict[str, DeviceProfile] = {}

    # ------------------------------------------------------------------
    def _setup(self, init_params):
        fl = self.fl
        params = init_params if init_params is not None else \
            self.model.init(jax.random.PRNGKey(fl.seed))
        # calibrate proxies at the baseline operating point (all layers
        # active) and specialize per device profile
        base = self._base_resources
        if base is None:
            from repro.core.freezing import count_params
            base = calibrate(count_params(params), fl)
        self.profiles = {name: p.with_resources(base)
                         for name, p in self._profiles_raw.items()}
        runner = ClientRunner(self.model, fl, self.data, base)
        executor = (make_executor(self._executor_spec, runner)
                    if isinstance(self._executor_spec, str)
                    else self._executor_spec(runner))
        return params, runner, executor

    def _client_info(self, cid: int) -> ClientInfo:
        profile = self.profiles[self._client_profiles[cid]]
        return ClientInfo(client_id=cid, profile=profile,
                          shard_size=self.data.shard_size(cid))

    def _emit(self, hook: str, *args) -> None:
        for cb in self.callbacks:
            getattr(cb, hook)(self, *args)

    # ------------------------------------------------------------------
    def run(self, rounds: Optional[int] = None, init_params=None) -> FLResult:
        fl = self.fl
        rounds = rounds or fl.rounds
        rng = np.random.default_rng(fl.seed)
        params, runner, executor = self._setup(init_params)
        evaluate = make_eval_fn(self.model, self.dataset, fl)
        result = FLResult(method=self.strategy.name)
        heterogeneous = len(self.profiles) > 1

        dynamics = self.dynamics
        dynamics.reset()
        fleet = [self._client_info(c) for c in range(fl.num_clients)]

        self.params = params
        self._emit("on_train_start")
        for t in range(1, rounds + 1):
            t0 = time.time()
            self._emit("on_round_start", t)
            val_loss = evaluate(params)

            # --- round composition: gate, sample, deadline -------------
            avail, clients = dynamics.compose(
                t, fleet, rng, self.strategy.duals_snapshot())
            base_knobs = self.strategy.configure_round(t, clients)
            knobs = dynamics.adjust_knobs(clients, base_knobs)
            surv_idx, drop_idx, times = dynamics.finish(t, clients, knobs,
                                                        rng)
            survivors = [clients[i] for i in surv_idx]
            surv_knobs = [knobs[i] for i in surv_idx]
            plan = RoundPlan(
                round=t,
                available=tuple(ci.client_id for ci in avail),
                sampled=tuple(ci.client_id for ci in clients),
                survivors=tuple(ci.client_id for ci in survivors),
                dropped=tuple(clients[i].client_id for i in drop_idx),
                times=tuple(times))
            self._emit("on_round_composed", plan)
            if drop_idx:
                self.strategy.on_dropout([clients[i] for i in drop_idx])

            # --- LocalTrain for the cohort; only survivors report ------
            outs = (executor.run_round(params,
                                       list(zip(survivors, surv_knobs)))
                    if survivors else [])
            if outs:
                weights = [float(ci.shard_size) for ci in survivors]
                delta = self.strategy.aggregate([o.delta for o in outs],
                                                weights)
                params = aggregation.apply_delta(params, delta)
                self.params = params
            dynamics.settle(clients, base_knobs, knobs, surv_idx, drop_idx)

            # --- constraint accounting over the clients that reported --
            usages = [ci.profile.resources.usage(o.params_active, kn)
                      for ci, kn, o in zip(survivors, surv_knobs, outs)]
            energy_true = [
                ci.profile.resources.usage(o.params_active, kn,
                                           include_accum=True)["energy"]
                for ci, kn, o in zip(survivors, surv_knobs, outs)]
            if usages:
                usage = {r: float(np.mean([u[r] for u in usages]))
                         for r in RESOURCES}
                train_loss = float(np.mean([o.train_loss for o in outs]))
                wire_mb = float(np.mean([o.wire_mb_actual for o in outs]))
                energy = float(np.mean(energy_true))
            else:               # everyone dropped / nobody reachable
                usage = {r: 0.0 for r in RESOURCES}
                train_loss = wire_mb = energy = 0.0
            ratios = usage_ratios(usage, fl.budgets)
            duals_by_profile = self.strategy.update_state(usages, survivors)

            # record the strategy's policy knobs, not any one client's
            # private carry boost (that stays visible via RoundPlan)
            record = RoundRecord(
                round=t, val_loss=val_loss,
                knobs=base_knobs[0].as_dict() if base_knobs else {},
                usage=usage, ratios=ratios,
                duals=_default_duals(duals_by_profile),
                train_loss=train_loss,
                wire_mb_actual=wire_mb,
                energy_true=energy,
                seconds=time.time() - t0,
                per_profile=_per_profile_record(
                    survivors, [base_knobs[i] for i in surv_idx], usages,
                    duals_by_profile)
                if heterogeneous and survivors else {},
                participants=[ci.client_id for ci in survivors],
                dropped=[clients[i].client_id for i in drop_idx],
                num_available=len(avail))
            result.history.append(record)
            self._emit("on_round_end", record)

        result.final_params = params
        result.history[-1].val_loss = evaluate(params)
        self._emit("on_train_end", result)
        return result


def _default_duals(duals_by_profile: Dict[str, Dict[str, float]]
                   ) -> Dict[str, float]:
    """The record's back-compat scalar dual dict: the default profile's
    duals, the sole profile's, or zeros (fedavg keeps no duals)."""
    if DEFAULT_PROFILE in duals_by_profile:
        return dict(duals_by_profile[DEFAULT_PROFILE])
    if duals_by_profile:
        return dict(next(iter(duals_by_profile.values())))
    return {r: 0.0 for r in RESOURCES}


def _per_profile_record(clients: List[ClientInfo], knobs, usages,
                        duals_by_profile) -> Dict[str, Dict]:
    out: Dict[str, Dict] = {}
    for ci, kn, u in zip(clients, knobs, usages):
        name = ci.profile.name
        slot = out.setdefault(name, {"clients": 0, "knobs": kn.as_dict(),
                                     "usage": {r: 0.0 for r in RESOURCES}})
        slot["clients"] += 1
        for r in RESOURCES:
            slot["usage"][r] += u[r]
    for name, slot in out.items():
        n = slot["clients"]
        slot["usage"] = {r: v / n for r, v in slot["usage"].items()}
        profile = next(ci.profile for ci in clients
                       if ci.profile.name == name)
        slot["ratios"] = usage_ratios(slot["usage"], profile.budgets)
        if name in duals_by_profile:
            slot["duals"] = dict(duals_by_profile[name])
    return out
