"""Device profiles: per-client budgets + resource models.

The paper evaluates one homogeneous fleet, but its framing ("constants
can be adapted or re-scaled for specific device profiles", A.1) and the
multi-resource-allocation related work assume devices differ. A
``DeviceProfile`` carries a device class's budgets (Eq. 2 is then
per-class) and its resource-model calibration; the engine maps every
simulated client onto one profile so the CAFL-L duals/policy can run
per device class.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import Budgets, FLConfig
from repro.core.resources import ResourceModel

DEFAULT_PROFILE = "default"


@dataclass(frozen=True)
class DeviceProfile:
    """One device class in the fleet.

    ``resources=None`` means "the engine's calibrated base model scaled
    by ``compute_scale``" (>1 = less efficient silicon: more energy and
    heat per token than the calibration device). ``availability`` is the
    class's steady-state reachability (fraction of rounds a device of
    this class answers the server) — ``repro.fl.dynamics`` churn models
    read it; the engine itself never gates on it.
    """
    name: str
    budgets: Budgets
    resources: Optional[ResourceModel] = None
    compute_scale: float = 1.0
    availability: float = 1.0

    def with_resources(self, base: ResourceModel) -> "DeviceProfile":
        if self.resources is not None:
            return self
        return dataclasses.replace(
            self, resources=base.scaled(energy=self.compute_scale,
                                        temp=self.compute_scale))


@dataclass(frozen=True)
class ClientInfo:
    """A sampled client as the strategy sees it."""
    client_id: int
    profile: DeviceProfile
    shard_size: int = 0


@dataclass(frozen=True)
class FleetClass:
    """Spec for one tier of a heterogeneous fleet."""
    name: str
    fraction: float               # share of clients in this tier
    budget_scale: float = 1.0     # tier budgets = base budgets * scale
    compute_scale: float = 1.0    # tier efficiency (see DeviceProfile)
    availability: float = 1.0     # tier reachability (see DeviceProfile)


def uniform_fleet(fl: FLConfig) -> Tuple[Dict[str, DeviceProfile], List[str]]:
    """The paper's setting: every client is the same device."""
    profiles = {DEFAULT_PROFILE: DeviceProfile(DEFAULT_PROFILE, fl.budgets)}
    return profiles, [DEFAULT_PROFILE] * fl.num_clients


def make_fleet(fl: FLConfig, classes: Sequence[FleetClass]
               ) -> Tuple[Dict[str, DeviceProfile], List[str]]:
    """Partition ``fl.num_clients`` into device classes by fraction
    (contiguous blocks, remainder to the last class)."""
    assert classes, "need at least one FleetClass"
    profiles = {
        c.name: DeviceProfile(c.name, fl.budgets.scaled(c.budget_scale),
                              compute_scale=c.compute_scale,
                              availability=c.availability)
        for c in classes}
    assignment: List[str] = []
    for c in classes[:-1]:
        assignment += [c.name] * int(round(c.fraction * fl.num_clients))
    assignment = assignment[:fl.num_clients]
    assignment += [classes[-1].name] * (fl.num_clients - len(assignment))
    return profiles, assignment
