"""Composable federated engine: Strategy x Executor x DeviceProfile x
FleetDynamics x Aggregator x Callback, replacing the seed's monolithic
``run_federated``.

    from repro.fl import FederatedEngine, CAFLL, BatchedExecutor

    engine = FederatedEngine(model, fl, dataset, strategy="cafl",
                             executor="batched",
                             aggregator="fedbuff",   # default: "sync"
                             callbacks=[LoggingCallback()])
    result = engine.run()

The seed API (``repro.core.run_federated``) remains a thin wrapper.
"""
from repro.constraints import (  # noqa: F401
    AdaptiveStep, Constraint, ConstraintReport, ConstraintSet,
    DeadlineAwareKnobPolicy, DeadzoneSubgradient, DualController,
    KnobPolicy, PIController, PaperKnobPolicy, make_constraints,
    make_controller, make_knob_policy, paper_constraints,
    register_constraint,
)
from repro.core.client import ClientResult, ClientRunner  # noqa: F401
from repro.core.server import FLResult, RoundRecord  # noqa: F401
from repro.fl.aggregator import (  # noqa: F401
    Aggregator, ClientReport, ConstantStaleness, FedBuffAggregator,
    MaskedSumAggregator, PolynomialStaleness, ServerUpdate,
    StalenessPolicy, StalenessWeightedAggregator, SyncAggregator,
    canonical_order, make_aggregator, make_staleness_policy,
    report_order_key,
)
from repro.fl.callbacks import (  # noqa: F401
    CheckpointCallback, HistoryWriterCallback, LoggingCallback,
    RoundCallback, TimingCallback,
)
from repro.fl.clock import (  # noqa: F401
    TIME_MODES, EventQueue, KnobRoundTime, RoundTimeModel, SimClock,
    TimedReport, make_round_time, seconds_to_target,
)
from repro.fl.device import (  # noqa: F401
    DEFAULT_PROFILE, ClientInfo, DeviceProfile, FleetClass, make_fleet,
    uniform_fleet,
)
from repro.fl.dynamics import (  # noqa: F401
    AlwaysAvailable, AvailabilityModel, BernoulliChurn, ClientSampler,
    DeadlineStragglers, FleetDynamics, FullParticipation, NoStragglers,
    PeriodicAvailability, ResourceAwareSampler, RoundPlan,
    RoundRobinSampler, StragglerModel, UniformSampler, make_dynamics,
)
from repro.fl.engine import FederatedEngine  # noqa: F401
from repro.fl.executor import (  # noqa: F401
    BatchedExecutor, ClientExecutor, SequentialExecutor, make_executor,
)
from repro.fl.strategy import (  # noqa: F401
    CAFLL, FedAvg, FederatedStrategy, ServerOpt, make_strategy,
)
