"""Fleet dynamics: who *can* train, who is picked, and who finishes.

The paper's experiments assume a fully-participating, always-available
homogeneous fleet; the multi-resource-allocation related work treats
partial participation, stragglers and dropout as the defining condition
of on-device FL. This module makes round composition an explicit,
per-round process instead of the engine's implicit "all sampled clients
always finish" loop:

    AvailabilityModel  -- which clients a round can even see (charge /
                          idle windows, Bernoulli churn)
    ClientSampler      -- which available clients the server picks
                          (full, uniform K-of-N, round-robin,
                          resource-aware by dual-adjusted headroom)
    StragglerModel     -- which picked clients report before the round
                          deadline (wall-clock draws scaled by the
                          device profile's ``compute_scale``)

``FleetDynamics`` bundles the three plus the carry-over ledger that
re-credits a dropped client's lost token budget (paper Eq. 8 spirit) at
its next participation via extra gradient accumulation.

Determinism contract: every model draws only from the generator the
engine hands it, so the same ``fl.seed`` yields the same participation
sets. The default bundle (always available, uniform K-of-N, no
stragglers) consumes the generator exactly like the PR-1 engine's
``rng.choice(num_clients, size=clients_per_round, replace=False)`` —
full-participation configs reproduce earlier trajectories bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import FLConfig
from repro.core.policy import Knobs
from repro.fl.device import ClientInfo

# ---------------------------------------------------------------------------
# availability
# ---------------------------------------------------------------------------


class AvailabilityModel:
    """Gate: the subset of the fleet a round can even see."""

    name = "base"

    def available(self, rnd: int, clients: Sequence[ClientInfo],
                  rng: np.random.Generator) -> List[ClientInfo]:
        raise NotImplementedError


class AlwaysAvailable(AvailabilityModel):
    """The paper's setting: every client answers every round. Consumes
    no randomness, so it is stream-transparent."""

    name = "always"

    def available(self, rnd, clients, rng):
        return list(clients)


class PeriodicAvailability(AvailabilityModel):
    """Deterministic charge/idle windows: client ``i`` is reachable for
    ``on_rounds`` out of every ``period`` rounds, phase-staggered by its
    id so the fleet never goes dark all at once. ``per_profile`` maps a
    profile name to its own ``(period, on_rounds)`` window (e.g. low-end
    phones charge less often than plugged-in tablets)."""

    name = "periodic"

    def __init__(self, period: int = 4, on_rounds: int = 2,
                 per_profile: Optional[Dict[str, Tuple[int, int]]] = None):
        assert period >= 1 and 1 <= on_rounds <= period
        self.period = period
        self.on_rounds = on_rounds
        self.per_profile = per_profile or {}

    def _window(self, ci: ClientInfo) -> Tuple[int, int]:
        return self.per_profile.get(ci.profile.name,
                                    (self.period, self.on_rounds))

    def is_available(self, rnd: int, ci: ClientInfo) -> bool:
        period, on = self._window(ci)
        return (rnd + ci.client_id) % period < on

    def available(self, rnd, clients, rng):
        return [ci for ci in clients if self.is_available(rnd, ci)]


class BernoulliChurn(AvailabilityModel):
    """Independent per-round churn: client ``i`` answers with probability
    ``p * profile.availability`` (``per_profile`` overrides the product
    per device class). One uniform draw per client per round."""

    name = "bernoulli"

    def __init__(self, p: float = 1.0,
                 per_profile: Optional[Dict[str, float]] = None):
        assert 0.0 <= p <= 1.0
        self.p = p
        self.per_profile = per_profile or {}

    def prob(self, ci: ClientInfo) -> float:
        if ci.profile.name in self.per_profile:
            return self.per_profile[ci.profile.name]
        return self.p * ci.profile.availability

    def available(self, rnd, clients, rng):
        draws = rng.random(len(clients))
        return [ci for ci, u in zip(clients, draws) if u < self.prob(ci)]


# ---------------------------------------------------------------------------
# client sampling
# ---------------------------------------------------------------------------


class ClientSampler:
    """Picks this round's cohort from the available clients. ``duals``
    is the strategy's per-profile dual snapshot ({} for dual-free
    strategies) so samplers can be constraint-aware."""

    name = "base"

    def reset(self) -> None:
        pass

    def sample(self, rnd: int, available: Sequence[ClientInfo],
               rng: np.random.Generator,
               duals: Dict[str, Dict[str, float]]) -> List[ClientInfo]:
        raise NotImplementedError


class FullParticipation(ClientSampler):
    """Every available client trains (cross-silo style)."""

    name = "full"

    def sample(self, rnd, available, rng, duals):
        return list(available)


class UniformSampler(ClientSampler):
    """Uniform K-of-N without replacement. With every client available
    this draws ``rng.choice(N, size=K, replace=False)`` — the exact call
    (and generator consumption) of the PR-1 engine loop."""

    name = "uniform"

    def __init__(self, k: int):
        assert k >= 1
        self.k = k

    def sample(self, rnd, available, rng, duals):
        if len(available) < self.k:
            return list(available)
        idx = rng.choice(len(available), size=self.k, replace=False)
        return [available[int(i)] for i in idx]


class RoundRobinSampler(ClientSampler):
    """Deterministic fair rotation: a cyclic cursor over client ids;
    each round takes the next ``k`` available clients in id order. No
    randomness consumed — useful as a fully reproducible schedule."""

    name = "round_robin"

    def __init__(self, k: int):
        assert k >= 1
        self.k = k
        self._cursor = 0

    def reset(self) -> None:
        self._cursor = 0

    def sample(self, rnd, available, rng, duals):
        if not available:
            return []
        ordered = sorted(available, key=lambda ci: ci.client_id)
        # rotate so the scan starts just past the last cohort's end
        start = 0
        for i, ci in enumerate(ordered):
            if ci.client_id >= self._cursor:
                start = i
                break
        picked = [ordered[(start + j) % len(ordered)]
                  for j in range(min(self.k, len(ordered)))]
        self._cursor = (picked[-1].client_id + 1) if picked else 0
        return picked


class ResourceAwareSampler(ClientSampler):
    """Prefers clients whose device class has dual-adjusted headroom:
    score = sum_r lambda_r of the client's profile (high duals = the
    class is pressed against its budgets), pick the ``k`` lowest scores
    with random tie-breaking. With no duals yet (round 1, or FedAvg)
    this degrades to uniform K-of-N.

    ``explore`` reserves a fraction of the cohort for uniform sampling:
    CAFL-L duals only move for profiles that report, so a purely greedy
    sampler would freeze a pressed tier out forever (its high duals
    never decay because it is never sampled again). The explore slots
    guarantee every tier keeps feeding the dual update.
    """

    name = "resource_aware"

    def __init__(self, k: int, explore: float = 0.25):
        assert k >= 1 and 0.0 <= explore <= 1.0
        self.k = k
        self.explore = explore

    @staticmethod
    def pressure(ci: ClientInfo,
                 duals: Dict[str, Dict[str, float]]) -> float:
        lam = duals.get(ci.profile.name)
        return float(sum(lam.values())) if lam else 0.0

    def sample(self, rnd, available, rng, duals):
        if len(available) <= self.k:
            return list(available)
        n_explore = math.ceil(self.k * self.explore) if self.explore else 0
        perm = [int(i) for i in rng.permutation(len(available))]
        picked = perm[:n_explore]                    # uniform explore slots
        rest = perm[n_explore:]
        # stable sort over a random permutation = random tie-breaks
        order = sorted(rest,
                       key=lambda i: self.pressure(available[i], duals))
        picked += order[:self.k - n_explore]
        return [available[i] for i in picked]


# ---------------------------------------------------------------------------
# stragglers
# ---------------------------------------------------------------------------


class StragglerModel:
    """Decides which sampled clients report before the round deadline.
    ``split`` returns (survivor_idx, dropped_idx, times) as positions
    into the sampled cohort; ``times`` are the simulated wall-clock
    draws (empty when the model keeps none).

    ``deadline`` is the model's wall-clock round deadline in baseline-
    round units, or None when it keeps no clock — the engine's
    wall-clock mode and the deadline-aware knob policy both read (and
    the policy writes) it through this attribute."""

    name = "base"
    deadline: Optional[float] = None

    def split(self, rnd: int, sampled: Sequence[ClientInfo],
              knobs: Sequence[Knobs], rng: np.random.Generator
              ) -> Tuple[List[int], List[int], List[float]]:
        raise NotImplementedError

    def late_rounds(self, time: float) -> Optional[int]:
        """How many rounds after its training round a deadline-missing
        report arrives at the server (for aggregators that accept late
        reports). ``None`` = the report is lost forever; the base model
        keeps no clock, so misses are losses."""
        return None


class NoStragglers(StragglerModel):
    """Every sampled client finishes. Consumes no randomness."""

    name = "none"

    def split(self, rnd, sampled, knobs, rng):
        return list(range(len(sampled))), [], []


class DeadlineStragglers(StragglerModel):
    """Per-client wall-clock draw vs a fixed round deadline.

    The draw is ``compute_scale * (s * grad_accum * b) / work_unit``
    times a log-normal jitter — i.e. time 1.0 is one baseline round
    (``work_unit = s_base * b_base`` sequences) on calibration silicon.
    Clients whose draw exceeds ``deadline`` trained but never reported:
    the server aggregates only survivors and their token budget is
    carried to their next participation by ``FleetDynamics``.
    """

    name = "deadline"

    def __init__(self, deadline: float, jitter: float = 0.25,
                 work_unit: float = 1.0):
        assert deadline >= 0.0 and jitter >= 0.0 and work_unit > 0
        self.deadline = deadline
        self.jitter = jitter
        self.work_unit = work_unit

    @classmethod
    def for_config(cls, fl: FLConfig, deadline: float = 1.5,
                   jitter: float = 0.25) -> "DeadlineStragglers":
        """Deadline in units of baseline-knob rounds on the calibration
        device (deadline=1.5 drops anything >1.5x slower than that)."""
        return cls(deadline, jitter, work_unit=float(fl.s_base * fl.b_base))

    def draw_times(self, sampled, knobs, rng) -> List[float]:
        noise = (np.exp(rng.normal(0.0, self.jitter, size=len(sampled)))
                 if self.jitter > 0 else np.ones(len(sampled)))
        return [float(ci.profile.compute_scale
                      * (kn.s * kn.grad_accum * kn.b) / self.work_unit * z)
                for ci, kn, z in zip(sampled, knobs, noise)]

    def split(self, rnd, sampled, knobs, rng):
        times = self.draw_times(sampled, knobs, rng)
        survivors = [i for i, t in enumerate(times) if t <= self.deadline]
        dropped = [i for i, t in enumerate(times) if t > self.deadline]
        return survivors, dropped, times

    def late_rounds(self, time):
        """A round lasts one deadline of wall clock, so a client that
        finishes at ``time`` delivers ceil(time/deadline) - 1 rounds
        after the one it trained in. deadline<=0 has no round length to
        measure lateness in, so misses stay losses."""
        if self.deadline <= 0.0:
            return None
        late = math.ceil(time / self.deadline) - 1
        return late if late >= 1 else None


# ---------------------------------------------------------------------------
# the bundle
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RoundPlan:
    """One round's composition, as callbacks and records observe it."""
    round: int
    available: Tuple[int, ...]     # client ids the round could see
    sampled: Tuple[int, ...]       # the cohort the sampler picked
    survivors: Tuple[int, ...]     # reported before the deadline
    dropped: Tuple[int, ...]       # sampled but missed the deadline
    times: Tuple[float, ...] = ()  # straggler draws (aligned to sampled)
    # deadline-missers whose report will still arrive in a later round
    # (subset of ``dropped``; empty unless the aggregator accepts late)
    late: Tuple[int, ...] = ()


@dataclass
class FleetDynamics:
    """Sampler x availability x straggler bundle + the dropped-client
    token-budget ledger. One instance drives one engine run (``reset``
    clears cursors and debts between runs)."""

    sampler: ClientSampler
    availability: AvailabilityModel = field(default_factory=AlwaysAvailable)
    stragglers: StragglerModel = field(default_factory=NoStragglers)
    carryover_tokens: bool = True   # re-credit dropped clients' budget
    max_carry_accum: int = 4        # cap on extra grad-accum steps/round
    _debt: Dict[int, int] = field(default_factory=dict, repr=False)

    @classmethod
    def default(cls, fl: FLConfig) -> "FleetDynamics":
        """The PR-1 loop as an explicit bundle: always-available fleet,
        uniform K-of-N, no stragglers. Stream-identical to the old
        engine's inline ``rng.choice``."""
        return cls(sampler=UniformSampler(fl.clients_per_round))

    def reset(self) -> None:
        self.sampler.reset()
        self._debt.clear()

    # -- round composition --------------------------------------------------
    def compose(self, rnd: int, clients: Sequence[ClientInfo],
                rng: np.random.Generator,
                duals: Dict[str, Dict[str, float]]
                ) -> Tuple[List[ClientInfo], List[ClientInfo]]:
        """-> (available, sampled) for this round."""
        avail = self.availability.available(rnd, clients, rng)
        sampled = self.sampler.sample(rnd, avail, rng, duals)
        return avail, sampled

    def adjust_knobs(self, sampled: Sequence[ClientInfo],
                     knobs: Sequence[Knobs]) -> List[Knobs]:
        """Spend carried token debt: a client that dropped earlier gets
        extra grad-accum microbatches (capped) so its lost tokens are
        made up without changing the round's step count."""
        if not self.carryover_tokens:
            return list(knobs)
        out = []
        for ci, kn in zip(sampled, knobs):
            debt = self._debt.get(ci.client_id, 0)
            if debt > 0:
                extra = min(self.max_carry_accum,
                            max(1, math.ceil(debt / (kn.s * kn.b))))
                kn = dataclasses.replace(kn, grad_accum=kn.grad_accum + extra)
            out.append(kn)
        return out

    def finish(self, rnd: int, sampled: Sequence[ClientInfo],
               knobs: Sequence[Knobs], rng: np.random.Generator
               ) -> Tuple[List[int], List[int], List[float]]:
        return self.stragglers.split(rnd, sampled, knobs, rng)

    def settle(self, sampled: Sequence[ClientInfo],
               base_knobs: Sequence[Knobs],
               adjusted_knobs: Sequence[Knobs],
               survivor_idx: Sequence[int],
               dropped_idx: Sequence[int]) -> None:
        """Update the ledger: survivors pay down exactly the tokens their
        carry boost trained (when ``max_carry_accum`` capped the boost
        the remainder stays owed); dropped clients owe this round's
        *base* token budget on top of any standing debt (the carry boost
        itself never compounds)."""
        if not self.carryover_tokens:
            return
        for i in survivor_idx:
            cid = sampled[i].client_id
            if cid not in self._debt:
                continue
            base, adj = base_knobs[i], adjusted_knobs[i]
            repaid = (adj.grad_accum - base.grad_accum) * adj.s * adj.b
            left = self._debt[cid] - repaid
            if left > 0:
                self._debt[cid] = left
            else:
                del self._debt[cid]
        for i in dropped_idx:
            kn = base_knobs[i]
            cid = sampled[i].client_id
            self._debt[cid] = (self._debt.get(cid, 0)
                               + kn.s * kn.grad_accum * kn.b)

    def debt(self, client_id: int) -> int:
        """Outstanding token (sequence) debt for a client (0 if none)."""
        return self._debt.get(client_id, 0)


def make_dynamics(fl: FLConfig, sampler: str = "uniform",
                  availability: str = "always", stragglers: str = "none",
                  deadline: float = 1.5, jitter: float = 0.25,
                  churn_p: float = 0.8, period: int = 4, on_rounds: int = 2
                  ) -> FleetDynamics:
    """Convenience string-spec constructor mirroring ``make_strategy`` /
    ``make_executor`` so configs and benchmarks can name a scenario."""
    samplers = {
        "full": lambda: FullParticipation(),
        "uniform": lambda: UniformSampler(fl.clients_per_round),
        "round_robin": lambda: RoundRobinSampler(fl.clients_per_round),
        "resource_aware": lambda: ResourceAwareSampler(fl.clients_per_round),
    }
    avails = {
        "always": lambda: AlwaysAvailable(),
        "periodic": lambda: PeriodicAvailability(period, on_rounds),
        "bernoulli": lambda: BernoulliChurn(churn_p),
    }
    stragglerss = {
        "none": lambda: NoStragglers(),
        "deadline": lambda: DeadlineStragglers.for_config(fl, deadline,
                                                          jitter),
    }
    try:
        return FleetDynamics(sampler=samplers[sampler](),
                             availability=avails[availability](),
                             stragglers=stragglerss[stragglers]())
    except KeyError as e:
        raise ValueError(f"unknown dynamics component {e.args[0]!r}") from None
