"""Client executors: how one round's LocalTrain workload actually runs.

``SequentialExecutor`` keeps the seed semantics: a Python loop over
clients driving ``ClientRunner.train_client`` (one jitted grad step per
microbatch, one host sync per client).

``BatchedExecutor`` groups clients that received the same knobs (same
shapes), pre-samples every microbatch, and runs the whole group's local
training as ONE jitted call: ``vmap`` over clients of a
``lax.scan`` over local steps of a ``lax.scan`` over grad-accum
microbatches. That removes the per-client Python dispatch and every
intermediate host sync — the only transfer per group is the stacked
deltas and losses coming back.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.client import (ClientResult, ClientRunner,
                               _masked_wire_mb, apply_masked_update)
from repro.core.policy import Knobs
from repro.fl.device import ClientInfo

Assignment = Tuple[ClientInfo, Knobs]


class ClientExecutor:
    """Protocol: run one round of LocalTrain for the sampled clients."""

    def run_round(self, params, assignments: Sequence[Assignment]
                  ) -> List[ClientResult]:
        raise NotImplementedError


class SequentialExecutor(ClientExecutor):
    """Seed semantics: clients one after another through the shared
    jitted step cache."""

    def __init__(self, runner: ClientRunner):
        self.runner = runner

    def run_round(self, params, assignments):
        return [self.runner.train_client(ci.client_id, params, kn)
                for ci, kn in assignments]


class BatchedExecutor(ClientExecutor):
    """Same-knob clients stacked and trained in a single jitted
    vmap-of-scan call. Numerically matches the sequential path up to
    float reassociation (same batches, same update math)."""

    def __init__(self, runner: ClientRunner):
        self.runner = runner
        self._batched = jax.jit(jax.vmap(self._one_client,
                                         in_axes=(None, None, 0)))

    def _one_client(self, params, mask, batches):
        """LocalTrain for one client; ``batches`` leaves are shaped
        (s, grad_accum, b, seq). vmapped over a leading client axis."""
        opt = self.runner.opt
        ga = jax.tree.leaves(batches)[0].shape[1]
        loss_fn = self.runner.model.train_loss

        def local_step(carry, micros):
            w, opt_state = carry
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), w)

            def accum(c, mb):
                gsum, lsum = c
                (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    w, mb)
                gsum = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                    gsum, grads)
                return (gsum, lsum + loss.astype(jnp.float32)), None

            (gsum, lsum), _ = jax.lax.scan(
                accum, (zeros, jnp.zeros((), jnp.float32)), micros)
            grads = jax.tree.map(lambda g: g / ga, gsum)
            w, opt_state = apply_masked_update(opt, w, opt_state, grads, mask)
            return (w, opt_state), lsum / ga

        opt_state = opt.init(params)
        (w, _), losses = jax.lax.scan(local_step, (params, opt_state), batches)
        delta = jax.tree.map(lambda a, b_: a.astype(jnp.float32)
                             - b_.astype(jnp.float32), w, params)
        return delta, jnp.mean(losses)

    def _stack_batches(self, cids: Sequence[int], kn: Knobs):
        """Pre-sample every microbatch for the group, in the same
        (client, step, micro) order the sequential path draws them, and
        stack to leaves of shape (C, s, grad_accum, b, seq)."""
        per_key: Dict[str, list] = {}
        for cid in cids:
            rows: Dict[str, list] = {}
            for _ in range(kn.s):
                for _ in range(kn.grad_accum):
                    batch = self.runner.data.batch(cid, kn.b,
                                                   self.runner.fl.seq_len)
                    for key, arr in batch.items():
                        rows.setdefault(key, []).append(arr)
            for key, arrs in rows.items():
                stacked = np.stack(arrs).reshape(
                    (kn.s, kn.grad_accum) + arrs[0].shape)
                per_key.setdefault(key, []).append(stacked)
        return {key: jnp.asarray(np.stack(arrs))
                for key, arrs in per_key.items()}

    def run_round(self, params, assignments):
        # group client indices by knobs; same knobs => same shapes
        groups: Dict[Knobs, List[int]] = {}
        for idx, (_, kn) in enumerate(assignments):
            groups.setdefault(kn, []).append(idx)

        results: List[ClientResult] = [None] * len(assignments)  # type: ignore
        for kn, idxs in groups.items():
            cids = [assignments[i][0].client_id for i in idxs]
            mask, active = self.runner.mask_for(params, kn.k)
            batches = self._stack_batches(cids, kn)
            deltas, losses = self._batched(params, mask, batches)
            losses = np.asarray(losses)
            topk = self.runner.fl.wire_topk
            for row, i in enumerate(idxs):
                raw = jax.tree.map(lambda l, r=row: l[r], deltas)
                delta = _compress(raw, mask, kn.q, topk=topk)
                results[i] = ClientResult(
                    client_id=cids[row], delta=delta, params_active=active,
                    train_loss=float(losses[row]),
                    wire_mb_actual=_masked_wire_mb(delta, mask, kn.q,
                                                   topk=topk))
        return results


def _compress(raw_delta, mask, q: int, topk=None):
    """Wire-compress an already-computed fp32 delta (the batched path
    computes w - params on device; only the q/topk knobs remain)."""
    from repro.core import compression, freezing
    delta = compression.compress_decompress(raw_delta, q, topk=topk)
    return freezing.apply_mask(delta, mask)


# ---------------------------------------------------------------------------
# trace-analysis entry points (repro.analysis.trace)
# ---------------------------------------------------------------------------


def _batched_round_build():
    from repro.analysis.trace.registry import (TRACE_MODEL,
                                               charlm_trace_setup)
    runner, params, _ = charlm_trace_setup(b=4)
    ex = BatchedExecutor(runner)
    mask, _ = runner.mask_for(params, 0)
    seq = TRACE_MODEL["seq_len"]
    batches = {
        "tokens": jax.ShapeDtypeStruct((2, 2, 1, 4, seq), jnp.int32),
        "targets": jax.ShapeDtypeStruct((2, 2, 1, 4, seq), jnp.int32),
    }
    return ex._batched, (params, mask, batches)


def trace_entry_points() -> List[object]:
    """Declared traceable surface: the one jitted call a batched round
    makes (vmap over clients of scan over steps of scan over micros)."""
    from repro.analysis.trace.registry import EntryPoint
    return [EntryPoint(
        name="fl.executor_batched_round", path="src/repro/fl/executor.py",
        line=58, build=_batched_round_build,
        note="vmap(C=2) of scan(s=2) of scan(ga=1), b=4")]


EXECUTORS = {
    "sequential": SequentialExecutor,
    "batched": BatchedExecutor,
}


def make_executor(name: str, runner: ClientRunner) -> ClientExecutor:
    try:
        return EXECUTORS[name](runner)
    except KeyError:
        raise ValueError(f"unknown executor {name!r}; "
                         f"options: {sorted(EXECUTORS)}") from None
