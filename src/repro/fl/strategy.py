"""Federated strategies: who trains with what knobs, and how updates merge.

A ``FederatedStrategy`` answers the three server-side questions of
Algorithm 1, each independently replaceable:

    configure_round(rnd, clients) -> per-client Knobs      (lines 5-8)
    aggregate(deltas, weights)    -> combined delta tree   (line 15)
    update_state(usages, clients) -> per-profile duals     (line 17)

``aggregate`` is *pure delta combination*: the when/which of server
updates (round barrier, FedBuff buffering, staleness discounts, masked
sums, dropout renormalization) lives in ``repro.fl.aggregator``, which
routes every client's example count through ``ClientReport.weight``
and binds this method as its combine function — so ``ServerOpt`` and
weighted variants compose with every server-update policy.

``FedAvg`` fixes the knobs and averages; ``CAFLL`` runs the paper's
Lagrangian loop with one dual state *per device profile*; ``ServerOpt``
wraps any strategy with a FedOpt-family server optimizer (FedAvgM /
FedAdam) on the aggregated pseudo-gradient, proving the aggregation
axis composes.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax

from repro.configs.base import FLConfig
from repro.core import aggregation
from repro.core.duals import RESOURCES, DualState, dual_update
from repro.core.policy import Knobs, fedavg_knobs, policy
from repro.fl.device import DEFAULT_PROFILE, ClientInfo
from repro.optim import adam, make_optimizer


class FederatedStrategy:
    """Base strategy: plain-mean aggregation, no state. Subclasses
    override any subset of the three hooks."""

    name = "base"

    def configure_round(self, rnd: int, clients: Sequence[ClientInfo]
                        ) -> List[Knobs]:
        raise NotImplementedError

    def aggregate(self, deltas: Sequence, weights: Optional[List[float]] = None):
        """Pure delta combination. ``weights`` are the clients' example
        counts as routed by the aggregator (``ClientReport.weight``);
        the base strategy ignores them (the paper aggregates
        participating clients with a plain mean)."""
        return aggregation.aggregate(deltas)

    def update_state(self, usages: Sequence[Dict[str, float]],
                     clients: Sequence[ClientInfo]) -> Dict[str, Dict[str, float]]:
        """Consume the round's per-client usage — under fleet dynamics
        the engine passes only the clients that actually *reported*, so
        duals never move on work the server never saw. Returns the
        per-profile dual snapshot for logging ({} when the strategy
        keeps no duals; with no survivors the snapshot is unchanged)."""
        return {}

    def on_dropout(self, dropped: Sequence[ClientInfo]) -> None:
        """Observe clients that were sampled but missed the round
        deadline (their deltas and usages are discarded). Default:
        ignore — the FleetDynamics ledger already carries their token
        budget; strategies may additionally adapt."""

    def duals_snapshot(self) -> Dict[str, Dict[str, float]]:
        return {}


class FedAvg(FederatedStrategy):
    """The baseline: fixed knobs, no compression, no adaptation.
    ``weighted=True`` gives the |D_i|-weighted variant (Eq. 1)."""

    name = "fedavg"

    def __init__(self, fl: FLConfig, weighted: bool = False):
        self.fl = fl
        self.weighted = weighted

    def configure_round(self, rnd, clients):
        kn = fedavg_knobs(self.fl)
        return [kn] * len(clients)

    def aggregate(self, deltas, weights=None):
        return aggregation.aggregate(deltas, weights if self.weighted else None)


class CAFLL(FederatedStrategy):
    """The paper's constraint-aware loop, generalized to heterogeneous
    fleets: one ``DualState`` per device profile, updated against that
    profile's budgets with the mean usage of its sampled clients."""

    name = "cafl"

    def __init__(self, fl: FLConfig, init_duals: Optional[DualState] = None):
        self.fl = fl
        self.duals: Dict[str, DualState] = {}
        if init_duals is not None:
            self.duals[DEFAULT_PROFILE] = init_duals

    def duals_for(self, profile_name: str) -> DualState:
        return self.duals.setdefault(profile_name, DualState())

    def configure_round(self, rnd, clients):
        per_profile = {}
        for ci in clients:
            name = ci.profile.name
            if name not in per_profile:
                per_profile[name] = policy(self.duals_for(name), self.fl)
        return [per_profile[ci.profile.name] for ci in clients]

    def update_state(self, usages, clients):
        by_profile: Dict[str, list] = {}
        for u, ci in zip(usages, clients):
            by_profile.setdefault(ci.profile.name, []).append((u, ci.profile))
        for name, entries in by_profile.items():
            us = [u for u, _ in entries]
            profile = entries[0][1]
            mean = {r: sum(u[r] for u in us) / len(us) for r in RESOURCES}
            self.duals[name] = dual_update(self.duals_for(name), mean,
                                           profile.budgets, self.fl.duals)
        return self.duals_snapshot()

    def duals_snapshot(self):
        return {name: dict(st.lam) for name, st in self.duals.items()}


class ServerOpt(FederatedStrategy):
    """FedOpt-family wrapper: treat the inner strategy's aggregate as a
    pseudo-gradient and run a server optimizer over it (Reddi et al.,
    "Adaptive Federated Optimization"). ``optimizer="momentum"`` is
    FedAvgM, ``"adam"`` is FedAdam."""

    def __init__(self, inner: FederatedStrategy, optimizer: str = "adam",
                 lr: float = 0.1, eps: float = 0.1):
        self.inner = inner
        # FedAdam needs a LARGE adaptivity eps (the FedOpt paper's tau,
        # ~1e-3..1e-1): with the adam default 1e-8 the server step
        # degrades to sign descent of magnitude lr per coordinate and
        # diverges on pseudo-gradients this small.
        self.opt = (adam(lr, eps=eps) if optimizer == "adam"
                    else make_optimizer(optimizer, lr))
        self.name = f"{inner.name}+{optimizer}"
        self._state = None

    def configure_round(self, rnd, clients):
        return self.inner.configure_round(rnd, clients)

    def aggregate(self, deltas, weights=None):
        mean = self.inner.aggregate(deltas, weights)
        # pseudo-gradient g = -delta; optimizer returns the descent update
        g = jax.tree.map(lambda d: -d, mean)
        if self._state is None:
            self._state = self.opt.init(g)
        updates, self._state = self.opt.update(g, self._state, g)
        return updates

    def update_state(self, usages, clients):
        return self.inner.update_state(usages, clients)

    def on_dropout(self, dropped):
        self.inner.on_dropout(dropped)

    def duals_snapshot(self):
        return self.inner.duals_snapshot()


def make_strategy(method: str, fl: FLConfig,
                  init_duals: Optional[DualState] = None) -> FederatedStrategy:
    """Resolve a method string: "fedavg", "cafl", "fedavg_weighted",
    "fedadam", "fedavgm", or any base composed as "<base>+adam" /
    "<base>+momentum" (e.g. "cafl+adam"). ``fl.server_opt`` composes the
    same wrapper onto a plain method name."""
    name = method.lower()
    aliases = {"fedadam": "fedavg+adam", "fedavgm": "fedavg+momentum"}
    name = aliases.get(name, name)
    base_name, _, server = name.partition("+")
    if base_name == "fedavg":
        base: FederatedStrategy = FedAvg(fl)
    elif base_name == "fedavg_weighted":
        base = FedAvg(fl, weighted=True)
    elif base_name == "cafl":
        base = CAFLL(fl, init_duals=init_duals)
    else:
        raise ValueError(f"unknown federated method: {method!r}")
    server = server or fl.server_opt
    if server:
        base = ServerOpt(base, optimizer=server, lr=fl.server_lr)
    return base
