"""Federated strategies: who trains with what knobs, and how updates merge.

A ``FederatedStrategy`` answers the three server-side questions of
Algorithm 1, each independently replaceable:

    configure_round(rnd, clients) -> per-client Knobs      (lines 5-8)
    aggregate(deltas, weights)    -> combined delta tree   (line 15)
    update_state(usages, clients) -> per-profile duals     (line 17)

``aggregate`` is *pure delta combination*: the when/which of server
updates (round barrier, FedBuff buffering, staleness discounts, masked
sums, dropout renormalization) lives in ``repro.fl.aggregator``, which
routes every client's example count through ``ClientReport.weight``
and binds this method as its combine function — so ``ServerOpt`` and
weighted variants compose with every server-update policy.

``FedAvg`` fixes the knobs and averages; ``CAFLL`` runs the paper's
Lagrangian loop with one dual state *per device profile*; ``ServerOpt``
wraps any strategy with a FedOpt-family server optimizer (FedAvgM /
FedAdam) on the aggregated pseudo-gradient, proving the aggregation
axis composes.

``CAFLL``'s constraint loop is itself three pluggable axes
(``repro.constraints``): which resources are budgeted (``Constraint``
registry), how each dual answers its violation signal
(``DualController``), and how the duals steer the knobs
(``KnobPolicy``) — chosen per run via ``fl.constraints`` /
``fl.dual_controller`` / ``fl.knob_policy`` or constructor kwargs. The
default stack reproduces the seed trajectories bit-for-bit.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax

from repro.configs.base import FLConfig
from repro.constraints import (ConstraintReport, make_constraints,
                               make_controller, make_knob_policy,
                               resolve_dual_configs)
from repro.core import aggregation
from repro.core.duals import DualState
from repro.core.policy import Knobs, fedavg_knobs
from repro.fl.device import DEFAULT_PROFILE, ClientInfo
from repro.optim import adam, make_optimizer


class FederatedStrategy:
    """Base strategy: plain-mean aggregation, no state. Subclasses
    override any subset of the three hooks."""

    name = "base"

    def reset(self) -> None:
        """Clear per-run control transients (controller state, knob
        policy adaptations) — the engine calls this at the top of every
        ``run()``. Dual multipliers are *not* transients: they persist
        so ``init_duals`` warm continuation across runs keeps working."""

    def configure_round(self, rnd: int, clients: Sequence[ClientInfo]
                        ) -> List[Knobs]:
        raise NotImplementedError

    def aggregate(self, deltas: Sequence, weights: Optional[List[float]] = None):
        """Pure delta combination. ``weights`` are the clients' example
        counts as routed by the aggregator (``ClientReport.weight``);
        the base strategy ignores them (the paper aggregates
        participating clients with a plain mean)."""
        return aggregation.aggregate(deltas)

    def update_state(self, usages: Sequence[Dict[str, float]],
                     clients: Sequence[ClientInfo]) -> Dict[str, Dict[str, float]]:
        """Consume the round's per-client constraint measurements
        (dicts keyed by constraint name; the engine builds them from
        each ``ClientReport`` via the strategy's constraint set) — under
        fleet dynamics only clients that actually *reported* appear, so
        duals never move on work the server never saw. Returns the
        per-profile dual snapshot for logging ({} when the strategy
        keeps no duals; with no survivors the snapshot is unchanged)."""
        return {}

    def on_dropout(self, dropped: Sequence[ClientInfo]) -> None:
        """Observe clients that were sampled but missed the round
        deadline (their deltas and usages are discarded). Default:
        ignore — the FleetDynamics ledger already carries their token
        budget; strategies may additionally adapt."""

    def observe_round(self, plan, reports: Sequence, dynamics) -> None:
        """Round telemetry hook, fired after constraint accounting:
        the composition ``RoundPlan`` (with per-client arrival times),
        the delivered reports, and the live ``FleetDynamics``. Default:
        ignore; ``CAFLL`` forwards it to its knob policy so server-side
        knobs (deadline widening) can react."""

    def duals_snapshot(self) -> Dict[str, Dict[str, float]]:
        return {}

    def constraint_reports(self) -> Dict[str, List[ConstraintReport]]:
        """Per-profile ``ConstraintReport`` lists from the most recent
        ``update_state`` ({} for dual-free strategies or before the
        first update)."""
        return {}


class FedAvg(FederatedStrategy):
    """The baseline: fixed knobs, no compression, no adaptation.
    ``weighted=True`` gives the |D_i|-weighted variant (Eq. 1)."""

    name = "fedavg"

    def __init__(self, fl: FLConfig, weighted: bool = False):
        self.fl = fl
        self.weighted = weighted

    def configure_round(self, rnd, clients):
        kn = fedavg_knobs(self.fl)
        return [kn] * len(clients)

    def aggregate(self, deltas, weights=None):
        return aggregation.aggregate(deltas, weights if self.weighted else None)


class CAFLL(FederatedStrategy):
    """The paper's constraint-aware loop, generalized to heterogeneous
    fleets: one ``DualState`` per device profile, updated against that
    profile's budgets with the mean usage of its sampled clients.

    The constraint stack is pluggable (``repro.constraints``): the
    default — paper proxies x ``DeadzoneSubgradient`` x
    ``PaperKnobPolicy`` — is bit-for-bit the seed loop, while a
    registered fifth constraint, an adaptive/PI controller, or a
    deadline-aware knob policy drop in without touching the dual math.
    """

    name = "cafl"

    def __init__(self, fl: FLConfig, init_duals: Optional[DualState] = None,
                 constraints=None, controller=None, knob_policy=None):
        self.fl = fl
        self.constraints = make_constraints(
            constraints if constraints is not None else fl.constraints)
        self.controller = make_controller(
            controller if controller is not None else fl.dual_controller)
        self.knob_policy = make_knob_policy(
            knob_policy if knob_policy is not None else fl.knob_policy,
            constraints=self.constraints)
        # per-constraint dual configs: fl.dual_overrides lets one
        # constraint (say the latency dual) run a faster eta / tighter
        # deadzone without destabilizing the shared paper config.
        # Resolved once — typos in override names fail fast here.
        self._dual_cfgs = resolve_dual_configs(fl.duals, fl.dual_overrides,
                                               self.constraints.names)
        self.duals: Dict[str, DualState] = {}
        self._last_reports: Dict[str, List[ConstraintReport]] = {}
        if init_duals is not None:
            self.duals[DEFAULT_PROFILE] = init_duals

    def reset(self):
        self.controller.reset()
        self.knob_policy.reset()
        self._last_reports = {}

    def duals_for(self, profile_name: str) -> DualState:
        return self.duals.setdefault(
            profile_name, DualState(lam=self.constraints.init_lam()))

    def configure_round(self, rnd, clients):
        per_profile = {}
        for ci in clients:
            name = ci.profile.name
            if name not in per_profile:
                per_profile[name] = self.knob_policy.knobs(
                    self.duals_for(name), self.fl)
        return [per_profile[ci.profile.name] for ci in clients]

    def update_state(self, usages, clients):
        by_profile: Dict[str, list] = {}
        for u, ci in zip(usages, clients):
            by_profile.setdefault(ci.profile.name, []).append((u, ci.profile))
        self._last_reports = {}
        for name, entries in by_profile.items():
            us = [u for u, _ in entries]
            profile = entries[0][1]
            state = self.duals_for(name)
            new_lam = dict(state.lam)
            reports = []
            for c in self.constraints:
                mean = sum(u[c.name] for u in us) / len(us)
                budget = c.budget_of(profile.budgets)
                ratio = mean / budget
                prev = state.lam.get(c.name, 0.0)
                lam = self.controller.step(f"{name}:{c.name}", prev, ratio,
                                           self._dual_cfgs[c.name])
                new_lam[c.name] = lam
                reports.append(ConstraintReport(
                    name=c.name, profile=name, usage=mean, budget=budget,
                    ratio=ratio, lam_prev=prev, lam=lam,
                    violated=ratio > 1.0))
            self.duals[name] = DualState(lam=new_lam)
            self._last_reports[name] = reports
        return self.duals_snapshot()

    def observe_round(self, plan, reports, dynamics):
        self.knob_policy.observe(plan, reports, dynamics)

    def duals_snapshot(self):
        return {name: dict(st.lam) for name, st in self.duals.items()}

    def constraint_reports(self):
        return self._last_reports


class ServerOpt(FederatedStrategy):
    """FedOpt-family wrapper: treat the inner strategy's aggregate as a
    pseudo-gradient and run a server optimizer over it (Reddi et al.,
    "Adaptive Federated Optimization"). ``optimizer="momentum"`` is
    FedAvgM, ``"adam"`` is FedAdam."""

    def __init__(self, inner: FederatedStrategy, optimizer: str = "adam",
                 lr: float = 0.1, eps: float = 0.1):
        self.inner = inner
        # FedAdam needs a LARGE adaptivity eps (the FedOpt paper's tau,
        # ~1e-3..1e-1): with the adam default 1e-8 the server step
        # degrades to sign descent of magnitude lr per coordinate and
        # diverges on pseudo-gradients this small.
        self.opt = (adam(lr, eps=eps) if optimizer == "adam"
                    else make_optimizer(optimizer, lr))
        self.name = f"{inner.name}+{optimizer}"
        self._state = None

    def configure_round(self, rnd, clients):
        return self.inner.configure_round(rnd, clients)

    def aggregate(self, deltas, weights=None):
        mean = self.inner.aggregate(deltas, weights)
        # pseudo-gradient g = -delta; optimizer returns the descent update
        g = jax.tree.map(lambda d: -d, mean)
        if self._state is None:
            self._state = self.opt.init(g)
        updates, self._state = self.opt.update(g, self._state, g)
        return updates

    def reset(self):
        self.inner.reset()

    def update_state(self, usages, clients):
        return self.inner.update_state(usages, clients)

    def on_dropout(self, dropped):
        self.inner.on_dropout(dropped)

    def observe_round(self, plan, reports, dynamics):
        self.inner.observe_round(plan, reports, dynamics)

    def duals_snapshot(self):
        return self.inner.duals_snapshot()

    def constraint_reports(self):
        return self.inner.constraint_reports()

    @property
    def constraints(self):
        """The inner strategy's constraint set (None for dual-free
        bases) — the engine reads it to know what to measure."""
        return getattr(self.inner, "constraints", None)


def make_strategy(method: str, fl: FLConfig,
                  init_duals: Optional[DualState] = None,
                  constraints=None, controller=None,
                  knob_policy=None) -> FederatedStrategy:
    """Resolve a method string: "fedavg", "cafl", "fedavg_weighted",
    "fedadam", "fedavgm", or any base composed as "<base>+adam" /
    "<base>+momentum" (e.g. "cafl+adam"). ``fl.server_opt`` composes the
    same wrapper onto a plain method name; the constraint-stack kwargs
    (specs or instances) override ``fl.constraints`` /
    ``fl.dual_controller`` / ``fl.knob_policy`` for CAFLL bases."""
    name = method.lower()
    aliases = {"fedadam": "fedavg+adam", "fedavgm": "fedavg+momentum"}
    name = aliases.get(name, name)
    base_name, _, server = name.partition("+")
    if base_name == "fedavg":
        base: FederatedStrategy = FedAvg(fl)
    elif base_name == "fedavg_weighted":
        base = FedAvg(fl, weighted=True)
    elif base_name == "cafl":
        base = CAFLL(fl, init_duals=init_duals, constraints=constraints,
                     controller=controller, knob_policy=knob_policy)
    else:
        raise ValueError(f"unknown federated method: {method!r}")
    server = server or fl.server_opt
    if server:
        base = ServerOpt(base, optimizer=server, lr=fl.server_lr)
    return base
