"""Virtual wall clock: simulated time as a first-class engine axis.

The paper's thermal/latency story (Sec. 3, Eq. 5-7) is about *time* —
a round deadline is seconds of wall clock, a straggler draw is seconds
of device compute, FedBuff's headline win over the deadline-discard
barrier is fewer *seconds* to a loss target — but a round-count
simulation can only measure any of it in abstract rounds. This module
supplies the two pieces the engine threads through its loop to run in
``time_mode="wall_clock"``:

    SimClock        monotone virtual time, advanced on events (client
                    finishes, barrier/buffer completions). Every
                    advance is logged, so tests can assert no event is
                    lost and time never runs backwards.
    RoundTimeModel  how long a round takes on the server's clock:
                    client compute times come from the straggler
                    model's draws when it keeps a clock, else from the
                    knobs via the same ``compute_scale * s*ga*b /
                    work_unit`` law ``DeadlineStragglers`` uses
                    (``KnobRoundTime``), plus a fixed per-round server
                    cost (eval + aggregation).

Timing rules the engine applies (see ``FederatedEngine.run``):

    barrier rounds   last until every survivor reported, or until the
                     deadline when someone missed it (the server waited
                     in vain) — ``round_seconds`` = min(deadline, max
                     survivor time) + server cost
    buffered async   the round ends at the first mid-round server
                     update (the "buffer completes" event); deliveries
                     after it roll into the next round's inbox
    late reports     land at ``round_start + draw`` — their actual
                     simulated arrival — instead of the rounds-mode
                     ``ceil(t/deadline) - 1`` round-delay quantization,
                     so a report is never applied later (in seconds)
                     than the round-quantized schedule implies

``time_mode="rounds"`` keeps the seed semantics bit-for-bit (the golden
trajectories pin it); the clock still runs there, purely as accounting,
so ``RoundRecord.sim_time`` / ``round_seconds`` are comparable across
modes.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from repro.configs.base import FLConfig
from repro.core.policy import Knobs
from repro.fl.device import ClientInfo

TIME_MODES = ("rounds", "wall_clock")


class SimClock:
    """Monotone virtual time, advanced on simulation events.

    ``advance_to`` clamps backwards moves to the current time (time
    never reverses; an event that "happened" earlier than now is simply
    processed now), and every call is recorded in ``events`` as
    ``(label, requested_time, clock_after)`` so invariants — monotone
    readings, no event loss — are checkable from the log alone. The
    log keeps at most ``max_events`` entries (oldest half dropped when
    full; ``event_count`` keeps the true total) so a 100k-round horizon
    run cannot accumulate unbounded telemetry.
    """

    def __init__(self, start: float = 0.0, max_events: int = 100_000):
        assert start >= 0.0 and max_events >= 2
        self._now = float(start)
        self.max_events = max_events
        self.event_count = 0
        self.events: List[Tuple[str, float, float]] = []

    @property
    def now(self) -> float:
        return self._now

    def advance_to(self, t: float, label: str = "") -> float:
        """Move the clock to ``t`` (no-op if ``t`` is in the past) and
        return the new reading."""
        self._now = max(self._now, float(t))
        if len(self.events) >= self.max_events:
            del self.events[:self.max_events // 2]
        self.events.append((label, float(t), self._now))
        self.event_count += 1
        return self._now

    def advance(self, dt: float, label: str = "") -> float:
        assert dt >= 0.0, f"negative clock step {dt!r}"
        return self.advance_to(self._now + dt, label)

    def __repr__(self) -> str:
        return f"SimClock(now={self._now:.4f}, events={len(self.events)})"


class RoundTimeModel:
    """Server-side round duration from the round's composition.

        client_seconds(ci, kn)  one client's simulated compute time
        round_seconds(...)      the barrier's duration for one round

    The engine consults the model wherever the straggler model kept no
    wall clock (``NoStragglers`` draws no times), so every scenario —
    not just deadline ones — has a defined round length.
    """

    name = "base"

    def client_seconds(self, ci: ClientInfo, kn: Knobs) -> float:
        raise NotImplementedError

    def round_seconds(self, sampled: Sequence[ClientInfo],
                      knobs: Sequence[Knobs], times: Sequence[float],
                      survivor_idx: Sequence[int],
                      deadline: Optional[float]) -> float:
        raise NotImplementedError


@dataclass
class KnobRoundTime(RoundTimeModel):
    """The default model, on the same scale as ``DeadlineStragglers``:
    time 1.0 is one baseline round (``work_unit = s_base * b_base``
    sequences) on calibration silicon, so deadlines, straggler draws
    and round durations all share one unit.

    ``server_seconds`` is the fixed per-round server cost (eval, dual
    update, aggregation) added to every round. ``idle_seconds`` is the
    duration of a round nobody could join (no cohort, no deadline to
    wait out) — it must be positive or a ``horizon_seconds`` run over a
    dead fleet would never terminate.
    """

    name = "knob"

    work_unit: float = 1.0
    server_seconds: float = 0.0
    idle_seconds: float = 1.0

    def __post_init__(self):
        assert self.work_unit > 0 and self.server_seconds >= 0.0
        assert self.idle_seconds > 0.0

    @classmethod
    def for_config(cls, fl: FLConfig, **kw) -> "KnobRoundTime":
        return cls(work_unit=float(fl.s_base * fl.b_base), **kw)

    def client_seconds(self, ci, kn):
        return float(ci.profile.compute_scale
                     * (kn.s * kn.grad_accum * kn.b) / self.work_unit)

    def round_seconds(self, sampled, knobs, times, survivor_idx, deadline):
        if times:
            if len(survivor_idx) < len(times) and deadline is not None:
                # someone missed: the barrier waited out the deadline
                dur = float(deadline)
            else:
                dur = max((times[i] for i in survivor_idx),
                          default=float(deadline or 0.0))
        elif sampled:
            dur = max(self.client_seconds(ci, kn)
                      for ci, kn in zip(sampled, knobs))
        else:
            dur = float(deadline) if deadline else self.idle_seconds
        if dur <= 0.0:
            dur = self.idle_seconds
        return dur + self.server_seconds


@dataclass(frozen=True)
class TimedReport:
    """One in-flight client report on the wall-clock event queue.
    ``seq`` is the stamping order: simultaneous arrivals resolve to it,
    so a homogeneous cohort (identical finish times) delivers in cohort
    order — exactly the rounds-mode inbox order, which keeps the
    no-straggler wall-clock stream bit-identical to ``"rounds"``.

    ``tie`` sits between ``arrival`` and ``seq`` in the sort key. It is
    0.0 in production (the key degenerates to ``(arrival, seq)``); the
    schedule sanitizer (``repro.analysis.sched``) stamps seeded random
    ties to replay a run under a different — but equally legal —
    resolution of simultaneous arrivals. Any ordering the sanitizer can
    produce respects every arrival time, so a run whose results change
    under it was reading the tie-break, not the physics."""
    arrival: float                # absolute simulated arrival time
    report: object                # the ClientReport to deliver
    seq: int = 0                  # tie-break: stamping order
    tie: float = 0.0              # adversarial tie-break (sanitizer only)

    def sort_key(self):
        return (self.arrival, self.tie, self.seq)


@dataclass
class EventQueue:
    """Arrival-time-ordered pending reports for the wall-clock loop.
    Pure container semantics (push never drops, pop_until returns every
    event at or before the cutoff, exactly once) — property-tested."""

    _items: List[TimedReport] = field(default_factory=list)
    _seq: int = 0

    def stamp(self, arrival: float, report) -> TimedReport:
        """Mint an ordered event without queueing it (the engine stamps
        the current round's own finishes this way so they interleave
        deterministically with queued late arrivals).

        A NaN arrival is rejected here, not at sort time: NaN compares
        false against everything, so a NaN-stamped event would silently
        mis-sort (and ``pop_until`` would never deliver it). Infinite
        arrivals are rejected for the same reason — they can only mean
        a broken straggler draw upstream."""
        arrival = float(arrival)
        if not math.isfinite(arrival):
            raise ValueError(
                f"event arrival time must be finite, got {arrival!r}; "
                f"NaN/inf arrivals silently mis-sort the event queue")
        ev = TimedReport(arrival, report, self._seq)
        self._seq += 1
        return ev

    def push(self, arrival: float, report) -> None:
        """Queue a report for delivery at ``arrival``. Arrivals must be
        non-negative simulated seconds (the clock's origin is 0.0 and
        time is monotone — see ``SimClock``); ``stamp`` already rejects
        NaN/inf."""
        if float(arrival) < 0.0:
            raise ValueError(
                f"event arrival time must be >= 0, got {arrival!r}; "
                f"simulated time starts at 0.0 and never runs backwards")
        self._items.append(self.stamp(arrival, report))

    def push_event(self, ev: TimedReport) -> None:
        self._items.append(ev)

    def pop_until(self, cutoff: float) -> List[TimedReport]:
        due = sorted((e for e in self._items if e.arrival <= cutoff),
                     key=TimedReport.sort_key)
        self._items = [e for e in self._items if e.arrival > cutoff]
        return due

    def drain(self) -> List[TimedReport]:
        out = sorted(self._items, key=TimedReport.sort_key)
        self._items = []
        return out

    def __len__(self) -> int:
        return len(self._items)


def seconds_to_target(result, target: float) -> Optional[float]:
    """First simulated time at which a run's val loss reached
    ``target``, or None if it never did.

    The timing convention this encodes: a ``RoundRecord``'s
    ``val_loss`` is measured at round START (it is the loss the
    *previous* round's updates achieved), so a hit charges the round's
    start time ``sim_time - round_seconds`` — except the final record,
    whose loss is re-evaluated after the run's last update and so
    charges the full clock. Shared by ``benchmarks/fl_engine_bench``
    and ``examples/async_fleet`` so the two can never diverge on it.
    """
    history = result.history
    if not history:
        return None
    for r in history[:-1]:
        if r.val_loss <= target:
            return r.sim_time - r.round_seconds
    last = history[-1]
    return last.sim_time if last.val_loss <= target else None


def make_round_time(spec, fl: FLConfig) -> RoundTimeModel:
    """Resolve a round-time spec: an instance passes through; None /
    "knob" builds the default ``KnobRoundTime`` on the config's
    baseline work unit."""
    if isinstance(spec, RoundTimeModel):
        return spec
    if spec is None or spec == "knob":
        return KnobRoundTime.for_config(fl)
    raise ValueError(f"unknown round-time model {spec!r}; "
                     f"options: knob, or a RoundTimeModel instance")
