"""Round callbacks: side effects hooked out of the engine loop.

The seed hardcoded ``log=print`` into ``run_federated``; everything
observational (logging, checkpointing, history export, benchmark
timing) is now a ``RoundCallback`` so the engine itself stays pure
control flow.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, List, Optional


class RoundCallback:
    """Override any subset; all hooks default to no-ops."""

    def on_train_start(self, engine) -> None:
        pass

    def on_round_start(self, engine, rnd: int) -> None:
        pass

    def on_round_composed(self, engine, plan) -> None:
        """Fires once the round's fleet composition is fixed: ``plan``
        is a ``repro.fl.dynamics.RoundPlan`` (available / sampled /
        survivors / dropped / late client ids + straggler time draws)."""

    def on_server_update(self, engine, update) -> None:
        """Fires every time the aggregator turns buffered client
        reports into an applied ``ServerUpdate`` — once per round under
        the sync barrier, possibly several times (or zero) per round
        under FedBuff. ``engine.params`` already includes the update."""

    def on_dual_update(self, engine, rnd: int, constraint_reports) -> None:
        """Fires after the strategy's dual update, rounds where one ran
        (a dual-free strategy, or a round with no delivered reports,
        never fires it). ``constraint_reports`` maps each device-profile
        name to its list of ``repro.constraints.ConstraintReport``
        (usage / budget / ratio / lam move / violated, one per
        registered constraint)."""

    def on_round_end(self, engine, record) -> None:
        pass

    def on_train_end(self, engine, result) -> None:
        pass


class LoggingCallback(RoundCallback):
    """The seed's per-round log line, format preserved."""

    def __init__(self, log: Callable[[str], None] = print):
        self.log = log

    def on_round_end(self, engine, r) -> None:
        kn, rat, lam = r.knobs, r.ratios, r.duals
        if not kn:          # dynamics left the round with no cohort
            self.log(f"[{engine.strategy.name}] round {r.round:3d} "
                     f"val={r.val_loss:.4f} no clients reachable "
                     f"(available={r.num_available}) {r.seconds:.1f}s")
            return
        line = (
            f"[{engine.strategy.name}] round {r.round:3d} "
            f"val={r.val_loss:.4f} "
            f"knobs=(k={kn['k']},s={kn['s']},b={kn['b']},q={kn['q']},"
            f"ga={kn['grad_accum']}) "
            f"ratios=E{rat['energy']:.2f}/C{rat['comm']:.2f}/"
            f"M{rat['memory']:.2f}/T{rat['temp']:.2f} "
            f"lam=({lam['energy']:.2f},{lam['comm']:.2f},"
            f"{lam['memory']:.2f},{lam['temp']:.2f}) "
            f"{r.seconds:.1f}s")
        if r.dropped:       # seed format preserved unless dynamics bite
            line += (f" part={len(r.participants)}/{len(r.participants) + len(r.dropped)}"
                     f" drop={len(r.dropped)}")
        if r.late_arrivals:  # async aggregation delivered late reports
            line += (f" late={len(r.late_arrivals)}"
                     f" stale={r.mean_staleness:.2f}")
        if r.updates_applied != 1:   # not the plain one-barrier round
            line += f" upd={r.updates_applied}"
        if getattr(engine, "time_mode", "rounds") == "wall_clock":
            # simulated clock, in deadline units (seed format untouched
            # in the default rounds mode)
            line += f" sim={r.sim_time:.2f}(+{r.round_seconds:.2f})"
        self.log(line)


class CheckpointCallback(RoundCallback):
    """Save engine params every ``every`` rounds (0 = final only)."""

    def __init__(self, path: str, every: int = 0):
        self.path = path
        self.every = every

    def _save(self, engine) -> None:
        from repro.checkpointing import save
        os.makedirs(os.path.dirname(os.path.abspath(self.path)) or ".",
                    exist_ok=True)
        save(self.path, engine.params)

    def on_round_end(self, engine, record) -> None:
        if self.every and record.round % self.every == 0:
            self._save(engine)

    def on_train_end(self, engine, result) -> None:
        self._save(engine)


class HistoryWriterCallback(RoundCallback):
    """Dump the round-by-round history as JSON (the format
    ``benchmarks/common.load_fl`` and the fig/table scripts read)."""

    def __init__(self, path: str):
        self.path = path

    def on_train_end(self, engine, result) -> None:
        os.makedirs(os.path.dirname(os.path.abspath(self.path)) or ".",
                    exist_ok=True)
        payload = {
            "method": result.method,
            "summary": result.summary(),
            "history": [dataclasses.asdict(r) for r in result.history],
        }
        with open(self.path, "w") as f:
            json.dump(payload, f, indent=1)


class TimingCallback(RoundCallback):
    """Benchmark capture: wall-clock per round (excluding eval if the
    engine reports it) for the executor micro-benchmarks."""

    def __init__(self):
        self.round_seconds: List[float] = []
        self.total_seconds: Optional[float] = None
        self._t0 = None

    def on_train_start(self, engine) -> None:
        self._t0 = time.time()

    def on_round_end(self, engine, record) -> None:
        self.round_seconds.append(record.seconds)

    def on_train_end(self, engine, result) -> None:
        if self._t0 is not None:
            self.total_seconds = time.time() - self._t0
