"""Client-side LocalTrain (Algorithm 1, lines 10-11).

Runs ``s`` optimizer steps, each accumulating gradients over
``grad_accum`` microbatches of size ``b`` (token-budget preservation,
Eq. 8), with the bottom layers frozen per ``k`` (gradient mask) and the
resulting update quantized to level ``q`` for the wire.

Returns (delta_tree, usage, metrics) where usage is the paper's A.1
proxy evaluated at the executed knobs.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import compression, freezing
from repro.core.policy import Knobs
from repro.core.resources import ResourceModel
from repro.data.federated import FederatedData
from repro.models.zoo import Model
from repro.optim import make_optimizer


class ClientRunner:
    """Owns the jitted train-step cache shared by all simulated clients."""

    def __init__(self, model: Model, fl: FLConfig, data: FederatedData,
                 resources: ResourceModel):
        self.model = model
        self.fl = fl
        self.data = data
        self.resources = resources
        self.opt = make_optimizer(fl.optimizer, fl.lr, fl.weight_decay)
        self._grad_fns = {}
        self._masks = {}          # k -> mask tree
        self._active = {}         # k -> active param count

        @jax.jit
        def _apply(params, opt_state, grads, mask):
            grads = freezing.apply_mask(grads, mask)
            updates, opt_state = self.opt.update(grads, opt_state, params)
            updates = freezing.apply_mask(updates, mask)
            new_params = jax.tree.map(
                lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)
                              ).astype(p.dtype), params, updates)
            return new_params, opt_state

        self._apply = _apply

    def _grad_fn(self, b: int):
        if b not in self._grad_fns:
            loss_fn = self.model.train_loss

            @jax.jit
            def gf(params, batch):
                (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, batch)
                return loss, grads

            self._grad_fns[b] = gf
        return self._grad_fns[b]

    def mask_for(self, params, k: int):
        if k not in self._masks:
            self._masks[k] = freezing.mask_tree(params, self.model.cfg, k)
            self._active[k] = freezing.count_active(params, self._masks[k])
        return self._masks[k], self._active[k]

    def local_train(self, client_id: int, params, knobs: Knobs
                    ) -> Tuple[dict, Dict[str, float], Dict[str, float]]:
        fl = self.fl
        mask, active = self.mask_for(params, knobs.k)
        grad_fn = self._grad_fn(knobs.b)
        opt_state = self.opt.init(params)
        w = params
        losses = []
        for _ in range(knobs.s):
            grads_sum = None
            for _ in range(knobs.grad_accum):
                batch = self.data.batch(client_id, knobs.b, fl.seq_len)
                batch = {k2: jnp.asarray(v) for k2, v in batch.items()}
                loss, grads = grad_fn(w, batch)
                losses.append(float(loss))
                if grads_sum is None:
                    grads_sum = grads
                else:
                    grads_sum = jax.tree.map(lambda a, g: a + g, grads_sum, grads)
            if knobs.grad_accum > 1:
                grads_sum = jax.tree.map(lambda g: g / knobs.grad_accum,
                                         grads_sum)
            w, opt_state = self._apply(w, opt_state, grads_sum, mask)

        delta = jax.tree.map(lambda a, b_: a.astype(jnp.float32)
                             - b_.astype(jnp.float32), w, params)
        # wire compression (q knob) — quantize the update, server gets the
        # dequantized tree; masked (frozen) leaves are exact zeros either way
        delta = compression.compress_decompress(delta, knobs.q)
        delta = freezing.apply_mask(delta, mask)

        usage = self.resources.usage(active, knobs)
        usage_true = self.resources.usage(active, knobs, include_accum=True)
        metrics = {
            "train_loss": float(np.mean(losses)),
            "params_active": active,
            "wire_mb_actual": _masked_wire_mb(delta, mask, knobs.q),
            "energy_true": usage_true["energy"],
            "temp_true": usage_true["temp"],
        }
        return delta, usage, metrics


def _masked_wire_mb(delta, mask, q: int) -> float:
    """Actual bytes: only trainable leaves ship."""
    total = 0.0
    for leaf, m in zip(jax.tree.leaves(delta), jax.tree.leaves(mask)):
        m_arr = np.asarray(m)
        frac = float(np.mean(m_arr)) if m_arr.ndim else float(m_arr)
        n = frac * np.prod(leaf.shape)
        total += n * compression.BYTES_PER_PARAM[q]
        if q > 0:
            total += 4.0 * (n / 256.0)
    return total / 1e6
