"""Client-side LocalTrain (Algorithm 1, lines 10-11).

Runs ``s`` optimizer steps, each accumulating gradients over
``grad_accum`` microbatches of size ``b`` (token-budget preservation,
Eq. 8), with the bottom layers frozen per ``k`` (gradient mask) and the
resulting update quantized to level ``q`` for the wire.

``ClientRunner`` owns the jitted train-step caches shared by every
simulated client; the ``repro.fl`` executors drive it — sequentially
(one client at a time) or batched (a vmapped stack of clients).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import compression, freezing
from repro.core.policy import Knobs
from repro.core.resources import ResourceModel
from repro.data.federated import FederatedData
from repro.models.zoo import Model
from repro.optim import make_optimizer


@dataclass
class ClientResult:
    """What one client hands back to the server each round."""
    client_id: int
    delta: Any                  # masked, wire-compressed update tree
    params_active: float        # masked parameter count (proxies charge this)
    train_loss: float
    wire_mb_actual: float       # measured bytes incl. quantization scales


def apply_masked_update(opt, params, opt_state, grads, mask):
    """One optimizer step under a freezing mask: frozen leaves see zero
    gradient and zero movement; the add happens in fp32 then casts back.
    Shared by the sequential jitted step and the batched scan body."""
    grads = freezing.apply_mask(grads, mask)
    updates, opt_state = opt.update(grads, opt_state, params)
    updates = freezing.apply_mask(updates, mask)
    new_params = jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)
                      ).astype(p.dtype), params, updates)
    return new_params, opt_state


class ClientRunner:
    """Owns the jitted train-step cache shared by all simulated clients."""

    def __init__(self, model: Model, fl: FLConfig, data: FederatedData,
                 resources: ResourceModel):
        self.model = model
        self.fl = fl
        self.data = data
        self.resources = resources
        self.opt = make_optimizer(fl.optimizer, fl.lr, fl.weight_decay)
        # jitted: eager zeros_like per client/round is an implicit h2d
        # transfer (fill value) the steady-state guard pin disallows
        self._opt_init = jax.jit(self.opt.init)
        self._grad_fn_cache = None
        self._masks = {}          # k -> mask tree
        self._active = {}         # k -> active param count

        # opt-state and grads are rebound every step, so their buffers
        # are donated (in-place update; halves the step's transient
        # peak). params must NOT be donated: the first step reads the
        # caller's round-global tree, which finalize_delta and every
        # other client still need.
        @functools.partial(jax.jit, donate_argnums=(1, 2))
        def _apply(params, opt_state, grads, mask):
            return apply_masked_update(self.opt, params, opt_state, grads,
                                       mask)

        self._apply = _apply

    def grad_fn(self):
        if self._grad_fn_cache is None:
            loss_fn = self.model.train_loss

            @jax.jit
            def gf(params, batch):
                (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, batch)
                return loss, grads

            self._grad_fn_cache = gf
        return self._grad_fn_cache

    def mask_for(self, params, k: int):
        if k not in self._masks:
            self._masks[k] = freezing.mask_tree(params, self.model.cfg, k)
            self._active[k] = freezing.count_active(params, self._masks[k])
        return self._masks[k], self._active[k]

    def sample_batch(self, client_id: int, b: int):
        batch = self.data.batch(client_id, b, self.fl.seq_len)
        return {k: jnp.asarray(v) for k, v in batch.items()}

    def train_client(self, client_id: int, params, knobs: Knobs
                     ) -> ClientResult:
        """LocalTrain for one client. Loss stays on device until the
        single host sync at the end (no per-microbatch ``float(loss)``)."""
        mask, active = self.mask_for(params, knobs.k)
        grad_fn = self.grad_fn()
        opt_state = self._opt_init(params)
        w = params
        losses = []
        for _ in range(knobs.s):
            grads_sum = None
            for _ in range(knobs.grad_accum):
                batch = self.sample_batch(client_id, knobs.b)
                loss, grads = grad_fn(w, batch)
                losses.append(loss)
                if grads_sum is None:
                    grads_sum = grads
                else:
                    grads_sum = jax.tree.map(lambda a, g: a + g, grads_sum,
                                             grads)
            if knobs.grad_accum > 1:
                # 0-d f32 divisor: dividing by the Python int would be an
                # implicit h2d transfer per leaf under the transfer-guard
                # pin; bit-identical (small ints are exact in f32).
                accum = jnp.asarray(np.asarray(knobs.grad_accum, np.float32))
                grads_sum = jax.tree.map(lambda g: g / accum, grads_sum)
            w, opt_state = self._apply(w, opt_state, grads_sum, mask)

        topk = self.fl.wire_topk
        delta = finalize_delta(w, params, mask, knobs.q, topk=topk)
        train_loss = float(jnp.mean(jnp.stack(losses)))   # one sync/client
        return ClientResult(
            client_id=client_id, delta=delta, params_active=active,
            train_loss=train_loss,
            wire_mb_actual=_masked_wire_mb(delta, mask, knobs.q, topk=topk))

    def local_train(self, client_id: int, params, knobs: Knobs
                    ) -> Tuple[dict, Dict[str, float], Dict[str, float]]:
        """Back-compat wrapper: (delta, usage, metrics) with usage from the
        runner's own (fleet-wide) resource model."""
        r = self.train_client(client_id, params, knobs)
        usage = self.resources.usage(r.params_active, knobs)
        usage_true = self.resources.usage(r.params_active, knobs,
                                          include_accum=True)
        metrics = {
            "train_loss": r.train_loss,
            "params_active": r.params_active,
            "wire_mb_actual": r.wire_mb_actual,
            "energy_true": usage_true["energy"],
            "temp_true": usage_true["temp"],
        }
        return r.delta, usage, metrics


def finalize_delta(w, params, mask, q: int, topk=None):
    """Client update as shipped: fp32 difference, wire-compressed
    (q knob, optional top-k sparsification; the server immediately
    dequantizes), frozen leaves exact zeros either way."""
    delta = jax.tree.map(lambda a, b_: a.astype(jnp.float32)
                         - b_.astype(jnp.float32), w, params)
    delta = compression.compress_decompress(delta, q, topk=topk)
    return freezing.apply_mask(delta, mask)


#: one accounting unit is 2**-11 byte: the finest grain the wire
#: formats produce (1/2048 byte/param for the per-block scale share),
#: so per-param costs below are exact integers and the final scale-out
#: is a dyadic float multiply (bit-identical to the old float math)
_UNIT_BYTES = 2.0 ** -11
#: dense per-param unit costs by q (4 B, 1+1/64 B, 1/4+1/64 B — the
#: +1/64 is the fp32 block scale amortized over a 256-wide block)
_DENSE_UNITS = {0: 8192, 1: 2080, 2: 544}


def _masked_wire_mb(delta, mask, q: int, topk=None) -> float:
    """Actual bytes: only trainable leaves ship (exact-integer active
    counts; the per-block formulas mirror compression.wire_bytes)."""
    units = 0
    for leaf, m in zip(jax.tree.leaves(delta), jax.tree.leaves(mask)):
        m_arr = np.asarray(m)
        if m_arr.ndim:
            # masks broadcast against the leaf (per-unit singleton dims):
            # each nonzero mask entry governs leaf.size/mask.size params
            n = int(np.count_nonzero(m_arr)) * (
                int(np.prod(leaf.shape)) // m_arr.size)
        else:
            n = int(np.prod(leaf.shape)) * int(m_arr.item())
        if q == 0 or topk is None or topk >= 256:
            units += n * _DENSE_UNITS[q]
        else:
            bits = 8 if q == 1 else 2
            # per param: topk*bits/256 code bits + 1 bitmask bit
            # + 32/256 scale bits == (topk*bits + 288) units
            units += n * (topk * bits + 288)
    return compression.to_mb(units * _UNIT_BYTES)


# ---------------------------------------------------------------------------
# trace-analysis entry points (repro.analysis.trace)
# ---------------------------------------------------------------------------

#: the two operating points the static memory gate compares: the
#: FedAvg baseline batch (calibration — its traced peak *defines*
#: Table-1's 0.31 memory units, mirroring core.resources.calibrate)
#: vs the CAFL-L adapted batch, which is gated against Budgets.memory
TRACE_BASELINE_B = 32
TRACE_ADAPTED_B = 8


def _local_step(model, opt, params, opt_state, batch, mask):
    """One full local step (grad + masked update) as a single program:
    the unit whose peak the static memory gate prices."""
    (loss, _), grads = jax.value_and_grad(model.train_loss, has_aux=True)(
        params, batch)
    new_params, opt_state = apply_masked_update(opt, params, opt_state,
                                                grads, mask)
    return loss, new_params, opt_state


def _local_step_build(b: int):
    def build():
        from repro.analysis.trace.registry import charlm_trace_setup
        runner, params, batch = charlm_trace_setup(b=b)
        mask, _ = runner.mask_for(params, 0)
        opt_state = runner._opt_init(params)
        step = jax.jit(
            functools.partial(_local_step, runner.model, runner.opt),
            donate_argnums=(1,))
        return step, (params, opt_state, batch, mask)
    return build


def _grad_step_build():
    from repro.analysis.trace.registry import charlm_trace_setup
    runner, params, batch = charlm_trace_setup(b=TRACE_ADAPTED_B)
    return runner.grad_fn(), (params, batch)


def _update_step_build():
    from repro.analysis.trace.registry import charlm_trace_setup
    runner, params, batch = charlm_trace_setup(b=TRACE_ADAPTED_B)
    mask, _ = runner.mask_for(params, 0)
    opt_state = runner._opt_init(params)
    grads = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params)
    return runner._apply, (params, opt_state, grads, mask)


def trace_entry_points() -> List[Any]:
    """Declared traceable surfaces of the client update path."""
    from repro.analysis.trace.registry import EntryPoint
    path = "src/repro/core/client.py"
    return [
        EntryPoint(
            name="fl.client_grad_step", path=path, line=89,
            build=_grad_step_build,
            note="value_and_grad of the char-LM train loss"),
        EntryPoint(
            name="fl.client_update_step", path=path, line=77,
            build=_update_step_build, donatable=(1, 2),
            note="masked optimizer step; opt-state + grads donated"),
        EntryPoint(
            name="fl.client_local_step", path=path, line=214,
            build=_local_step_build(TRACE_ADAPTED_B), donatable=(1,),
            gated=True,
            note=f"grad + update at adapted b={TRACE_ADAPTED_B}"),
        EntryPoint(
            name="fl.client_local_step@baseline", path=path, line=214,
            build=_local_step_build(TRACE_BASELINE_B), donatable=(1,),
            calibration=True,
            note=f"grad + update at baseline b={TRACE_BASELINE_B}"),
    ]
