"""CAFL-L / FedAvg server (Algorithm 1).

One ``run_federated`` drives both methods: ``method="fedavg"`` uses fixed
baseline knobs and skips dual updates; ``method="cafl"`` runs the full
constraint-aware loop: evaluate -> policy pi(lambda) -> LocalTrain on the
sampled clients -> aggregate -> dual ascent on mean usage.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import aggregation
from repro.core.client import ClientRunner
from repro.core.duals import DualState, dual_update, usage_ratios
from repro.core.policy import Knobs, fedavg_knobs, policy
from repro.core.resources import ResourceModel, calibrate
from repro.data.federated import FederatedData
from repro.data.shakespeare import CharDataset, sample_batch
from repro.models.zoo import Model


@dataclass
class RoundRecord:
    round: int
    val_loss: float
    knobs: Dict
    usage: Dict[str, float]
    ratios: Dict[str, float]
    duals: Dict[str, float]
    train_loss: float
    wire_mb_actual: float
    energy_true: float
    seconds: float


@dataclass
class FLResult:
    method: str
    history: List[RoundRecord] = field(default_factory=list)
    final_params: Optional[dict] = None

    def tail_mean(self, getter, n: int = 10) -> float:
        vals = [getter(r) for r in self.history[-n:]]
        return float(np.mean(vals))

    def summary(self, tail: int = 10) -> Dict[str, float]:
        return {
            "energy": self.tail_mean(lambda r: r.usage["energy"], tail),
            "comm_mb": self.tail_mean(lambda r: r.usage["comm"], tail),
            "memory": self.tail_mean(lambda r: r.usage["memory"], tail),
            "temp": self.tail_mean(lambda r: r.usage["temp"], tail),
            "val_loss": self.tail_mean(lambda r: r.val_loss, tail),
            "wire_mb_actual": self.tail_mean(lambda r: r.wire_mb_actual, tail),
            "energy_true": self.tail_mean(lambda r: r.energy_true, tail),
        }


def make_eval_fn(model: Model, dataset: CharDataset, fl: FLConfig):
    loss_jit = jax.jit(model.train_loss)
    rng = np.random.default_rng(fl.seed + 777)
    batches = [sample_batch(dataset.val, rng, fl.eval_batch_size, fl.seq_len)
               for _ in range(fl.eval_batches)]
    batches = [{k: jnp.asarray(v) for k, v in b.items()} for b in batches]

    def evaluate(params) -> float:
        losses = [float(loss_jit(params, b)[0]) for b in batches]
        return float(np.mean(losses))

    return evaluate


def run_federated(model: Model, fl: FLConfig, dataset: CharDataset,
                  method: Optional[str] = None, rounds: Optional[int] = None,
                  resources: Optional[ResourceModel] = None,
                  init_params=None, init_duals: Optional[DualState] = None,
                  log=print) -> FLResult:
    method = method or fl.method
    rounds = rounds or fl.rounds
    rng = np.random.default_rng(fl.seed)

    params = init_params if init_params is not None else \
        model.init(jax.random.PRNGKey(fl.seed))
    data = FederatedData(dataset.train, fl.num_clients, seed=fl.seed,
                         noniid_alpha=fl.noniid_alpha)

    # calibrate proxies at the baseline operating point (all layers active)
    if resources is None:
        from repro.core.freezing import count_params
        p_all = count_params(params)
        resources = calibrate(p_all, fl)

    runner = ClientRunner(model, fl, data, resources)
    evaluate = make_eval_fn(model, dataset, fl)
    duals = init_duals if init_duals is not None else DualState()
    result = FLResult(method=method)

    for t in range(1, rounds + 1):
        t0 = time.time()
        val_loss = evaluate(params)
        clients = rng.choice(fl.num_clients, size=fl.clients_per_round,
                             replace=False)
        knobs: Knobs = policy(duals, fl) if method == "cafl" else fedavg_knobs(fl)

        deltas, usages, metrics = [], [], []
        for cid in clients:
            d, u, m = runner.local_train(int(cid), params, knobs)
            deltas.append(d)
            usages.append(u)
            metrics.append(m)

        mean_delta = aggregation.aggregate(deltas)
        params = aggregation.apply_delta(params, mean_delta)

        usage = {k: float(np.mean([u[k] for u in usages]))
                 for k in usages[0]}
        ratios = usage_ratios(usage, fl.budgets)
        if method == "cafl":
            duals = dual_update(duals, usage, fl.budgets, fl.duals)

        rec = RoundRecord(
            round=t, val_loss=val_loss, knobs=knobs.as_dict(), usage=usage,
            ratios=ratios, duals=dict(duals.lam),
            train_loss=float(np.mean([m["train_loss"] for m in metrics])),
            wire_mb_actual=float(np.mean([m["wire_mb_actual"] for m in metrics])),
            energy_true=float(np.mean([m["energy_true"] for m in metrics])),
            seconds=time.time() - t0)
        result.history.append(rec)
        if log:
            log(f"[{method}] round {t:3d} val={val_loss:.4f} "
                f"knobs=(k={knobs.k},s={knobs.s},b={knobs.b},q={knobs.q},"
                f"ga={knobs.grad_accum}) "
                f"ratios=E{ratios['energy']:.2f}/C{ratios['comm']:.2f}/"
                f"M{ratios['memory']:.2f}/T{ratios['temp']:.2f} "
                f"lam=({duals.lam['energy']:.2f},{duals.lam['comm']:.2f},"
                f"{duals.lam['memory']:.2f},{duals.lam['temp']:.2f}) "
                f"{rec.seconds:.1f}s")

    result.final_params = params
    result.history[-1].val_loss = evaluate(params)
    return result
