"""CAFL-L / FedAvg server entry point (Algorithm 1).

The federated loop itself lives in ``repro.fl`` — a composable engine of
``FederatedStrategy`` x ``ClientExecutor`` x ``DeviceProfile`` x
``RoundCallback``. ``run_federated`` is the seed-compatible wrapper:
``method="fedavg"`` uses fixed baseline knobs and skips dual updates;
``method="cafl"`` runs the full constraint-aware loop; FedOpt-style
server optimizers compose as ``method="fedadam"`` / ``"cafl+adam"``.

This module keeps the result dataclasses and the eval builder so that
``repro.core`` and ``repro.fl`` have no import cycle (``repro.fl``
imports them from here; the wrapper imports the engine lazily).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.duals import DualState
from repro.core.resources import ResourceModel
from repro.data.shakespeare import CharDataset, sample_batch
from repro.models.zoo import Model


@dataclass
class RoundRecord:
    round: int
    val_loss: float
    knobs: Dict
    usage: Dict[str, float]
    ratios: Dict[str, float]
    duals: Dict[str, float]
    train_loss: float
    wire_mb_actual: float
    energy_true: float
    seconds: float
    # --- virtual wall clock (repro.fl.clock) ---
    # simulated clock at the end of this round (cumulative seconds in
    # deadline units: 1.0 = one baseline round on calibration silicon)
    # and this round's simulated duration. Populated in BOTH time
    # modes — in time_mode="rounds" purely as accounting, so seconds-
    # to-target is comparable across modes. 0.0 = pre-clock record.
    sim_time: float = 0.0
    round_seconds: float = 0.0
    # per-device-class breakdown; empty for a homogeneous fleet
    per_profile: Dict[str, Dict] = field(default_factory=dict)
    # --- fleet dynamics (repro.fl.dynamics) ---
    # clients whose report reached the server this round (their usages
    # drive the dual update and their deltas the server updates);
    # under a sync barrier these are exactly the deadline survivors
    participants: List[int] = field(default_factory=list)
    # sampled clients whose report was LOST this round (missed the
    # deadline and the aggregator does not take late reports; token
    # budget carried to their next participation)
    dropped: List[int] = field(default_factory=list)
    # fleet size the round could see after availability gating
    # (-1 = record predates fleet dynamics)
    num_available: int = -1
    # --- server-update policy (repro.fl.aggregator) ---
    # ServerUpdates applied this round (sync barrier: 1, or 0 with no
    # survivors; FedBuff may apply several mid-round or none)
    updates_applied: int = 0
    # client reports folded into those updates
    reports_applied: int = 0
    # mean staleness (rounds late) over the reports delivered this
    # round; 0.0 for a pure barrier round
    mean_staleness: float = 0.0
    # deadline-missers from earlier rounds whose report arrived at the
    # aggregator this round (an async policy may buffer it and apply
    # it in a later update — see updates_applied/reports_applied)
    late_arrivals: List[int] = field(default_factory=list)
    # --- constraint stack (repro.constraints) ---
    # per-constraint accounting for the default profile:
    # {name: {"ratio": u/b, "lam": dual after this round's update,
    #         "violated": u > b}} — every registered constraint appears,
    # not just the paper's four (empty for pre-refactor records)
    constraints: Dict[str, Dict] = field(default_factory=dict)


@dataclass
class FLResult:
    method: str
    history: List[RoundRecord] = field(default_factory=list)
    final_params: Optional[dict] = None

    def tail_mean(self, getter, n: int = 10) -> float:
        vals = [getter(r) for r in self.history[-n:]]
        return float(np.mean(vals))

    def summary(self, tail: int = 10) -> Dict[str, float]:
        return {
            "energy": self.tail_mean(lambda r: r.usage["energy"], tail),
            "comm_mb": self.tail_mean(lambda r: r.usage["comm"], tail),
            "memory": self.tail_mean(lambda r: r.usage["memory"], tail),
            "temp": self.tail_mean(lambda r: r.usage["temp"], tail),
            "val_loss": self.tail_mean(lambda r: r.val_loss, tail),
            "wire_mb_actual": self.tail_mean(lambda r: r.wire_mb_actual, tail),
            "energy_true": self.tail_mean(lambda r: r.energy_true, tail),
        }


def make_eval_fn(model: Model, dataset: CharDataset, fl: FLConfig):
    loss_jit = jax.jit(model.train_loss)
    rng = np.random.default_rng(fl.seed + 777)
    batches = [sample_batch(dataset.val, rng, fl.eval_batch_size, fl.seq_len)
               for _ in range(fl.eval_batches)]
    batches = [{k: jnp.asarray(v) for k, v in b.items()} for b in batches]

    def evaluate(params) -> float:
        losses = [float(loss_jit(params, b)[0]) for b in batches]
        return float(np.mean(losses))

    return evaluate


def run_federated(model: Model, fl: FLConfig, dataset: CharDataset,
                  method: Optional[str] = None, rounds: Optional[int] = None,
                  resources: Optional[ResourceModel] = None,
                  init_params=None, init_duals: Optional[DualState] = None,
                  log=print, time_mode: Optional[str] = None,
                  horizon_seconds: Optional[float] = None) -> FLResult:
    """Seed-compatible driver: builds a ``FederatedEngine`` with the
    default homogeneous fleet and a logging callback, then runs it.
    ``time_mode`` / ``horizon_seconds`` pass through to the engine
    (defaults come from ``fl.time_mode`` / ``fl.horizon_seconds``)."""
    from repro.fl.callbacks import LoggingCallback
    from repro.fl.engine import FederatedEngine

    engine = FederatedEngine(
        model, fl, dataset,
        strategy=method or fl.method,
        callbacks=[LoggingCallback(log)] if log else [],
        resources=resources,
        init_duals=init_duals)
    return engine.run(rounds=rounds, init_params=init_params,
                      time_mode=time_mode, horizon_seconds=horizon_seconds)
