"""Policy pi(lambda) -> (k, s, b, q)  (paper Eq. 5-7 + compression rule).

    k = max(1,  k_base - floor(alpha_k (lam_C + lam_M + 0.5 lam_T)))
    s = max(10, floor(s_base (1 - beta_s (lam_E + lam_T))))
    b = max(8,  floor(b_base / (1 + gamma_b (lam_T + lam_M))))

q (compression level: 0 = 32-bit, 1 = 8-bit, 2 = 2-bit) is driven by the
communication dual — the paper states the mapping qualitatively; the
thresholds here are the config's ``q_thresholds``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.configs.base import DualConfig, FLConfig
from repro.core.duals import DualState


@dataclass(frozen=True)
class Knobs:
    k: int      # unfrozen (top) layers
    s: int      # local steps
    b: int      # microbatch size
    q: int      # compression level: 0=fp32, 1=int8, 2=2-bit
    grad_accum: int = 1

    def as_dict(self):
        return {"k": self.k, "s": self.s, "b": self.b, "q": self.q,
                "grad_accum": self.grad_accum}


Q_THRESHOLDS = (0.25, 1.0)  # lam_C above these -> q=1, q=2


def policy(duals: DualState, fl: FLConfig) -> Knobs:
    """The paper's Eq. 5-7 mapping over the four canonical dual groups.
    ``repro.constraints.PaperKnobPolicy`` wraps this (folding any extra
    constraints' duals into the groups first); other mappings plug in as
    alternative ``KnobPolicy`` implementations. Missing groups read as
    zero pressure so reduced constraint stacks stay usable."""
    d: DualConfig = fl.duals
    lam = duals.lam
    lam_e, lam_c, lam_m, lam_t = (lam.get("energy", 0.0), lam.get("comm", 0.0),
                                  lam.get("memory", 0.0), lam.get("temp", 0.0))
    k = max(d.k_min, fl.k_base
            - math.floor(d.alpha_k * (lam_c + lam_m + 0.5 * lam_t)))
    s = max(d.s_min, math.floor(fl.s_base * (1 - d.beta_s * (lam_e + lam_t))))
    b = max(d.b_min, math.floor(fl.b_base / (1 + d.gamma_b * (lam_t + lam_m))))
    if lam_c > Q_THRESHOLDS[1]:
        q = 2
    elif lam_c > Q_THRESHOLDS[0]:
        q = 1
    else:
        q = 0
    accum = token_budget_accum(fl, s, b)
    return Knobs(k=k, s=s, b=b, q=q, grad_accum=accum)


def token_budget_accum(fl: FLConfig, s: int, b: int) -> int:
    """Token-budget preservation (paper Eq. 8):
    grad_accum = max(1, ceil(T_target / (s * b))), T_target = s_base*b_base.
    ``fl.token_budget=False`` ablates it (grad_accum = 1).

    ``fl.token_preservation="clamped"`` rounds *down* instead: once the
    duals shrink s and b, the ceil can overshoot the target by up to
    s*b-1 tokens and inflate simulated round time ~1.5x — enough to
    starve a tight straggler deadline (see ROADMAP / the unreliable
    fleet example). Clamped mode never trains past the baseline round
    (s * grad_accum * b <= T_target whenever s*b <= T_target), trading
    a bounded token undershoot for deadline safety."""
    if fl.token_preservation not in ("ceil", "clamped"):
        raise ValueError(
            f"unknown token_preservation {fl.token_preservation!r}; "
            f"options: ceil, clamped")
    if not fl.token_budget:
        return 1
    t_target = fl.s_base * fl.b_base
    if fl.token_preservation == "clamped":
        return max(1, t_target // (s * b))
    return max(1, math.ceil(t_target / (s * b)))


def fedavg_knobs(fl: FLConfig) -> Knobs:
    """The FedAvg baseline: fixed knobs, no compression, no adaptation."""
    return Knobs(k=fl.k_base, s=fl.s_base, b=fl.b_base, q=0, grad_accum=1)
