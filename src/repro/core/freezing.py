"""Freezing-depth (the policy's ``k`` knob) as a parameter mask tree.

``k`` = number of *top* (closest-to-head) unfrozen transformer layers.
Frozen layers carry no gradients, no optimizer movement, and are excluded
from ``params_active`` — which is what the paper's E/C/M proxies charge
for. The mask is a pytree of 0/1 floats shaped to broadcast against each
leaf; for scan-stacked unit params the mask is per-unit along axis 0, so a
single compiled step serves every value of k.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import stack_plan


def _layer_bounds(cfg: ModelConfig):
    prefix, unit, n_units, suffix = stack_plan(cfg)
    return len(prefix), len(unit), n_units, len(suffix)


def mask_tree(params: Any, cfg: ModelConfig, k: int) -> Any:
    """1.0 = trainable, 0.0 = frozen. Top-k layers + head/final norm are
    trainable; embeddings freeze whenever any layer is frozen."""
    n_prefix, unit_len, n_units, n_suffix = _layer_bounds(cfg)
    total = cfg.num_layers
    k = max(1, min(k, total))
    first_unfrozen = total - k          # layer index of first trainable layer

    def ones_like(t):
        return jax.tree.map(lambda l: jnp.ones((), jnp.float32), t)

    def zeros_like(t):
        return jax.tree.map(lambda l: jnp.zeros((), jnp.float32), t)

    mask = {}
    stack = params["stack"] if "stack" in params else None
    if stack is not None:
        smask = {}
        if "prefix" in stack:
            smask["prefix"] = [
                ones_like(p) if i >= first_unfrozen else zeros_like(p)
                for i, p in enumerate(stack["prefix"])]
        if "units" in stack:
            unit_first_layer = np.arange(n_units) * unit_len + n_prefix
            # a unit is trainable iff its *last* layer is unfrozen; partial
            # units round down (freeze) to keep one executable per k.
            unit_last_layer = unit_first_layer + unit_len - 1
            unit_trainable = (unit_last_layer >= first_unfrozen).astype(np.float32)
            vec = jnp.asarray(unit_trainable)

            def unit_mask(leaf):
                shape = (n_units,) + (1,) * (leaf.ndim - 1)
                return vec.reshape(shape)

            smask["units"] = jax.tree.map(unit_mask, stack["units"])
        if "suffix" in stack:
            base = n_prefix + unit_len * n_units
            smask["suffix"] = [
                ones_like(p) if base + i >= first_unfrozen else zeros_like(p)
                for i, p in enumerate(stack["suffix"])]
        mask["stack"] = smask
    if "io" in params:
        io = params["io"]
        full = (k >= total)
        iomask = {}
        for key in io:
            if key in ("embed", "pos_embed", "frontend_proj"):
                iomask[key] = jax.tree.map(
                    lambda l: jnp.asarray(1.0 if full else 0.0, jnp.float32),
                    io[key])
            else:                        # head, final_norm: always trainable
                iomask[key] = ones_like(io[key])
        mask["io"] = iomask
    for key in params:
        if key not in mask:              # enc/dec stacks etc.
            mask[key] = ones_like(params[key])
    return mask


def apply_mask(tree: Any, mask: Any) -> Any:
    return jax.tree.map(lambda t, m: t * m.astype(t.dtype), tree, mask)


def count_params(params: Any) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))


def count_active(params: Any, mask: Any) -> float:
    """Masked parameter count (params the round actually trains/ships)."""
    total = 0.0
    for leaf, m in zip(jax.tree.leaves(params), jax.tree.leaves(mask)):
        m_arr = np.asarray(m)
        size = np.prod(leaf.shape)
        if m_arr.ndim == 0:
            total += float(m_arr) * size
        else:
            frac = float(np.mean(m_arr))
            total += frac * size
    return total
