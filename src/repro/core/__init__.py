"""CAFL-L: the paper's primary contribution — constraint-aware federated
learning with Lagrangian dual optimization (duals, policy, resource
proxies, token-budget preservation, compression, freezing, client/server).
"""
from repro.core.duals import (  # noqa: F401
    RESOURCES, DualState, deadzone, dual_update, lagrangian_value,
    usage_ratios,
)
from repro.core.policy import (  # noqa: F401
    Knobs, fedavg_knobs, policy, token_budget_accum,
)
from repro.core.resources import (  # noqa: F401
    BYTES_PER_PARAM, TABLE1_FEDAVG, ResourceModel, calibrate,
)
from repro.core import aggregation  # noqa: F401
from repro.core.client import ClientResult, ClientRunner  # noqa: F401
from repro.core.server import FLResult, RoundRecord, run_federated  # noqa: F401
