"""Server-side delta combination (Algorithm 1, line 15).

The paper aggregates the *participating* clients' deltas with a plain
mean: w <- w + (1/|S_t|) sum_i dw_i. Passing ``weights`` gives the
|D_i|-weighted FedAvg variant (Eq. 1).

Weight normalization lives in one place — ``normalize_weights`` — so
every caller (``FedAvg(weighted=True)``, ``ServerOpt``'s inner combine,
the ``repro.fl.aggregator`` policies, dropout renormalization over
survivors) shares the same renormalization semantics: whatever subset
of clients is present, their weights are rescaled to sum to 1.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def normalize_weights(weights: Optional[Sequence[float]], n: int
                      ) -> List[float]:
    """The shared renormalization path: ``None`` -> uniform 1/n; else
    weights rescaled to sum to 1 over the clients that are present."""
    assert n > 0
    if weights is None:
        return [1.0 / n] * n
    assert len(weights) == n
    tot = sum(weights)
    assert tot > 0, "aggregation weights must have positive mass"
    return [x / tot for x in weights]


def aggregate(deltas: Sequence, weights: Optional[List[float]] = None):
    n = len(deltas)
    assert n > 0
    w = normalize_weights(weights, n)
    # Pre-staged 0-d f32 scalars: combining with Python floats would be
    # an implicit host->device transfer per leaf, which the steady-state
    # transfer-guard pin (repro.analysis.runtime) disallows. Explicit
    # numpy ingestion is guard-exempt and bit-identical to the weak-typed
    # Python-float path for f32 leaves.
    w_dev = [jnp.asarray(np.asarray(x, np.float32)) for x in w]

    def combine(*leaves):
        acc = leaves[0].astype(jnp.float32) * w_dev[0]
        for wi, leaf in zip(w_dev[1:], leaves[1:]):
            acc = acc + leaf.astype(jnp.float32) * wi
        return acc

    return jax.tree.map(combine, *deltas)


def apply_delta(params, delta):
    return jax.tree.map(lambda p, d: (p.astype(jnp.float32)
                                      + d.astype(jnp.float32)).astype(p.dtype),
                        params, delta)
