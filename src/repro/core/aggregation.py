"""Server-side aggregation (Algorithm 1, line 15).

The paper aggregates the *participating* clients' deltas with a plain
mean: w <- w + (1/|S_t|) sum_i dw_i. ``weighted=True`` gives the
|D_i|-weighted FedAvg variant (Eq. 1) for ablations.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp


def aggregate(deltas: Sequence, weights: Optional[List[float]] = None):
    n = len(deltas)
    assert n > 0
    if weights is None:
        w = [1.0 / n] * n
    else:
        tot = sum(weights)
        w = [x / tot for x in weights]

    def combine(*leaves):
        acc = leaves[0].astype(jnp.float32) * w[0]
        for wi, leaf in zip(w[1:], leaves[1:]):
            acc = acc + leaf.astype(jnp.float32) * wi
        return acc

    return jax.tree.map(combine, *deltas)


def apply_delta(params, delta):
    return jax.tree.map(lambda p, d: (p.astype(jnp.float32)
                                      + d.astype(jnp.float32)).astype(p.dtype),
                        params, delta)
