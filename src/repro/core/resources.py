"""Resource-usage proxies (paper Appendix A.1) + calibration.

    E ~ alpha_E * params_active * s * b
    C ~ sparsity * params_active * bytes_per_param(q)
    M ~ alpha_M * (0.2 + beta_M * params_active * b)
    T ~ alpha_T * (0.35 + gamma_T * s + delta_T * b)

The paper reports *relative units* "derived from these proxies" and says
constants "can be adapted or re-scaled for specific device profiles".
``calibrate`` pins the constants so the FedAvg baseline reproduces the
paper's Table 1 FedAvg row exactly (E 4.52e6, C 5.18 MB, T 0.62, M 0.31)
given *our* model's true active-parameter count — this preserves every
violation ratio the paper reports while staying honest about parameter
counts (see EXPERIMENTS.md §Paper).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict

from repro.configs.base import FLConfig
from repro.core.policy import Knobs

BYTES_PER_PARAM = {0: 4.0, 1: 1.0, 2: 0.25}

# Table 1 "FedAvg" row — calibration targets.
TABLE1_FEDAVG = {"energy": 4.52e6, "comm": 5.18, "temp": 0.62, "memory": 0.31}


@dataclass(frozen=True)
class ResourceModel:
    alpha_e: float
    kappa_c: float          # MB per (param * byte)
    sparsity: float
    alpha_m: float
    beta_m: float
    alpha_t: float
    gamma_t: float
    delta_t: float

    def usage(self, params_active: float, knobs: Knobs,
              include_accum: bool = False) -> Dict[str, float]:
        """Per-client usage for one round. ``include_accum`` is the
        beyond-paper 'true compute' variant: the paper's proxy (A.1)
        deliberately charges energy for s*b only, not the accumulated
        microbatches (see EXPERIMENTS.md §Paper for the discussion)."""
        s_eff = knobs.s * (knobs.grad_accum if include_accum else 1)
        e = self.alpha_e * params_active * s_eff * knobs.b
        c = self.sparsity * params_active * BYTES_PER_PARAM[knobs.q] * self.kappa_c
        m = self.alpha_m * (0.2 + self.beta_m * params_active * knobs.b)
        t = self.alpha_t * (0.35 + self.gamma_t * s_eff + self.delta_t * knobs.b)
        return {"energy": e, "comm": c, "memory": m, "temp": t}

    def scaled(self, energy: float = 1.0, comm: float = 1.0,
               memory: float = 1.0, temp: float = 1.0) -> "ResourceModel":
        """Per-device-class efficiency variant: a low-end handset burns
        more energy / runs hotter per token than the calibration device
        (>1 = less efficient). Used by ``repro.fl.device`` fleets."""
        return dataclasses.replace(
            self, alpha_e=self.alpha_e * energy, kappa_c=self.kappa_c * comm,
            alpha_m=self.alpha_m * memory, alpha_t=self.alpha_t * temp)


def calibrate(params_active_base: float, fl: FLConfig) -> ResourceModel:
    """Pin proxy constants to the paper's Table 1 FedAvg row at the
    baseline knobs (k_base: all params active, s_base, b_base, q=0)."""
    s, b = fl.s_base, fl.b_base
    p = float(params_active_base)
    alpha_e = TABLE1_FEDAVG["energy"] / (p * s * b)
    kappa_c = TABLE1_FEDAVG["comm"] / (p * BYTES_PER_PARAM[0])
    # memory: floor 0.2 (activations/runtime) + param*batch term = 0.31
    alpha_m = 1.0
    beta_m = (TABLE1_FEDAVG["memory"] - 0.2) / (p * b)
    # temperature: floor 0.35, remaining 0.27 split evenly between s and b
    alpha_t = 1.0
    rem = TABLE1_FEDAVG["temp"] - 0.35
    gamma_t = (rem / 2) / s
    delta_t = (rem / 2) / b
    return ResourceModel(alpha_e=alpha_e, kappa_c=kappa_c, sparsity=1.0,
                         alpha_m=alpha_m, beta_m=beta_m, alpha_t=alpha_t,
                         gamma_t=gamma_t, delta_t=delta_t)
