"""Update compression for the communication knob ``q``.

q=0: fp32 (4 B/param) — no-op.
q=1: blockwise int8 absmax quantization (1 B/param + fp32 scale / block).
q=2: blockwise 2-bit quantization (0.25 B/param + fp32 scale / block).

``topk`` adds the sparse wire format on top of either quantized level:
only the ``topk`` largest-magnitude codes per block ship, as
(packed codes, 1-bit/coordinate keep-bitmask, per-block fp32 scale) —
the knob surface the Constraint API's ``wire_mb`` constraint steers.

The FL loop calls ``compress_decompress`` (the server immediately
dequantizes, so we model the *wire* format and keep the math in fp32).
On TPU the quantize/top-k path is the fused Pallas kernel in
``repro.kernels.wire``; on CPU (this container, and inside the FL
simulation loop) the pure-jnp reference path is used —
``repro.kernels.ops`` picks the backend.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np


def compress_decompress(tree: Any, q: int, block: int = 256,
                        topk: Optional[int] = None) -> Any:
    if q == 0:
        return tree
    from repro.kernels import ops
    bits = 8 if q == 1 else 2
    return jax.tree.map(
        lambda l: ops.quantize_dequantize(l, bits=bits, block=block,
                                          topk=topk), tree)


#: dyadic scale-out factor: integer *bit* counts -> bytes; exact in
#: float (power of two), so the rewrite below is bit-identical to the
#: old per-block float formulas
_BYTES_PER_BIT = 0.125


def to_mb(bytes_: float) -> float:
    """The one float-division reporting edge for byte counts (exact
    integer accounting everywhere upstream; see analysis rule REPRO003)."""
    return bytes_ / 1e6


def wire_bytes(tree: Any, q: int, block: int = 256,
               topk: Optional[int] = None) -> float:
    """Exact bytes of the shipped wire tuple.

    Matches ``kernels.ops.quantize_wire`` output leaf by leaf: each
    leaf ships ``ceil(n / block)`` blocks (the tail block is padded
    within itself; no ``ROWS_PER_TILE`` pad blocks — the kernel path
    strips those before return). Dense format: ``block`` codes at
    ``bits`` each + one fp32 scale per block. Top-k format: ``topk``
    packed codes + a 1-bit/coordinate keep-bitmask + the scale.

    Counted in integer bits, scaled out once — exact accounting.
    """
    leaves = jax.tree.leaves(tree)
    n = sum(int(np.prod(l.shape)) for l in leaves)
    if q == 0:
        return n * 32 * _BYTES_PER_BIT
    bits = 8 if q == 1 else 2
    n_blocks = sum(-(-int(np.prod(l.shape)) // block) for l in leaves)
    if topk is not None and topk < block:
        code_bits = n_blocks * (topk * bits + block)
    else:
        code_bits = n_blocks * block * bits
    return (code_bits + 32 * n_blocks) * _BYTES_PER_BIT


def wire_mb(tree: Any, q: int, block: int = 256,
            topk: Optional[int] = None) -> float:
    return to_mb(wire_bytes(tree, q, block, topk))


def compression_error(tree: Any, q: int, block: int = 256,
                      topk: Optional[int] = None) -> Dict[str, float]:
    """Relative L2 error introduced by the wire format (diagnostics)."""
    if q == 0:
        return {"rel_l2": 0.0}
    deq = compress_decompress(tree, q, block, topk)
    num = 0.0
    den = 0.0
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(deq)):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        num += float(np.sum((a - b) ** 2))
        den += float(np.sum(a ** 2))
    return {"rel_l2": float(np.sqrt(num / max(den, 1e-30)))}
