"""Update compression for the communication knob ``q``.

q=0: fp32 (4 B/param) — no-op.
q=1: blockwise int8 absmax quantization (1 B/param + fp32 scale / block).
q=2: blockwise 2-bit quantization (0.25 B/param + fp32 scale / block).

The FL loop calls ``compress_decompress`` (the server immediately
dequantizes, so we model the *wire* format and keep the math in fp32).
On TPU the quantizer is the Pallas kernel in ``repro.kernels.quantize``;
on CPU (this container, and inside the FL simulation loop) the pure-jnp
reference path is used — ``repro.kernels.ops`` picks the backend.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np

from repro.core.resources import BYTES_PER_PARAM


def compress_decompress(tree: Any, q: int, block: int = 256) -> Any:
    if q == 0:
        return tree
    from repro.kernels import ops
    bits = 8 if q == 1 else 2
    return jax.tree.map(lambda l: ops.quantize_dequantize(l, bits=bits,
                                                          block=block), tree)


def wire_bytes(tree: Any, q: int, block: int = 256) -> float:
    """Actual bytes on the wire, including per-block scales."""
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))
    payload = n * BYTES_PER_PARAM[q]
    if q == 0:
        return payload
    n_blocks = sum(-(-int(np.prod(l.shape)) // block)
                   for l in jax.tree.leaves(tree))
    return payload + 4.0 * n_blocks


def wire_mb(tree: Any, q: int, block: int = 256) -> float:
    return wire_bytes(tree, q, block) / 1e6


def compression_error(tree: Any, q: int, block: int = 256) -> Dict[str, float]:
    """Relative L2 error introduced by the wire format (diagnostics)."""
    if q == 0:
        return {"rel_l2": 0.0}
    deq = compress_decompress(tree, q, block)
    num = 0.0
    den = 0.0
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(deq)):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        num += float(np.sum((a - b) ** 2))
        den += float(np.sum(a ** 2))
    return {"rel_l2": float(np.sqrt(num / max(den, 1e-30)))}
