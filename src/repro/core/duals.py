"""Lagrangian dual variables and their dead-zone update (paper Eq. 3-4).

    L(w, lambda) = F(w) + sum_j lambda_j * max(0, u_j - b_j)
    lambda_j <- max(0, lambda_j + eta * dz(u_j / b_j))

The dead-zone dz(.) returns 0 inside [1 - delta, 1 + delta] and the signed
excess (u/b - 1) outside — the stability device the paper uses so duals do
not chatter when usage hovers at the budget.

Since the Constraint API landed, the general machinery lives in
``repro.constraints``: constraints are an open registry (not this
module's fixed 4-tuple), the update law is a pluggable
``DualController`` (``dual_update`` below delegates to the default
``DeadzoneSubgradient`` — same arithmetic, pinned by the golden
trajectories), and the duals->knobs mapping is a ``KnobPolicy``. This
module keeps the paper-shaped helpers (``RESOURCES``, ``DualState``,
``deadzone``, ratio/Lagrangian accounting) every seed call site uses.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

from repro.configs.base import Budgets, DualConfig

RESOURCES = ("energy", "comm", "memory", "temp")


def budgets_dict(budgets: Budgets) -> Dict[str, float]:
    """Budgets dataclass -> the {resource: bound} mapping the dual math
    runs on (``comm_mb`` is the ``comm`` resource)."""
    return {"energy": budgets.energy, "comm": budgets.comm_mb,
            "memory": budgets.memory, "temp": budgets.temp}


@dataclass
class DualState:
    """One multiplier per constraint. Defaults to the paper's four;
    a custom constraint stack simply keys more (or other) names."""

    lam: Dict[str, float] = field(
        default_factory=lambda: {r: 0.0 for r in RESOURCES})

    def as_tuple(self):
        return tuple(self.lam[r] for r in RESOURCES)


def deadzone(ratio: float, delta: float) -> float:
    """dz(u/b): signed excess outside the +-delta band around 1."""
    x = ratio - 1.0
    if abs(x) <= delta:
        return 0.0
    return x


def usage_ratios(usage: Dict[str, float], budgets: Budgets) -> Dict[str, float]:
    b = budgets_dict(budgets)
    return {r: usage[r] / b[r] for r in RESOURCES}


def dual_update(state: DualState, usage: Dict[str, float], budgets: Budgets,
                cfg: DualConfig) -> DualState:
    """One server-side dual ascent step (Algorithm 1, line 17) over the
    paper's four resources. Kept as the seed-compatible entry point;
    the law itself is ``repro.constraints.DeadzoneSubgradient`` (other
    controllers plug in through ``CAFLL(controller=...)``)."""
    from repro.constraints.controllers import DeadzoneSubgradient
    ctrl = DeadzoneSubgradient()
    ratios = usage_ratios(usage, budgets)
    new = {r: ctrl.step(r, state.lam[r], ratios[r], cfg) for r in RESOURCES}
    return DualState(lam=new)


def lagrangian_value(loss: float, usage: Dict[str, float], budgets: Budgets,
                     state: DualState) -> float:
    """Eq. 3 evaluated at (w, lambda) — used for logging/monitoring."""
    b = budgets_dict(budgets)
    penalty = sum(state.lam[r] * max(0.0, usage[r] - b[r]) for r in RESOURCES)
    return loss + penalty
