"""Encoder-decoder backbone (SeamlessM4T-medium text/speech decoder stack).

Per the assignment carve-out, the modality frontend (mel-spectrogram +
conv feature extractor) is a stub — ``src_embeds`` arrive as precomputed
frame embeddings of width ``cfg.frontend.embed_dim`` and are linearly
projected into the encoder. The transformer backbone (12L encoder +
12L decoder, d=1024, 16H, d_ff=4096) is fully implemented.

Decoder layers = self-attn (causal, cached) + cross-attn (encoder memory,
K/V precomputed once at prefill) + FFN. Both stacks scan over layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import zoo as Z


def _enc_layer_init(rng, cfg: ModelConfig):
    r = jax.random.split(rng, 2)
    return {"ln1": L.norm_init(cfg), "attn": L.attn_init(r[0], cfg),
            "ln2": L.norm_init(cfg), "ffn": L.mlp_init(r[1], cfg)}


def _dec_layer_init(rng, cfg: ModelConfig):
    r = jax.random.split(rng, 3)
    return {"ln1": L.norm_init(cfg), "self_attn": L.attn_init(r[0], cfg),
            "ln_x": L.norm_init(cfg), "cross_attn": L.attn_init(r[1], cfg),
            "ln2": L.norm_init(cfg), "ffn": L.mlp_init(r[2], cfg)}


def _enc_layer(p, x, cfg):
    h = L.norm_apply(p["ln1"], x, cfg)
    q, k, v = L._qkv(p["attn"], h, cfg)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    q = L.rope(q, pos, cfg.rope_theta)
    k = L.rope(k, pos, cfg.rope_theta)
    b, s, _ = x.shape
    a = L.bidir_attention(q, k, v).reshape(b, s, -1) @ p["attn"]["wo"]
    x = x + a
    h = L.norm_apply(p["ln2"], x, cfg)
    return x + L.mlp_apply(p["ffn"], h, cfg)


def _cross_kv(p, memory, cfg):
    b, s, _ = memory.shape
    k = (memory @ p["cross_attn"]["wk"]).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = (memory @ p["cross_attn"]["wv"]).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    return k, v


def _cross_attend(p, x, k_enc, v_enc, cfg):
    b, s, _ = x.shape
    q = (x @ p["cross_attn"]["wq"]).reshape(b, s, cfg.num_heads, cfg.head_dim)
    out = L.bidir_attention(q, k_enc, v_enc)
    return out.reshape(b, s, -1) @ p["cross_attn"]["wo"]


def _dec_layer_full(p, x, positions, k_enc, v_enc, cfg, want_cache):
    h = L.norm_apply(p["ln1"], x, cfg)
    tmp_cfg = cfg
    a, (k, v) = L.attn_apply_full(p["self_attn"], h, positions, tmp_cfg, window=None)
    x = x + a
    h = L.norm_apply(p["ln_x"], x, cfg)
    x = x + _cross_attend(p, h, k_enc, v_enc, cfg)
    h = L.norm_apply(p["ln2"], x, cfg)
    x = x + L.mlp_apply(p["ffn"], h, cfg)
    return x, ({"k": k, "v": v} if want_cache else None)


def _dec_layer_decode(p, x, cache, k_enc, v_enc, cfg):
    h = L.norm_apply(p["ln1"], x, cfg)
    a, cache = L.attn_apply_decode(p["self_attn"], h, cache, cfg, window=None)
    x = x + a
    h = L.norm_apply(p["ln_x"], x, cfg)
    x = x + _cross_attend(p, h, k_enc, v_enc, cfg)
    h = L.norm_apply(p["ln2"], x, cfg)
    return x + L.mlp_apply(p["ffn"], h, cfg), cache


def encdec_model(cfg: ModelConfig) -> Z.Model:
    n_enc = cfg.enc_layers
    n_dec = cfg.num_layers

    def init(rng):
        r = jax.random.split(rng, 3)
        enc = jax.vmap(lambda k: _enc_layer_init(k, cfg))(
            jax.random.split(r[0], n_enc))
        dec = jax.vmap(lambda k: _dec_layer_init(k, cfg))(
            jax.random.split(r[1], n_dec))
        io = Z.io_init(r[2], cfg)
        io["enc_norm"] = L.norm_init(cfg)
        return {"io": io, "enc": enc, "dec": dec}

    def encode(params, src_embeds):
        x = (src_embeds.astype(cfg.compute_dtype)
             @ params["io"]["frontend_proj"])
        x = L.shard_batch(x)

        def body(h, layer_params):
            return _enc_layer(layer_params, h, cfg), None

        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc"])
        return L.norm_apply(params["io"]["enc_norm"], x, cfg)

    def _dec_forward(params, memory, tokens, want_cache):
        x = L.shard_batch(Z.embed_tokens(params["io"], tokens, cfg))
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def body(carry, layer_params):
            h = carry
            k_enc, v_enc = _cross_kv(layer_params, memory, cfg)
            h, c = _dec_layer_full(layer_params, h, positions, k_enc, v_enc,
                                   cfg, want_cache)
            return h, c

        body_fn = body if want_cache else jax.checkpoint(body)
        x, caches = jax.lax.scan(body_fn, x, params["dec"])
        x = L.norm_apply(params["io"]["final_norm"], x, cfg)
        return x, caches

    def train_loss(params, batch):
        memory = encode(params, batch["src_embeds"])
        x, _ = _dec_forward(params, memory, batch["tokens"], want_cache=False)
        targets = batch["targets"]
        mask = batch.get("loss_mask", jnp.ones(targets.shape, jnp.float32))
        w = Z.unembed_matrix(params["io"], cfg).astype(cfg.compute_dtype)
        ce = Z.chunked_ce_loss(x, w, targets, mask, cfg.final_softcap)
        return ce, {"ce": ce, "aux": 0.0}

    def prefill(params, batch, use_decode_window: bool = False,
                max_new_tokens: int = 0):
        memory = encode(params, batch["src_embeds"])
        ctx_len = batch["tokens"].shape[1]
        x, self_caches = _dec_forward(params, memory, batch["tokens"],
                                      want_cache=True)
        logits = Z.logits_fn(params["io"], x[:, -1:], cfg)
        s_buf = ctx_len + max_new_tokens
        if use_decode_window and cfg.decode_window:
            s_buf = min(s_buf, cfg.decode_window)
        # precompute cross-attention K/V once: recomputing them from the
        # encoder memory every decode step cost useful-ratio 0.01 on the
        # dry-run (EXPERIMENTS.md §Roofline notes)
        cross_k, cross_v = jax.vmap(
            lambda lp: _cross_kv(lp, memory, cfg))(params["dec"])
        caches = {"self": jax.vmap(lambda c: L.attn_cache_from_full(
            c["k"], c["v"], s_buf))(self_caches),
            "cross_k": cross_k, "cross_v": cross_v}
        return logits, caches

    def decode_step(params, caches, tokens):
        x = L.shard_batch(Z.embed_tokens(params["io"], tokens, cfg))

        def body(h, xs):
            layer_params, cache, k_enc, v_enc = xs
            h, cache = _dec_layer_decode(layer_params, h, cache, k_enc, v_enc, cfg)
            return h, cache

        x, self_caches = jax.lax.scan(
            body, x, (params["dec"], caches["self"],
                      caches["cross_k"], caches["cross_v"]))
        x = L.norm_apply(params["io"]["final_norm"], x, cfg)
        logits = Z.logits_fn(params["io"], x, cfg)
        return logits, {"self": self_caches, "cross_k": caches["cross_k"],
                        "cross_v": caches["cross_v"]}

    def init_cache(batch_size, ctx_len, long: bool = False, src_len: int = 4096):
        s_buf = ctx_len
        if long and cfg.decode_window:
            s_buf = min(s_buf, cfg.decode_window)
        per_layer = L.attn_cache_init(cfg, batch_size, s_buf)
        caches = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_dec,) + a.shape).copy(),
            per_layer)
        cross = jnp.zeros((n_dec, batch_size, src_len, cfg.num_kv_heads,
                           cfg.head_dim), cfg.compute_dtype)
        return {"self": caches, "cross_k": cross, "cross_v": cross}

    def param_count():
        import math
        params = jax.eval_shape(init, jax.random.PRNGKey(0))
        total = sum(math.prod(l.shape) for l in jax.tree.leaves(params))
        return {"total": total, "active": total}

    return Z.Model(cfg, init, train_loss, prefill, decode_step, init_cache,
                   param_count)
