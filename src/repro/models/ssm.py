"""xLSTM blocks (arXiv:2405.04517): chunkwise-parallel mLSTM + scan sLSTM.

mLSTM uses the stabilized matrix-memory recurrence
    C_t = f_t C_{t-1} + i_t v_t k_t^T,   n_t = f_t n_{t-1} + i_t k_t,
    h_t = (C_t q_t) / max(|n_t . q_t|, exp(-m_t)),
computed in *chunkwise-parallel* form (intra-chunk quadratic of size
``chunk_size``, inter-chunk lax.scan over the recurrent state) — the TPU
adaptation: the chunk is the MXU tile, the scan is the sequential axis,
and memory stays O(S * chunk) instead of O(S^2).

sLSTM is inherently sequential (block-diagonal recurrent weights feed the
gates), so it is a lax.scan over time.
"""
from __future__ import annotations

import math
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init


# ---------------------------------------------------------------------------
# causal depthwise conv (width-4 prenet used by both block types)
# ---------------------------------------------------------------------------


def causal_dwconv(x, w):
    """x: (B, S, D); w: (W, D) depthwise causal conv."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i:i + x.shape[1]] * w[i]
    return out


def causal_dwconv_step(x_t, conv_state, w):
    """x_t: (B, D); conv_state: (B, W-1, D) (oldest..newest)."""
    width = w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,W,D)
    out = jnp.einsum("bwd,wd->bd", window, w)
    new_state = window[:, 1:] if width > 1 else conv_state
    return out, new_state


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(rng, cfg: ModelConfig):
    xc = cfg.xlstm
    d = cfg.d_model
    di = int(xc.proj_factor_mlstm * d)
    h = cfg.num_heads
    r = jax.random.split(rng, 9)
    return {
        "w_up": dense_init(r[0], d, 2 * di, cfg.param_dtype),
        "conv_w": (jax.random.normal(r[1], (xc.conv_width, di), jnp.float32)
                   * 0.1).astype(cfg.param_dtype),
        "wq": dense_init(r[2], di, di, cfg.param_dtype),
        "wk": dense_init(r[3], di, di, cfg.param_dtype),
        "wv": dense_init(r[4], di, di, cfg.param_dtype),
        "w_i": dense_init(r[5], di, h, jnp.float32),
        "b_i": jnp.zeros((h,), jnp.float32),
        "w_f": dense_init(r[6], di, h, jnp.float32),
        "b_f": jnp.full((h,), 3.0, jnp.float32),   # forget bias -> long memory
        "skip_scale": jnp.ones((di,), cfg.param_dtype),
        "gn_scale": jnp.ones((di,), cfg.param_dtype),
        "w_down": dense_init(r[7], di, d, cfg.param_dtype),
    }


def _mlstm_heads(p, x_conv, x_up, cfg):
    b, s, di = x_conv.shape
    h = cfg.num_heads
    dh = di // h
    q = (x_conv @ p["wq"]).reshape(b, s, h, dh)
    k = (x_conv @ p["wk"]).reshape(b, s, h, dh) / math.sqrt(dh)
    v = (x_up @ p["wv"]).reshape(b, s, h, dh)
    li = (x_conv.astype(jnp.float32) @ p["w_i"] + p["b_i"])           # (B,S,H)
    lf = jax.nn.log_sigmoid(x_conv.astype(jnp.float32) @ p["w_f"] + p["b_f"])
    return q, k, v, li, lf


def _groupnorm_heads(x, scale, num_heads):
    """Per-head group norm over the head dim. x: (B, S, DI)."""
    b, s, di = x.shape
    xh = x.reshape(b, s, num_heads, di // num_heads).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + 1e-6)
    return (xh.reshape(b, s, di) * scale.astype(jnp.float32)).astype(x.dtype)


def mlstm_chunkwise(q, k, v, li, lf, chunk: int, state=None):
    """Stabilized chunkwise mLSTM.

    q,k,v: (B, S, H, Dh); li, lf: (B, S, H) log input/forget gates.
    state: optional (C (B,H,Dh,Dh), n (B,H,Dh), m (B,H)).
    Returns h_out (B,S,H,Dh), final state.
    """
    b, s0, nh, dh = q.shape
    L = min(chunk, s0)
    pad = (-s0) % L
    if pad:
        # state-neutral padding: i=0 (log -inf), f=1 (log 0) leaves the
        # recurrent state untouched through padded steps.
        zp = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, zp) for t in (q, k, v))
        li = jnp.pad(li, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)), constant_values=0.0)
    s = s0 + pad
    nc = s // L

    def resh(x):
        return x.reshape(b, nc, L, *x.shape[2:]).swapaxes(0, 1)  # (NC,B,L,...)

    qc, kc, vc = resh(q), resh(k), resh(v)
    lic, lfc = resh(li), resh(lf)

    if state is None:
        C0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, nh, dh), jnp.float32)
        m0 = jnp.full((b, nh), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def body(carry, xs):
        C, n, m = carry
        qb, kb, vb, lib, lfb = xs                 # (B,L,H,*)
        cum = jnp.cumsum(lfb, axis=1)             # inclusive (B,L,H)
        # stabilizer per query position t
        src = lib - cum                           # (B,L,H): li_s - b_s
        run_max = jax.lax.cummax(src, axis=1)     # max_{s<=t}(li_s - b_s)
        m_t = cum + jnp.maximum(m[:, None, :], run_max)        # (B,L,H)
        # intra-chunk decay matrix (B,H,L,L): t rows, s cols
        dmat = (cum[:, :, None, :] - cum[:, None, :, :]
                + lib[:, None, :, :]) - m_t[:, :, None, :]
        dmat = jnp.transpose(dmat, (0, 3, 1, 2))  # (B,H,L,L)
        causal = jnp.tril(jnp.ones((L, L), bool))
        dmat = jnp.where(causal[None, None], dmat, -jnp.inf)
        dec = jnp.exp(dmat)
        scores = jnp.einsum("blhd,bshd->bhls", qb.astype(jnp.float32),
                            kb.astype(jnp.float32)) * dec
        # inter-chunk contribution
        inter_w = jnp.exp(cum + m[:, None, :] - m_t)          # (B,L,H)
        h_inter = jnp.einsum("blhd,bhde->blhe", qb.astype(jnp.float32), C)
        n_inter = jnp.einsum("blhd,bhd->blh", qb.astype(jnp.float32), n)
        num = (jnp.einsum("bhls,bshd->blhd", scores, vb.astype(jnp.float32))
               + h_inter * inter_w[..., None])
        den = jnp.sum(scores, axis=-1).transpose(0, 2, 1) + n_inter * inter_w
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        h_out = (num / den[..., None]).astype(qb.dtype)
        # state update to chunk end
        cum_L = cum[:, -1, :]                                  # (B,H)
        m_new = cum_L + jnp.maximum(m, run_max[:, -1, :])
        w_old = jnp.exp(cum_L + m - m_new)                     # (B,H)
        w_s = jnp.exp(cum_L[:, None] - cum + lib - m_new[:, None])  # (B,L,H)
        C_new = (C * w_old[..., None, None]
                 + jnp.einsum("blh,blhd,blhe->bhde", w_s,
                              kb.astype(jnp.float32), vb.astype(jnp.float32)))
        n_new = (n * w_old[..., None]
                 + jnp.einsum("blh,blhd->bhd", w_s, kb.astype(jnp.float32)))
        return (C_new, n_new, m_new), h_out

    (C, n, m), hs = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    h_out = hs.swapaxes(0, 1).reshape(b, s, nh, dh)[:, :s0]
    return h_out, (C, n, m)


def mlstm_step(q, k, v, li, lf, state):
    """Single decode step. q,k,v: (B,H,Dh); li,lf: (B,H)."""
    C, n, m = state
    m_new = jnp.maximum(lf + m, li)
    fp = jnp.exp(lf + m - m_new)
    ip = jnp.exp(li - m_new)
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    C = C * fp[..., None, None] + ip[..., None, None] * kf[..., :, None] * vf[..., None, :]
    n = n * fp[..., None] + ip[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), jnp.exp(-m_new))
    return (num / den[..., None]).astype(q.dtype), (C, n, m_new)


def mlstm_apply_full(p, x, cfg: ModelConfig, state=None):
    """x: (B,S,D) -> (B,S,D), decode cache {conv, C, n, m}."""
    xc = cfg.xlstm
    up = x @ p["w_up"]
    di = up.shape[-1] // 2
    x_up, z_gate = up[..., :di], up[..., di:]
    x_conv = jax.nn.silu(causal_dwconv(x_up, p["conv_w"]))
    q, k, v, li, lf = _mlstm_heads(p, x_conv, x_up, cfg)
    h, (C, n, m) = mlstm_chunkwise(q, k, v, li, lf, xc.chunk_size, state)
    h = h.reshape(x.shape[0], x.shape[1], di)
    h = _groupnorm_heads(h, p["gn_scale"], cfg.num_heads)
    h = h + p["skip_scale"] * x_conv
    out = (h * jax.nn.silu(z_gate)) @ p["w_down"]
    conv_tail = x_up[:, -(xc.conv_width - 1):].astype(cfg.compute_dtype)
    return out, {"conv": conv_tail, "C": C, "n": n, "m": m}


def mlstm_apply_decode(p, x, cache, cfg: ModelConfig):
    """x: (B,1,D); cache: {conv_state, C, n, m}."""
    b = x.shape[0]
    up = x[:, 0] @ p["w_up"]
    di = up.shape[-1] // 2
    x_up, z_gate = up[..., :di], up[..., di:]
    xc_t, conv_state = causal_dwconv_step(x_up, cache["conv"], p["conv_w"])
    x_conv = jax.nn.silu(xc_t)
    h = cfg.num_heads
    dh = di // h
    q = (x_conv @ p["wq"]).reshape(b, h, dh)
    k = (x_conv @ p["wk"]).reshape(b, h, dh) / math.sqrt(dh)
    v = (x_up @ p["wv"]).reshape(b, h, dh)
    li = (x_conv.astype(jnp.float32) @ p["w_i"] + p["b_i"])
    lf = jax.nn.log_sigmoid(x_conv.astype(jnp.float32) @ p["w_f"] + p["b_f"])
    hv, (C, n, m) = mlstm_step(q, k, v, li, lf, (cache["C"], cache["n"], cache["m"]))
    hv = hv.reshape(b, 1, di)
    hv = _groupnorm_heads(hv, p["gn_scale"], cfg.num_heads)
    hv = hv + p["skip_scale"] * x_conv[:, None]
    out = (hv * jax.nn.silu(z_gate)[:, None]) @ p["w_down"]
    return out, {"conv": conv_state, "C": C, "n": n, "m": m}


def mlstm_cache_init(cfg: ModelConfig, batch: int):
    xc = cfg.xlstm
    di = int(xc.proj_factor_mlstm * cfg.d_model)
    h = cfg.num_heads
    dh = di // h
    return {"conv": jnp.zeros((batch, xc.conv_width - 1, di), cfg.compute_dtype),
            "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, h, dh), jnp.float32),
            "m": jnp.full((batch, h), -1e30, jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    r = jax.random.split(rng, 4)
    # input projections for 4 gates (z, i, f, o) and block-diagonal recurrent
    return {
        "w_in": dense_init(r[0], d, 4 * d, cfg.param_dtype),
        "b_in": jnp.concatenate([jnp.zeros((2 * d,), jnp.float32),
                                 jnp.full((d,), 3.0, jnp.float32),
                                 jnp.zeros((d,), jnp.float32)]).astype(jnp.float32),
        "r_blocks": (jax.random.normal(r[1], (4, h, dh, dh), jnp.float32)
                     / math.sqrt(dh)).astype(cfg.param_dtype),
        "gn_scale": jnp.ones((d,), cfg.param_dtype),
        "w_up": dense_init(r[2], d, int(cfg.xlstm.proj_factor_slstm * d) * 2,
                           cfg.param_dtype),
        "w_down": dense_init(r[3], int(cfg.xlstm.proj_factor_slstm * d), d,
                             cfg.param_dtype),
    }


def _slstm_cell(p, x_gates, hcnm, num_heads):
    """x_gates: (B, 4D) precomputed input part; recurrent part added here."""
    h_prev, c_prev, n_prev, m_prev = hcnm
    b, d = h_prev.shape
    dh = d // num_heads
    hh = h_prev.reshape(b, num_heads, dh)
    rec = jnp.einsum("bhd,ghde->gbhe", hh.astype(jnp.float32),
                     p["r_blocks"].astype(jnp.float32)).reshape(4, b, d)
    g = x_gates.astype(jnp.float32).reshape(b, 4, d).transpose(1, 0, 2) + rec
    z, i_raw, f_raw, o_raw = g[0], g[1], g[2], g[3]
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o_raw)
    li = i_raw
    lf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(lf + m_prev, li)
    ip = jnp.exp(li - m_new)
    fp = jnp.exp(lf + m_prev - m_new)
    c_new = fp * c_prev + ip * z
    n_new = fp * n_prev + ip
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return h_new, c_new, n_new, m_new


def slstm_apply_full(p, x, cfg: ModelConfig, state=None):
    from repro.models.layers import shard_batch
    b, s, d = x.shape
    x_gates = x @ p["w_in"] + p["b_in"].astype(x.dtype)
    if state is None:
        zeros = jnp.zeros((b, d), jnp.float32)
        state = (zeros, zeros, zeros, jnp.full((b, d), -1e30, jnp.float32))
    # keep the recurrent state batch-sharded: a feature-sharded carry makes
    # GSPMD all-reduce the block-diagonal recurrent einsum EVERY time step
    # (measured 412 GB/device on train_4k — see EXPERIMENTS.md §Perf)
    state = tuple(shard_batch(t) for t in state)

    def body(carry, xg):
        new = _slstm_cell(p, xg, carry, cfg.num_heads)
        new = tuple(shard_batch(t) for t in new)
        return new, new[0]

    state, hs = jax.lax.scan(body, state, x_gates.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(x.dtype)                         # (B,S,D)
    h = _groupnorm_heads(h, p["gn_scale"], cfg.num_heads)
    up = h @ p["w_up"]
    dff = up.shape[-1] // 2
    out = (jax.nn.gelu(up[..., :dff]) * up[..., dff:]) @ p["w_down"]
    return out, state


def slstm_apply_decode(p, x, cache, cfg: ModelConfig):
    x_gates = x[:, 0] @ p["w_in"] + p["b_in"].astype(x.dtype)
    state = (cache["h"], cache["c"], cache["n"], cache["m"])
    state = _slstm_cell(p, x_gates, state, cfg.num_heads)
    h = state[0][:, None].astype(x.dtype)
    h = _groupnorm_heads(h, p["gn_scale"], cfg.num_heads)
    up = h @ p["w_up"]
    dff = up.shape[-1] // 2
    out = (jax.nn.gelu(up[..., :dff]) * up[..., dff:]) @ p["w_down"]
    return out, {"h": state[0], "c": state[1], "n": state[2], "m": state[3]}


def slstm_cache_init(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    zeros = jnp.zeros((batch, d), jnp.float32)
    return {"h": zeros, "c": zeros, "n": zeros,
            "m": jnp.full((batch, d), -1e30, jnp.float32)}
