"""Decoder stack: block specs, scan-over-units layout, train/prefill/decode.

The layer stack is organised as ``prefix | scanned units | suffix``:
* ``prefix`` — leading non-uniform layers (e.g. DeepSeek's 3 dense layers);
* ``units`` — the architecture's repeating pattern (e.g. gemma2's
  (local, global), RecurrentGemma's (rec, rec, attn), xLSTM's 7xmLSTM+sLSTM)
  stacked along a leading axis and driven by ``jax.lax.scan`` — this keeps
  the HLO size O(pattern) instead of O(layers), which is what makes the
  512-virtual-device dry-run compile on one CPU core;
* ``suffix`` — remainder layers when the pattern does not divide the depth
  (RecurrentGemma-2B: 26 = 8*(rec,rec,attn) + (rec, rec)).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import rglru as rg
from repro.models import ssm


class BlockSpec(NamedTuple):
    kind: str                  # attn | rec | mlstm | slstm
    window: Optional[int]      # attention window (None = global)
    use_moe: bool


def block_spec(cfg: ModelConfig, i: int) -> BlockSpec:
    kind = cfg.layer_kind(i)
    window = None
    if kind == "attn":
        window = cfg.window if cfg.attn_type(i) == "local" else None
    use_moe = (cfg.moe is not None and kind == "attn"
               and i >= (cfg.moe.first_dense_layers if cfg.moe else 0))
    return BlockSpec(kind, window, use_moe)


def stack_plan(cfg: ModelConfig):
    """-> (prefix_specs, unit_specs, n_units, suffix_specs)."""
    n_prefix = cfg.moe.first_dense_layers if cfg.moe else 0
    pat = len(cfg.block_pattern) if cfg.block_pattern else 1
    pat = _lcm(pat, len(cfg.attn_pattern))
    body = cfg.num_layers - n_prefix
    n_units = body // pat
    n_suffix = body % pat
    specs = [block_spec(cfg, i) for i in range(cfg.num_layers)]
    prefix = specs[:n_prefix]
    unit = specs[n_prefix:n_prefix + pat]
    suffix = specs[cfg.num_layers - n_suffix:] if n_suffix else []
    # all units must share the spec sequence for scan-stacking
    for u in range(n_units):
        got = specs[n_prefix + u * pat: n_prefix + (u + 1) * pat]
        assert got == unit, f"non-uniform unit {u}: {got} != {unit}"
    return prefix, unit, n_units, suffix


def _lcm(a, b):
    import math
    return a * b // math.gcd(a, b)


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------


def block_init(rng, cfg: ModelConfig, spec: BlockSpec):
    r = jax.random.split(rng, 4)
    p = {"ln1": L.norm_init(cfg)}
    if spec.kind == "attn":
        p["attn"] = L.mla_init(r[0], cfg) if cfg.mla else L.attn_init(r[0], cfg)
        p["ln2"] = L.norm_init(cfg)
        if spec.use_moe:
            p["ffn"] = moe_lib.moe_init(r[1], cfg)
        elif cfg.mlp_type != "none":
            d_ff = cfg.d_ff
            if cfg.moe and cfg.moe.first_dense_layers and cfg.moe.d_ff_dense:
                d_ff = cfg.moe.d_ff_dense
            p["ffn"] = L.mlp_init(r[1], cfg, d_ff=d_ff)
        if cfg.post_norms:
            p["post1"] = L.norm_init(cfg)
            p["post2"] = L.norm_init(cfg)
    elif spec.kind == "rec":
        p["rec"] = rg.rglru_init(r[0], cfg)
        p["ln2"] = L.norm_init(cfg)
        p["ffn"] = L.mlp_init(r[1], cfg)
    elif spec.kind == "mlstm":
        p["mlstm"] = ssm.mlstm_init(r[0], cfg)
    elif spec.kind == "slstm":
        p["slstm"] = ssm.slstm_init(r[0], cfg)
    else:
        raise ValueError(spec.kind)
    return p


def _ffn(p, x, cfg, spec):
    if spec.use_moe:
        return moe_lib.moe_apply(p["ffn"], x, cfg)
    if cfg.mlp_type == "none" or "ffn" not in p:
        return jnp.zeros_like(x), 0.0
    return L.mlp_apply(p["ffn"], x, cfg), 0.0


def block_apply_full(p, x, positions, cfg: ModelConfig, spec: BlockSpec,
                     want_cache: bool):
    """-> (x, cache_entry_or_None, aux_loss)."""
    aux = 0.0
    if spec.kind == "attn":
        h = L.norm_apply(p["ln1"], x, cfg)
        if cfg.mla:
            a, (c_kv, k_rope) = L.mla_apply_full(p["attn"], h, positions, cfg)
        else:
            a, (k, v) = L.attn_apply_full(p["attn"], h, positions, cfg,
                                          window=spec.window)
        if cfg.post_norms:
            a = L.norm_apply(p["post1"], a, cfg)
        x = x + a
        h = L.norm_apply(p["ln2"], x, cfg)
        f, aux = _ffn(p, h, cfg, spec)
        if cfg.post_norms:
            f = L.norm_apply(p["post2"], f, cfg)
        x = x + f
        cache = None
        if want_cache:
            if cfg.mla:
                cache = {"c_kv": c_kv, "k_rope": k_rope}
            else:
                cache = {"k": k, "v": v}
        return x, cache, aux
    if spec.kind == "rec":
        h = L.norm_apply(p["ln1"], x, cfg)
        a, rec_cache = rg.rglru_apply_full(p["rec"], h, cfg)
        x = x + a
        h = L.norm_apply(p["ln2"], x, cfg)
        f, _ = _ffn(p, h, cfg, spec)
        x = x + f
        return x, (rec_cache if want_cache else None), aux
    if spec.kind == "mlstm":
        h = L.norm_apply(p["ln1"], x, cfg)
        a, ml_cache = ssm.mlstm_apply_full(p["mlstm"], h, cfg)
        return x + a, (ml_cache if want_cache else None), aux
    if spec.kind == "slstm":
        h = L.norm_apply(p["ln1"], x, cfg)
        a, st = ssm.slstm_apply_full(p["slstm"], h, cfg)
        cache = ({"h": st[0], "c": st[1], "n": st[2], "m": st[3]}
                 if want_cache else None)
        return x + a, cache, aux
    raise ValueError(spec.kind)


def block_cache_init(cfg: ModelConfig, spec: BlockSpec, batch: int,
                     ctx_len: int, use_decode_window: bool):
    if spec.kind == "attn":
        s_buf = ctx_len
        if spec.window is not None:
            s_buf = min(s_buf, spec.window)
        elif use_decode_window and cfg.decode_window:
            s_buf = min(s_buf, cfg.decode_window)
        if cfg.mla:
            return L.mla_cache_init(cfg, batch, s_buf)
        return L.attn_cache_init(cfg, batch, s_buf)
    if spec.kind == "rec":
        return rg.rglru_cache_init(cfg, batch)
    if spec.kind == "mlstm":
        return ssm.mlstm_cache_init(cfg, batch)
    if spec.kind == "slstm":
        return ssm.slstm_cache_init(cfg, batch)
    raise ValueError(spec.kind)


def block_apply_decode(p, x, cache, cfg: ModelConfig, spec: BlockSpec):
    if spec.kind == "attn":
        h = L.norm_apply(p["ln1"], x, cfg)
        window = spec.window
        if window is None and cfg.decode_window and cache_buf_len(cache) <= cfg.decode_window:
            # rolling global cache acts as a sliding window (long_500k variant)
            window = None
        if cfg.mla:
            a, cache = L.mla_apply_decode(p["attn"], h, cache, cfg)
        else:
            a, cache = L.attn_apply_decode(p["attn"], h, cache, cfg, window=window)
        if cfg.post_norms:
            a = L.norm_apply(p["post1"], a, cfg)
        x = x + a
        h = L.norm_apply(p["ln2"], x, cfg)
        f, _ = _ffn(p, h, cfg, spec)
        if cfg.post_norms:
            f = L.norm_apply(p["post2"], f, cfg)
        return x + f, cache
    if spec.kind == "rec":
        h = L.norm_apply(p["ln1"], x, cfg)
        a, cache = rg.rglru_apply_decode(p["rec"], h, cache, cfg)
        x = x + a
        h = L.norm_apply(p["ln2"], x, cfg)
        f, _ = _ffn(p, h, cfg, spec)
        return x + f, cache
    if spec.kind == "mlstm":
        h = L.norm_apply(p["ln1"], x, cfg)
        a, cache = ssm.mlstm_apply_decode(p["mlstm"], h, cache, cfg)
        return x + a, cache
    if spec.kind == "slstm":
        h = L.norm_apply(p["ln1"], x, cfg)
        a, cache = ssm.slstm_apply_decode(p["slstm"], h, cache, cfg)
        return x + a, cache
    raise ValueError(spec.kind)


def cache_buf_len(cache) -> int:
    for key in ("k", "c_kv"):
        if key in cache:
            return cache[key].shape[1]
    return 0


# ---------------------------------------------------------------------------
# the stack
# ---------------------------------------------------------------------------


def stack_init(rng, cfg: ModelConfig):
    prefix, unit, n_units, suffix = stack_plan(cfg)
    rngs = jax.random.split(rng, 3)
    params = {}
    if prefix:
        rp = jax.random.split(rngs[0], len(prefix))
        params["prefix"] = [block_init(rp[i], cfg, s) for i, s in enumerate(prefix)]
    if n_units:
        def unit_init(r):
            rs = jax.random.split(r, len(unit))
            return {f"b{j}": block_init(rs[j], cfg, s)
                    for j, s in enumerate(unit)}
        params["units"] = jax.vmap(unit_init)(jax.random.split(rngs[1], n_units))
    if suffix:
        rs = jax.random.split(rngs[2], len(suffix))
        params["suffix"] = [block_init(rs[i], cfg, s) for i, s in enumerate(suffix)]
    return params


def stack_apply_full(params, x, positions, cfg: ModelConfig,
                     want_cache: bool = False, remat: bool = True):
    """-> (x, caches, aux). caches = {prefix: [...], units: stacked, suffix: [...]}"""
    prefix, unit, n_units, suffix = stack_plan(cfg)
    caches = {"prefix": [], "suffix": []}
    aux_total = 0.0
    for p, s in zip(params.get("prefix", []), prefix):
        x, c, aux = block_apply_full(p, x, positions, cfg, s, want_cache)
        caches["prefix"].append(c)
        aux_total += aux
    if n_units:
        def body(carry, unit_params):
            h, aux_acc = carry
            unit_caches = {}
            for j, s in enumerate(unit):
                h, c, aux = block_apply_full(unit_params[f"b{j}"], h, positions,
                                             cfg, s, want_cache)
                if want_cache:
                    unit_caches[f"b{j}"] = c
            return (h, aux_acc + aux), unit_caches

        body_fn = jax.checkpoint(body) if remat else body
        (x, aux_total), unit_caches = jax.lax.scan(
            body_fn, (x, aux_total), params["units"])
        caches["units"] = unit_caches if want_cache else None
    for p, s in zip(params.get("suffix", []), suffix):
        x, c, aux = block_apply_full(p, x, positions, cfg, s, want_cache)
        caches["suffix"].append(c)
        aux_total += aux
    return x, (caches if want_cache else None), aux_total


def stack_apply_decode(params, x, caches, cfg: ModelConfig):
    prefix, unit, n_units, suffix = stack_plan(cfg)
    new_caches = {"prefix": [], "suffix": []}
    for p, s, c in zip(params.get("prefix", []), prefix, caches.get("prefix", [])):
        x, c = block_apply_decode(p, x, c, cfg, s)
        new_caches["prefix"].append(c)
    if n_units:
        def body(h, xs):
            unit_params, unit_cache = xs
            new_unit_cache = {}
            for j, s in enumerate(unit):
                h, nc = block_apply_decode(unit_params[f"b{j}"], h,
                                           unit_cache[f"b{j}"], cfg, s)
                new_unit_cache[f"b{j}"] = nc
            return h, new_unit_cache

        x, unit_caches = jax.lax.scan(body, x, (params["units"], caches["units"]))
        new_caches["units"] = unit_caches
    for p, s, c in zip(params.get("suffix", []), suffix, caches.get("suffix", [])):
        x, c = block_apply_decode(p, x, c, cfg, s)
        new_caches["suffix"].append(c)
    return x, new_caches


def stack_cache_init(cfg: ModelConfig, batch: int, ctx_len: int,
                     use_decode_window: bool = False):
    prefix, unit, n_units, suffix = stack_plan(cfg)
    caches = {"prefix": [block_cache_init(cfg, s, batch, ctx_len, use_decode_window)
                         for s in prefix],
              "suffix": [block_cache_init(cfg, s, batch, ctx_len, use_decode_window)
                         for s in suffix]}
    if n_units:
        unit_cache = {f"b{j}": block_cache_init(cfg, s, batch, ctx_len,
                                                use_decode_window)
                      for j, s in enumerate(unit)}
        caches["units"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_units,) + a.shape).copy(),
            unit_cache)
    return caches


def caches_from_prefill(cfg: ModelConfig, full_caches, ctx_len: int,
                        use_decode_window: bool, max_new_tokens: int = 0):
    """Convert prefill (k,v per layer over S) into rolling decode caches."""
    prefix, unit, n_units, suffix = stack_plan(cfg)

    def convert(spec, c):
        if c is None:
            return None
        if spec.kind == "attn":
            s_buf = ctx_len + max_new_tokens
            if spec.window is not None:
                s_buf = min(s_buf, spec.window)
            elif use_decode_window and cfg.decode_window:
                s_buf = min(s_buf, cfg.decode_window)
            if cfg.mla:
                kv = L.attn_cache_from_full(c["c_kv"][..., None, :],
                                            c["k_rope"][..., None, :], s_buf)
                return {"c_kv": kv["k"][..., 0, :], "k_rope": kv["v"][..., 0, :],
                        "index": kv["index"]}
            return L.attn_cache_from_full(c["k"], c["v"], s_buf)
        return c  # rec/mlstm/slstm caches already decode-ready

    out = {"prefix": [convert(s, c) for s, c in zip(prefix, full_caches["prefix"])],
           "suffix": [convert(s, c) for s, c in zip(suffix, full_caches["suffix"])]}
    if n_units:
        def convert_unit(unit_caches):
            return {f"b{j}": convert(s, unit_caches[f"b{j}"])
                    for j, s in enumerate(unit)}
        out["units"] = jax.vmap(convert_unit)(full_caches["units"])
    return out
