"""Mixture-of-Experts layer (GShard/Switch-style einsum dispatch).

Design notes for the TPU mapping:
* tokens are grouped (``group_size`` per group, group axis sharded over the
  ``data`` mesh axis) and dispatched to a per-group capacity buffer with a
  one-hot einsum — this is the classic GSPMD-friendly MoE formulation whose
  dispatch/combine einsums lower to all-to-alls when experts are sharded on
  the ``model`` axis;
* expert FFNs run as a single batched einsum over the expert axis
  (expert-parallel);
* the dispatch-einsum FLOP overhead scales with capacity-per-group, so
  ``group_size`` is kept small (2048) — see EXPERIMENTS.md §Perf where the
  sort-based alternative is evaluated as a beyond-paper optimization.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, mlp_init, mlp_apply


def moe_init(rng, cfg: ModelConfig):
    m = cfg.moe
    r = jax.random.split(rng, 5)
    e, dm, dff = m.num_experts, cfg.d_model, m.d_ff_expert
    scale = 1.0 / math.sqrt(dm)
    p = {
        "router": dense_init(r[0], dm, e, jnp.float32, scale=scale),
        "expert_gate": (jax.random.normal(r[1], (e, dm, dff), jnp.float32) * scale
                   ).astype(cfg.param_dtype),
        "expert_up": (jax.random.normal(r[2], (e, dm, dff), jnp.float32) * scale
                 ).astype(cfg.param_dtype),
        "expert_down": (jax.random.normal(r[3], (e, dff, dm), jnp.float32)
                   * (1.0 / math.sqrt(dff))).astype(cfg.param_dtype),
    }
    if m.num_shared_experts:
        p["shared"] = mlp_init(r[4], cfg, d_ff=m.d_ff_dense or m.d_ff_expert)
    return p


def _capacity(m, tokens_per_group: int) -> int:
    c = int(math.ceil(tokens_per_group * m.top_k * m.capacity_factor / m.num_experts))
    return max(4, c)


def moe_apply(p, x, cfg: ModelConfig, *, rng: Optional[jax.Array] = None):
    """x: (B, S, D) -> (B, S, D), aux_loss (load-balance)."""
    m = cfg.moe
    b, s, d = x.shape
    n_tok = b * s
    gs = min(m.group_size, n_tok)
    # pad so groups divide evenly
    n_grp = (n_tok + gs - 1) // gs
    pad = n_grp * gs - n_tok
    xf = x.reshape(n_tok, d)
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    xg = xf.reshape(n_grp, gs, d)

    logits = (xg @ p["router"].astype(xg.dtype)).astype(jnp.float32)  # (G,S,E)
    if m.router_noise and rng is not None:
        logits += m.router_noise * jax.random.normal(rng, logits.shape)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)             # (G,S,K)
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)

    cap = _capacity(m, gs)
    e = m.num_experts
    # position of each (token, k) within its expert queue — int8 one-hot /
    # int16 cumsum: these (Ntok, K, E) tensors dominate MoE HBM traffic at
    # E=256 (measured ~45% of deepseek train bytes, §Perf), and gs*K<=2^15
    # always fits int16
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int8)            # (G,S,K,E)
    flat = onehot.reshape(n_grp, gs * m.top_k, e)
    pos = jnp.cumsum(flat.astype(jnp.int16), axis=1) * flat - 1       # (G,S*K,E)
    pos = pos.reshape(n_grp, gs, m.top_k, e)
    in_cap = (pos >= 0) & (pos < cap)
    # combine tensor (G,S,K,E,C) -> summed over K into (G,S,E,C)
    pos_oh = jax.nn.one_hot(jnp.where(in_cap, pos, -1), cap, dtype=xg.dtype)
    combine = jnp.einsum("gske,gskec->gsec",
                         (gate_vals[..., None] * onehot).astype(xg.dtype) *
                         in_cap.astype(xg.dtype), pos_oh)
    dispatch = (combine > 0).astype(xg.dtype)                         # (G,S,E,C)

    # dispatch -> (E, G, C, D)
    xe = jnp.einsum("gsec,gsd->egcd", dispatch, xg)
    h = jnp.einsum("egcd,edf->egcf", xe, p["expert_gate"].astype(xe.dtype))
    u = jnp.einsum("egcd,edf->egcf", xe, p["expert_up"].astype(xe.dtype))
    h = jax.nn.silu(h) * u
    ye = jnp.einsum("egcf,efd->egcd", h, p["expert_down"].astype(h.dtype))
    y = jnp.einsum("gsec,egcd->gsd", combine, ye)                     # (G,S,D)

    y = y.reshape(n_grp * gs, d)[:n_tok].reshape(b, s, d)
    if m.num_shared_experts:
        y = y + mlp_apply(p["shared"], x, cfg)

    # Switch-style load-balance aux loss
    density = jnp.mean(jax.nn.one_hot(expert_idx[..., 0], e, dtype=jnp.float32),
                       axis=1)                                        # (G,E)
    density_proxy = jnp.mean(probs, axis=1)                           # (G,E)
    aux = jnp.mean(density * density_proxy) * (e ** 2) * m.aux_loss_weight
    return y, aux


def moe_param_count(cfg: ModelConfig) -> dict:
    """Total vs active parameter counts for the resource proxies."""
    m = cfg.moe
    d, dff, e = cfg.d_model, m.d_ff_expert, m.num_experts
    per_expert = 3 * d * dff
    total = e * per_expert + d * e
    active = m.top_k * per_expert + d * e
    if m.num_shared_experts:
        shared = 3 * d * (m.d_ff_dense or dff)
        total += shared
        active += shared
    return {"total": total, "active": active}
