"""Core neural-net layers in pure JAX (functional: init_* / apply pairs).

Conventions
-----------
* params are nested dicts of jnp arrays;
* activations are (batch, seq, ...) with compute in ``cfg.compute_dtype``;
* attention is implemented *blockwise* (static q-chunk loop with exact
  causal/windowed kv prefixes) so the lowered HLO never materialises an
  S x S score tensor and FLOPs stay ~2 * S^2/2 * D for causal attention.
  This is the pure-JAX analogue of the Pallas flash kernel in
  ``repro.kernels.flash_attention`` (the TPU-target version); the dry-run
  lowers this one because Pallas TPU kernels cannot lower on the CPU
  backend used for the 512-device placeholder mesh.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# mesh-aware activation sharding constraint
# ---------------------------------------------------------------------------


def shard_batch(x, n_batch_dims: int = 1):
    """Constrain the leading batch dim(s) to the (pod, data) mesh axes when
    lowering inside a mesh context; no-op otherwise (CPU FL runs).

    Without this, GSPMD propagates the embedding table's sharding through
    the gather and replicates the batch — measured 16x activation blow-up
    on the dry-run (see EXPERIMENTS.md §Dry-run).
    """
    try:
        import os
        from jax.sharding import PartitionSpec as _P, get_abstract_mesh
        mesh = get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        batch_axes = tuple(os.environ.get("REPRO_BATCH_AXES",
                                          "pod,data").split(","))
        axes = tuple(a for a in batch_axes if a in mesh.axis_names)
        while axes:
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            if x.shape[0] % n == 0:
                break
            axes = axes[:-1]
        if not axes:
            return x
        spec = _P(axes, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(rng, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(rng, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(rng, vocab: int, dim: int, dtype):
    return (jax.random.normal(rng, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def norm_init(cfg: ModelConfig, dim: Optional[int] = None):
    dim = dim or cfg.d_model
    if cfg.norm_type == "layer":
        return {"scale": jnp.ones((dim,), cfg.param_dtype),
                "bias": jnp.zeros((dim,), cfg.param_dtype)}
    return {"scale": jnp.zeros((dim,), cfg.param_dtype)}  # gemma-style (1+scale)


def norm_apply(p, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layer":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + 1e-6)
        out = out * (1.0 + p["scale"].astype(jnp.float32))
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """x: (..., S, H, D) with D even; positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    ang = ang[..., None, :]                                   # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_init(rng, cfg: ModelConfig, d_ff: Optional[int] = None, d_model: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    dm = d_model or cfg.d_model
    r = jax.random.split(rng, 3)
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {"w_gate": dense_init(r[0], dm, d_ff, cfg.param_dtype),
                "w_up": dense_init(r[1], dm, d_ff, cfg.param_dtype),
                "w_down": dense_init(r[2], d_ff, dm, cfg.param_dtype)}
    return {"w_up": dense_init(r[0], dm, d_ff, cfg.param_dtype),
            "b_up": jnp.zeros((d_ff,), cfg.param_dtype),
            "w_down": dense_init(r[1], d_ff, dm, cfg.param_dtype),
            "b_down": jnp.zeros((dm,), cfg.param_dtype)}


def mlp_apply(p, x, cfg: ModelConfig):
    if cfg.mlp_type in ("swiglu", "geglu"):
        g = x @ p["w_gate"]
        act = jax.nn.silu(g) if cfg.mlp_type == "swiglu" else jax.nn.gelu(g)
        return (act * (x @ p["w_up"])) @ p["w_down"]
    h = x @ p["w_up"] + p["b_up"]
    if cfg.mlp_type == "relu2":                     # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return h @ p["w_down"] + p["b_down"]


# ---------------------------------------------------------------------------
# blockwise causal attention (pure JAX, exact FLOPs, no S x S tensor)
# ---------------------------------------------------------------------------


def _softcap(scores, cap: Optional[float]):
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


def _scores_mask(qpos, kpos, window, causal=True):
    mask = kpos[None, :] >= 0                       # padding slots carry kpos=-1
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    return mask


def _attend_block(q, k, v, qpos, kpos, scale, softcap, window, kv_chunk=2048,
                  causal=True):
    """q: (B,Cq,H,D) k/v: (B,L,KVH,D) -> (B,Cq,H,Dv). fp32 online softmax.

    When the kv prefix is long, an inner lax.scan over kv chunks keeps the
    score tensor at (B,KVH,G,Cq,kv_chunk) — the flash-attention memory
    pattern, expressed in pure JAX so it lowers on any backend.
    """
    b, cq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    dv = v.shape[-1]
    qg = q.reshape(b, cq, kvh, g, d)
    L = k.shape[1]

    if L <= 2 * kv_chunk:
        scores = jnp.einsum("bqkgd,blkd->bkgql", qg, k).astype(jnp.float32) * scale
        scores = _softcap(scores, softcap)
        mask = _scores_mask(qpos, kpos, window, causal)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkgql,blkd->bqkgd", w, v)
        return out.reshape(b, cq, h, dv)

    n = (L + kv_chunk - 1) // kv_chunk
    pad = n * kv_chunk - L
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=-1)
    ks = k.reshape(b, n, kv_chunk, kvh, d).swapaxes(0, 1)
    vs = v.reshape(b, n, kv_chunk, kvh, dv).swapaxes(0, 1)
    kps = kpos.reshape(n, kv_chunk)

    m0 = jnp.full((b, kvh, g, cq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, cq), jnp.float32)
    a0 = jnp.zeros((b, cq, kvh, g, dv), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, kp = xs
        s = jnp.einsum("bqkgd,blkd->bkgql", qg, kb).astype(jnp.float32) * scale
        s = _softcap(s, softcap)
        mask = _scores_mask(qpos, kp, window, causal)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgql,blkd->bqkgd", p.astype(vb.dtype), vb)
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, kps))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return out.astype(v.dtype).reshape(b, cq, h, dv)


def bidir_attention(q, k, v, *, softcap=None, scale=None, kv_chunk=2048):
    """Full bidirectional attention (encoder). q:(B,Sq,H,D) k/v:(B,Sk,KVH,D)."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qpos = jnp.arange(q.shape[1])
    kpos = jnp.arange(k.shape[1])
    return _attend_block(q, k, v, qpos, kpos, scale, softcap, None,
                         kv_chunk=kv_chunk, causal=False)


def blockwise_attention(q, k, v, *, window: Optional[int], softcap: Optional[float],
                        q_chunk: int, scale: Optional[float] = None):
    """Causal (optionally windowed) attention.

    q: (B, S, H, Dq), k: (B, S, KVH, Dq), v: (B, S, KVH, Dv).
    Static python loop over q chunks; chunk i attends to the exact causal
    (or windowed) kv prefix with *static* slice bounds, so HLO FLOPs equal
    the true ~S^2/2 (or S*W) cost.
    """
    b, s, h, d = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    c = min(q_chunk, s)
    n = (s + c - 1) // c
    outs = []
    for i in range(n):
        q0, q1 = i * c, min((i + 1) * c, s)
        k1 = q1
        k0 = 0 if window is None else max(0, q1 - window - (q1 - q0))
        qpos = jnp.arange(q0, q1)
        kpos = jnp.arange(k0, k1)
        outs.append(_attend_block(q[:, q0:q1], k[:, k0:k1], v[:, k0:k1],
                                  qpos, kpos, scale, softcap, window))
    return jnp.concatenate(outs, axis=1) if n > 1 else outs[0]


def decode_attention(q, k_cache, v_cache, index, *, window: Optional[int],
                     softcap: Optional[float], scale: Optional[float] = None):
    """Single-token attention over a (possibly rolling) cache.

    q: (B, 1, H, D); caches: (B, S_buf, KVH, D); index: scalar int32 = number
    of tokens written so far (absolute). Slots hold absolute positions
    ``slot_pos``; with a rolling buffer slot j holds position
    index-1 - ((write-1 - j) mod S_buf) — but masking only needs validity +
    window, both derivable from index.
    """
    b, _, h, d = q.shape
    s_buf = k_cache.shape[1]
    kvh = k_cache.shape[2]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, 1, kvh, g, d)
    scores = jnp.einsum("bqkgd,blkd->bkgql", qg, k_cache).astype(jnp.float32) * scale
    scores = _softcap(scores, softcap)
    slot = jnp.arange(s_buf)
    write = (index - 1) % s_buf                     # slot of newest token
    age = (write - slot) % s_buf                    # 0 = newest
    valid = age < jnp.minimum(index, s_buf)
    if window is not None:
        valid &= age < window
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgql,blkd->bqkgd", w, v_cache)
    return out.reshape(b, 1, h, -1)


# ---------------------------------------------------------------------------
# GQA attention layer (params + cache plumbing)
# ---------------------------------------------------------------------------


def attn_init(rng, cfg: ModelConfig, kv_dim: Optional[int] = None):
    dm = cfg.d_model
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    r = jax.random.split(rng, 4)
    kd = kv_dim or dm  # cross-attention reads from encoder width
    p = {"wq": dense_init(r[0], dm, h * hd, cfg.param_dtype),
         "wk": dense_init(r[1], kd, kvh * hd, cfg.param_dtype),
         "wv": dense_init(r[2], kd, kvh * hd, cfg.param_dtype),
         "wo": dense_init(r[3], h * hd, dm, cfg.param_dtype)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), cfg.param_dtype)
        p["bk"] = jnp.zeros((kvh * hd,), cfg.param_dtype)
        p["bv"] = jnp.zeros((kvh * hd,), cfg.param_dtype)
    return p


def _qkv(p, x, cfg: ModelConfig, src=None):
    b, s, _ = x.shape
    src = x if src is None else src
    q = x @ p["wq"]
    k = src @ p["wk"]
    v = src @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, src.shape[1], cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, src.shape[1], cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def attn_apply_full(p, x, positions, cfg: ModelConfig, *, window=None):
    """Training / prefill forward (no cache in, optionally cache out)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    out = blockwise_attention(q, k, v, window=window, softcap=cfg.attn_softcap,
                              q_chunk=cfg.q_chunk)
    return out.reshape(b, s, -1) @ p["wo"], (k, v)


def attn_apply_decode(p, x, cache, cfg: ModelConfig, *, window=None):
    """One-token decode. cache = {"k","v": (B,S_buf,KVH,D), "index": ()}"""
    b = x.shape[0]
    q, k, v = _qkv(p, x, cfg)
    idx = cache["index"]
    pos = jnp.full((b, 1), idx, jnp.int32)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    s_buf = cache["k"].shape[1]
    slot = idx % s_buf
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                           (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                           (0, slot, 0, 0))
    out = decode_attention(q, k_cache, v_cache, idx + 1, window=window,
                           softcap=cfg.attn_softcap)
    new_cache = {"k": k_cache, "v": v_cache, "index": idx + 1}
    return out.reshape(b, 1, -1) @ p["wo"], new_cache


def attn_cache_init(cfg: ModelConfig, batch: int, s_buf: int, dtype=None):
    dtype = dtype or cfg.compute_dtype
    return {"k": jnp.zeros((batch, s_buf, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, s_buf, cfg.num_kv_heads, cfg.head_dim), dtype),
            "index": jnp.zeros((), jnp.int32)}


def attn_cache_from_full(k, v, s_buf: int):
    """Build a decode cache from prefill K/V (keep the trailing window)."""
    s = k.shape[1]
    if s >= s_buf:
        # newest token ends at slot (s-1) % s_buf to stay consistent with
        # the rolling-write convention used in attn_apply_decode.
        tail_k, tail_v = k[:, s - s_buf:], v[:, s - s_buf:]
        shift = s % s_buf
        tail_k = jnp.roll(tail_k, shift, axis=1)
        tail_v = jnp.roll(tail_v, shift, axis=1)
        return {"k": tail_k, "v": tail_v, "index": jnp.asarray(s, jnp.int32)}
    pad = [(0, 0), (0, s_buf - s), (0, 0), (0, 0)]
    return {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad),
            "index": jnp.asarray(s, jnp.int32)}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------


def mla_init(rng, cfg: ModelConfig):
    m = cfg.mla
    h = cfg.num_heads
    r = jax.random.split(rng, 7)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": dense_init(r[0], cfg.d_model, m.q_lora_rank, cfg.param_dtype),
        "q_norm": norm_init(cfg, m.q_lora_rank),
        "w_uq": dense_init(r[1], m.q_lora_rank, h * qk_head, cfg.param_dtype),
        "w_dkv": dense_init(r[2], cfg.d_model, m.kv_lora_rank, cfg.param_dtype),
        "kv_norm": norm_init(cfg, m.kv_lora_rank),
        "w_uk": dense_init(r[3], m.kv_lora_rank, h * m.qk_nope_head_dim, cfg.param_dtype),
        "w_uv": dense_init(r[4], m.kv_lora_rank, h * m.v_head_dim, cfg.param_dtype),
        "w_kr": dense_init(r[5], cfg.d_model, m.qk_rope_head_dim, cfg.param_dtype),
        "wo": dense_init(r[6], h * m.v_head_dim, cfg.d_model, cfg.param_dtype),
    }


def _mla_q(p, x, positions, cfg: ModelConfig):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    q_lat = norm_apply(p["q_norm"], x @ p["w_dq"], cfg)
    q = (q_lat @ p["w_uq"]).reshape(b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_apply_full(p, x, positions, cfg: ModelConfig):
    """Train/prefill: expand latents to per-head K/V, blockwise attention."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    q_nope, q_rope = _mla_q(p, x, positions, cfg)
    c_kv = norm_apply(p["kv_norm"], x @ p["w_dkv"], cfg)          # (B,S,r_kv)
    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, m.qk_nope_head_dim)
    vv = (c_kv @ p["w_uv"]).reshape(b, s, h, m.v_head_dim)
    k_rope = rope((x @ p["w_kr"])[:, :, None, :], positions, cfg.rope_theta)
    k_rope = jnp.broadcast_to(k_rope, (b, s, h, m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    out = blockwise_attention(q, k, vv, window=None, softcap=cfg.attn_softcap,
                              q_chunk=cfg.q_chunk, scale=scale)
    out = out.reshape(b, s, -1) @ p["wo"]
    return out, (c_kv, k_rope[:, :, 0, :])


def mla_apply_decode(p, x, cache, cfg: ModelConfig, *, window=None):
    """Absorbed-matmul MLA decode: scores/ctx live in the latent space, so
    per-step FLOPs are O(S * r_kv) instead of O(S * H * d) — DeepSeek-V3's
    actual serving trick, and the reason the cache is only r_kv + d_rope
    wide."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    idx = cache["index"]
    pos = jnp.full((b, 1), idx, jnp.int32)
    q_nope, q_rope = _mla_q(p, x, pos, cfg)                        # (B,1,H,*)
    c_new = norm_apply(p["kv_norm"], x @ p["w_dkv"], cfg)          # (B,1,r)
    kr_new = rope((x @ p["w_kr"])[:, :, None, :], pos, cfg.rope_theta)[:, :, 0, :]
    s_buf = cache["c_kv"].shape[1]
    slot = idx % s_buf
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new.astype(cache["c_kv"].dtype),
                                        (0, slot, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], kr_new.astype(cache["k_rope"].dtype),
                                          (0, slot, 0))
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)             # absorb W_UK
    scores = (jnp.einsum("bqhr,bsr->bhqs", q_lat, c_kv)
              + jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope)).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = _softcap(scores, cfg.attn_softcap)
    slot_ids = jnp.arange(s_buf)
    write = idx % s_buf
    age = (write - slot_ids) % s_buf
    valid = age < jnp.minimum(idx + 1, s_buf)
    if window is not None:
        valid &= age < window
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(c_kv.dtype)
    ctx_lat = jnp.einsum("bhqs,bsr->bqhr", w, c_kv)                # (B,1,H,r)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    ctx = jnp.einsum("bqhr,rhd->bqhd", ctx_lat, w_uv)              # absorb W_UV
    out = ctx.reshape(b, 1, -1) @ p["wo"]
    return out, {"c_kv": c_kv, "k_rope": k_rope, "index": idx + 1}


def mla_cache_init(cfg: ModelConfig, batch: int, s_buf: int, dtype=None):
    m = cfg.mla
    dtype = dtype or cfg.compute_dtype
    return {"c_kv": jnp.zeros((batch, s_buf, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, s_buf, m.qk_rope_head_dim), dtype),
            "index": jnp.zeros((), jnp.int32)}
