from repro.models.zoo import Model, build  # noqa: F401
