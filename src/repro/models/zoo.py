"""Model zoo: a uniform functional API over every assigned architecture.

``build(cfg)`` returns a ``Model`` whose methods are pure functions:

    init(rng) -> params
    train_loss(params, batch) -> (loss, metrics)
    prefill(params, batch) -> (last_logits, decode_caches)
    decode_step(params, caches, tokens) -> (logits, caches)
    init_cache(batch_size, ctx_len, long=False) -> caches

Batch dict keys (ShapeDtypeStruct-compatible, see launch/specs.py):
    tokens (B, S) int32; targets (B, S) int32; loss_mask (B, S) f32 [optional]
    patch_embeds (B, P, E_f)   — vlm frontend stub
    src_embeds (B, S_src, E_f) — audio frontend stub (enc-dec)
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# chunked cross-entropy (never materialises (B, S, V) at once)
# ---------------------------------------------------------------------------


def _ce_chunk_size(batch: int, vocab: int, seq: int) -> int:
    budget = 2 ** 33  # ~8 GiB of fp32 logits globally per chunk
    c = max(16, int(budget / max(1, batch * vocab * 4)))
    c = min(c, seq, 1024)
    while seq % c:
        c -= 1
    return max(c, 1)


def chunked_ce_loss(x, w_unembed, targets, mask, softcap=None):
    """x: (B,S,D), w_unembed: (D,V), targets: (B,S) -> scalar mean CE."""
    b, s, d = x.shape
    v = w_unembed.shape[1]
    c = _ce_chunk_size(b, v, s)
    n = s // c
    xs = x.reshape(b, n, c, d).swapaxes(0, 1)
    ts = targets.reshape(b, n, c).swapaxes(0, 1)
    ms = mask.reshape(b, n, c).swapaxes(0, 1)

    # remat: without it the scan saves every chunk's logits for the
    # backward pass, defeating the point of chunking (measured 4 GB/device
    # on the dry-run for 256k-vocab archs).
    @jax.checkpoint
    def body(carry, inp):
        tot, cnt = carry
        xc, tc, mc = inp
        logits = (xc @ w_unembed).astype(jnp.float32)
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        tot = tot + jnp.sum((lse - ll) * mc)
        cnt = cnt + jnp.sum(mc)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)),
                                 (xs, ts, ms))
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def io_init(rng, cfg: ModelConfig):
    r = jax.random.split(rng, 4)
    p = {"embed": L.embed_init(r[0], cfg.vocab_size, cfg.d_model, cfg.param_dtype),
         "final_norm": L.norm_init(cfg)}
    if not cfg.tie_embeddings:
        p["head"] = L.dense_init(r[1], cfg.d_model, cfg.vocab_size, cfg.param_dtype)
    if cfg.learned_pos_emb:
        p["pos_embed"] = (jax.random.normal(r[2], (cfg.learned_pos_emb, cfg.d_model),
                                            jnp.float32) * 0.02).astype(cfg.param_dtype)
    if cfg.frontend is not None:
        p["frontend_proj"] = L.dense_init(r[3], cfg.frontend.embed_dim,
                                          cfg.d_model, cfg.param_dtype)
    return p


def embed_tokens(p, tokens, cfg: ModelConfig, positions=None):
    x = jnp.take(p["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    if cfg.learned_pos_emb:
        pos = positions if positions is not None else jnp.arange(tokens.shape[-1])
        x = x + jnp.take(p["pos_embed"], pos, axis=0).astype(cfg.compute_dtype)
    return x


def unembed_matrix(p, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return p["embed"].T
    return p["head"]


def logits_fn(p, x, cfg: ModelConfig):
    logits = (x @ unembed_matrix(p, cfg)).astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


# ---------------------------------------------------------------------------
# decoder-only model (dense / moe / ssm / hybrid / vlm)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[..., Any]
    train_loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    init_cache: Callable[..., Any]
    param_count: Callable[[], Dict[str, int]]


def _decoder_model(cfg: ModelConfig) -> Model:
    n_prefix_tok = cfg.frontend.num_prefix_tokens if cfg.frontend else 0

    def init(rng):
        r1, r2 = jax.random.split(rng)
        return {"io": io_init(r1, cfg), "stack": T.stack_init(r2, cfg)}

    def _embed_batch(params, batch):
        tokens = batch["tokens"]
        x = embed_tokens(params["io"], tokens, cfg)
        if cfg.frontend is not None and "patch_embeds" in batch:
            patches = (batch["patch_embeds"].astype(cfg.compute_dtype)
                       @ params["io"]["frontend_proj"])
            x = jnp.concatenate([patches, x], axis=1)
        x = L.shard_batch(x)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        return x, positions

    def train_loss(params, batch):
        x, positions = _embed_batch(params, batch)
        x, _, aux = T.stack_apply_full(params["stack"], x, positions, cfg,
                                       want_cache=False, remat=True)
        x = L.norm_apply(params["io"]["final_norm"], x, cfg)
        if n_prefix_tok:
            x = x[:, n_prefix_tok:]
        targets = batch["targets"]
        mask = batch.get("loss_mask", jnp.ones(targets.shape, jnp.float32))
        w = unembed_matrix(params["io"], cfg).astype(cfg.compute_dtype)
        ce = chunked_ce_loss(x, w, targets, mask, cfg.final_softcap)
        return ce + aux, {"ce": ce, "aux": aux}

    def prefill(params, batch, use_decode_window: bool = False,
                max_new_tokens: int = 0):
        x, positions = _embed_batch(params, batch)
        ctx_len = x.shape[1]
        x, caches, _ = T.stack_apply_full(params["stack"], x, positions, cfg,
                                          want_cache=True, remat=False)
        x = L.norm_apply(params["io"]["final_norm"], x, cfg)
        logits = logits_fn(params["io"], x[:, -1:], cfg)
        caches = T.caches_from_prefill(cfg, caches, ctx_len, use_decode_window,
                                       max_new_tokens)
        return logits, caches

    def decode_step(params, caches, tokens):
        """tokens: (B, 1) -> logits (B, 1, V), new caches."""
        x = embed_tokens(params["io"], tokens, cfg,
                         positions=_decode_positions(caches, cfg))
        x = L.shard_batch(x)
        x, caches = T.stack_apply_decode(params["stack"], x, caches, cfg)
        x = L.norm_apply(params["io"]["final_norm"], x, cfg)
        return logits_fn(params["io"], x, cfg), caches

    def init_cache(batch_size, ctx_len, long: bool = False):
        return T.stack_cache_init(cfg, batch_size, ctx_len,
                                  use_decode_window=long)

    def param_count():
        params = jax.eval_shape(init, jax.random.PRNGKey(0))
        total = sum(math.prod(l.shape) for l in jax.tree.leaves(params))
        active = total
        if cfg.moe is not None:
            from repro.models.moe import moe_param_count
            per_layer = moe_param_count(cfg)
            n_moe = sum(1 for i in range(cfg.num_layers)
                        if T.block_spec(cfg, i).use_moe)
            active = total - n_moe * (per_layer["total"] - per_layer["active"])
        return {"total": total, "active": active}

    return Model(cfg, init, train_loss, prefill, decode_step, init_cache,
                 param_count)


def _decode_positions(caches, cfg: ModelConfig):
    """Absolute position of the new token = any attn cache's index."""
    def find(tree):
        if isinstance(tree, dict):
            if "index" in tree:
                return tree["index"]
            for v in tree.values():
                r = find(v)
                if r is not None:
                    return r
        elif isinstance(tree, (list, tuple)):
            for v in tree:
                r = find(v)
                if r is not None:
                    return r
        return None

    idx = find(caches)
    if idx is None:
        return None  # pure-recurrent model: positions unused
    if idx.ndim > 0:            # scan-stacked per-unit indices (all equal)
        idx = idx.reshape(-1)[0]
    return idx[None, None]


def build(cfg: ModelConfig) -> Model:
    if cfg.encdec:
        from repro.models.encdec import encdec_model
        return encdec_model(cfg)
    return _decoder_model(cfg)
