"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

    r_t = sigmoid(W_r x_t),  i_t = sigmoid(W_i x_t)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses ``jax.lax.associative_scan`` over the sequence (the
TPU-native way to parallelize a linear recurrence); decode is a single
fused step. Block layout follows Griffin's recurrent block: two branches
(gate branch with SiLU; recurrence branch with causal conv4 + RG-LRU),
merged multiplicatively and projected out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.models.ssm import causal_dwconv, causal_dwconv_step


def rglru_init(rng, cfg: ModelConfig):
    g = cfg.rglru
    d = cfg.d_model
    w = g.lru_width or d
    r = jax.random.split(rng, 7)
    # Lambda init so a^c in [0.9, 0.999] (Griffin appendix)
    u = jax.random.uniform(r[5], (w,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2 * g.c_const)))  # inv softplus
    return {
        "w_gate_branch": dense_init(r[0], d, w, cfg.param_dtype),
        "w_rec_branch": dense_init(r[1], d, w, cfg.param_dtype),
        "conv_w": (jax.random.normal(r[2], (g.conv_width, w), jnp.float32)
                   * 0.1).astype(cfg.param_dtype),
        "w_r": dense_init(r[3], w, w, cfg.param_dtype),
        "w_i": dense_init(r[4], w, w, cfg.param_dtype),
        "lambda_raw": lam,
        "w_out": dense_init(r[6], w, d, cfg.param_dtype),
    }


def _gates(p, x, cfg: ModelConfig):
    g = cfg.rglru
    r = jax.nn.sigmoid((x @ p["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ p["w_i"]).astype(jnp.float32))
    log_a = -g.c_const * jax.nn.softplus(p["lambda_raw"]) * r   # (…, W)
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via log: 0.5*log1p(-exp(2 log_a))
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i


def rglru_scan(a, bx):
    """h_t = a_t h_{t-1} + bx_t via associative scan. a, bx: (B, S, W)."""
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2

    a_out, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    del a_out
    return h


def rglru_apply_full(p, x, cfg: ModelConfig, h0=None):
    """x: (B, S, D) -> (B, S, D), decode cache {conv, h}."""
    g = cfg.rglru
    gate = jax.nn.silu(x @ p["w_gate_branch"])
    u_pre = x @ p["w_rec_branch"]
    u = causal_dwconv(u_pre, p["conv_w"])
    a, scale = _gates(p, u, cfg)
    bx = scale * u.astype(jnp.float32)
    if h0 is not None:
        # fold the incoming state into the first step
        bx = bx.at[:, 0].add(a[:, 0] * h0)
    h = rglru_scan(a, bx)
    out = (h.astype(x.dtype) * gate) @ p["w_out"]
    conv_tail = u_pre[:, -(g.conv_width - 1):].astype(cfg.compute_dtype)
    return out, {"conv": conv_tail, "h": h[:, -1]}


def rglru_apply_decode(p, x, cache, cfg: ModelConfig):
    """x: (B, 1, D); cache: {conv (B,W-1,Wd), h (B,Wd)}."""
    x_t = x[:, 0]
    gate = jax.nn.silu(x_t @ p["w_gate_branch"])
    u = x_t @ p["w_rec_branch"]
    u, conv_state = causal_dwconv_step(u, cache["conv"], p["conv_w"])
    a, scale = _gates(p, u, cfg)
    h = a * cache["h"] + scale * u.astype(jnp.float32)
    out = ((h.astype(x.dtype) * gate) @ p["w_out"])[:, None]
    return out, {"conv": conv_state, "h": h}


def rglru_cache_init(cfg: ModelConfig, batch: int):
    g = cfg.rglru
    w = g.lru_width or cfg.d_model
    return {"conv": jnp.zeros((batch, g.conv_width - 1, w), cfg.compute_dtype),
            "h": jnp.zeros((batch, w), jnp.float32)}
