"""Proxy-only constraint-loop simulation: the Lagrangian dynamics with
no NN in the loop (usage comes straight from the calibrated Appendix-A.1
resource model), so a controller or knob-policy choice can be evaluated
in milliseconds. Shared by ``benchmarks/fl_engine_bench.py`` and
``examples/constraint_controllers.py`` — one definition of the loop, so
the benchmark and the example can never drift apart.

The measurement source is the ``ResourceModel`` proxy dict, so the
simulated constraint set must only name proxy resources (the paper
four); report-derived constraints (``wire_mb``, ``latency``) need the
real engine.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.constraints.constraint import ConstraintSpec
from repro.constraints.controllers import ControllerSpec
from repro.constraints.knobs import KnobPolicySpec

from repro.configs.base import FLConfig
from repro.constraints.constraint import make_constraints
from repro.constraints.controllers import (make_controller,
                                           resolve_dual_configs)
from repro.constraints.knobs import make_knob_policy
from repro.core.duals import DualState
from repro.core.policy import Knobs
from repro.core.resources import calibrate

# the active-parameter fit used by the sweep/bench examples: freezing k
# of k_base layer groups keeps ~6% (embeddings/head) always trainable
ACTIVE_FLOOR = 0.06


def proxy_control_loop(fl: FLConfig, controller: ControllerSpec = "deadzone",
                       rounds: int = 80, p_base: float = 1.9e6,
                       constraints: ConstraintSpec = "paper",
                       knob_policy: KnobPolicySpec = "paper"
                       ) -> List[Tuple[Knobs, Dict[str, float]]]:
    """Roll the duals->knobs->usage->duals loop forward ``rounds`` steps
    and return the per-round ``(knobs, {constraint: ratio})`` history."""
    cset = make_constraints(constraints)
    ctrl = make_controller(controller)
    pol = make_knob_policy(knob_policy, constraints=cset)
    res = calibrate(p_base, fl)
    # per-constraint DualConfig overrides (fl.dual_overrides) apply in
    # the proxy loop exactly as in CAFLL.update_state, unknown-name
    # fail-fast included (one shared resolver, so they cannot diverge)
    cfgs = resolve_dual_configs(fl.duals, fl.dual_overrides, cset.names)
    duals = DualState(lam=cset.init_lam())
    history: List[Tuple[Knobs, Dict[str, float]]] = []
    for _ in range(rounds):
        kn = pol.knobs(duals, fl)
        p_active = p_base * ((1 - ACTIVE_FLOOR) * kn.k / fl.k_base
                             + ACTIVE_FLOOR)
        usage = res.usage(p_active, kn)
        ratios = cset.ratios(usage, fl.budgets)
        duals = DualState(lam={
            c.name: ctrl.step(c.name, duals.lam[c.name], ratios[c.name],
                              cfgs[c.name])
            for c in cset})
        history.append((kn, ratios))
    return history


def rounds_to_band(history: List[Tuple[Knobs, Dict[str, float]]],
                   band: float) -> Optional[int]:
    """First round (1-based) whose *worst* constraint ratio is inside
    the satisfaction band (<= band), or None if it never enters."""
    for i, (_, ratios) in enumerate(history):
        if max(ratios.values()) <= band:
            return i + 1
    return None


def tail_worst_ratio(history: List[Tuple[Knobs, Dict[str, float]]],
                     tail: int = 10) -> float:
    """Mean worst-constraint ratio over the last ``tail`` rounds — the
    steady-state violation a controller settles at."""
    window = history[-tail:]
    return sum(max(r.values()) for _, r in window) / len(window)
