"""First-class Constraint API: pluggable constraints x dual controllers
x knob policies — the Lagrangian loop (paper Eq. 2-7) as three
independently replaceable axes, mirroring what ``repro.fl.aggregator``
did for the server-update path.

    from repro.constraints import (make_constraints, PIController,
                                   DeadlineAwareKnobPolicy)

    strategy = CAFLL(fl, constraints="paper+wire_mb",
                     controller=PIController(),
                     knob_policy=DeadlineAwareKnobPolicy())

or per-config: ``fl.constraints`` / ``fl.dual_controller`` /
``fl.knob_policy`` (string registry + instance passthrough). The
default stack — ``DeadzoneSubgradient`` + ``PaperKnobPolicy`` + the
four paper proxies — reproduces the seed's dual/knob trajectories
bit-for-bit (pinned by ``tests/golden/``).
"""
from repro.constraints.constraint import (  # noqa: F401
    CONSTRAINT_REGISTRY, KNOB_GROUPS, Constraint, ConstraintReport,
    ConstraintSet, make_constraints, paper_constraints,
    register_constraint,
)
from repro.constraints.controllers import (  # noqa: F401
    CONTROLLERS, AdaptiveStep, DeadzoneSubgradient, DualController,
    PIController, dual_config_for, make_controller, resolve_dual_configs,
)
from repro.constraints.knobs import (  # noqa: F401
    KNOB_POLICIES, DeadlineAwareKnobPolicy, KnobPolicy, PaperKnobPolicy,
    make_knob_policy,
)
from repro.constraints.sim import (  # noqa: F401
    proxy_control_loop, rounds_to_band, tail_worst_ratio,
)
