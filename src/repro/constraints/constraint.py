"""First-class constraints: what is budgeted, how it is measured.

CAFL-L's Lagrangian loop (Eq. 2-4) is defined over an arbitrary set of
per-round resource constraints ``u_j(w) <= b_j``; the paper instantiates
four (energy / comm / memory / temperature, Appendix A.1 proxies) and
the seed hard-coded that 4-tuple into the dual math. A ``Constraint``
makes the set an open registry instead:

    name        the dual variable's key (``DualState.lam[name]``)
    measure     ClientReport -> per-client usage this round (the paper
                proxies read ``report.usage[name]``; new constraints can
                read anything the report carries — actual wire bytes,
                arrival time, true accumulated energy)
    budget_of   Budgets -> this constraint's bound b_j (per device
                profile, since each profile carries its own Budgets)
    knob_group  which Eq. 5-7 dual group the constraint's lambda joins
                ("energy" | "comm" | "memory" | "temp" | None): the
                paper's knob mapping is written over four grouped
                multipliers, so a *new* constraint steers the knobs by
                joining a group — or stays observational with None

Registering a fifth constraint (e.g. ``wire_mb``, the measured wire
bytes instead of the comm proxy) requires no change to the dual update
or the knob policy: the controller runs one dual per registered
constraint and ``PaperKnobPolicy`` folds grouped lambdas exactly as
Eq. 5-7 did.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, Optional, Sequence, Tuple, Union

from repro.configs.base import Budgets

# the Eq. 5-7 dual groups (== the paper's four constraints)
KNOB_GROUPS = ("energy", "comm", "memory", "temp")


@dataclass(frozen=True)
class Constraint:
    """One budgeted resource: measurement + bound + knob coupling."""

    name: str
    measure: Callable[[Any], float]          # ClientReport -> usage
    budget_of: Callable[[Budgets], float]    # profile budgets -> b_j
    knob_group: Optional[str] = None         # Eq. 5-7 group or None

    def __post_init__(self) -> None:
        if self.knob_group is not None and self.knob_group not in KNOB_GROUPS:
            raise ValueError(
                f"constraint {self.name!r}: unknown knob_group "
                f"{self.knob_group!r}; options: {', '.join(KNOB_GROUPS)}, None")


@dataclass(frozen=True)
class ConstraintReport:
    """One constraint's accounting for one dual update (per profile):
    the round's mean usage, the bound, their ratio, and the dual's move.
    ``violated`` is the hard budget test u > b (the deadzone band is the
    *controller's* stability device, not the constraint's semantics)."""

    name: str
    profile: str
    usage: float
    budget: float
    ratio: float
    lam_prev: float
    lam: float
    violated: bool

    def as_dict(self) -> Dict[str, float]:
        return {"usage": self.usage, "budget": self.budget,
                "ratio": self.ratio, "lam": self.lam,
                "violated": self.violated}


class ConstraintSet:
    """An ordered collection of constraints — the object the strategy,
    engine and knob policy share. Order is the dual-state key order."""

    def __init__(self, constraints: Sequence[Constraint]):
        names = [c.name for c in constraints]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate constraint names: {names}")
        self.constraints: Tuple[Constraint, ...] = tuple(constraints)

    def __iter__(self) -> Iterator[Constraint]:
        return iter(self.constraints)

    def __len__(self) -> int:
        return len(self.constraints)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(c.name for c in self.constraints)

    def measure(self, report: Any) -> Dict[str, float]:
        """Per-client measurement dict, keyed by constraint name — the
        round telemetry the dual update consumes."""
        return {c.name: float(c.measure(report)) for c in self.constraints}

    def budgets_dict(self, budgets: Budgets) -> Dict[str, float]:
        return {c.name: float(c.budget_of(budgets)) for c in self.constraints}

    def ratios(self, usage: Dict[str, float],
               budgets: Budgets) -> Dict[str, float]:
        return {c.name: usage[c.name] / c.budget_of(budgets)
                for c in self.constraints}

    def zero_usage(self) -> Dict[str, float]:
        return {c.name: 0.0 for c in self.constraints}

    def init_lam(self) -> Dict[str, float]:
        return {c.name: 0.0 for c in self.constraints}

    def grouped_lam(self, lam: Dict[str, float]) -> Dict[str, float]:
        """Fold per-constraint duals into the four Eq. 5-7 groups. With
        the paper set this is the identity (each constraint is its own
        group), so the default stack stays bit-for-bit."""
        out = {g: 0.0 for g in KNOB_GROUPS}
        for c in self.constraints:
            if c.knob_group is not None:
                out[c.knob_group] += lam.get(c.name, 0.0)
        return out


# ---------------------------------------------------------------------------
# the paper's four constraints + registered extras
# ---------------------------------------------------------------------------


def _proxy(name: str, budget_of: Callable[[Budgets], float]) -> Constraint:
    """One of the paper's Appendix-A.1 proxy constraints: measured from
    the resource model's usage dict the engine stamps on every report."""
    return Constraint(name=name, budget_of=budget_of,
                      measure=lambda rep, _n=name: rep.usage[_n],
                      knob_group=name)


def paper_constraints() -> ConstraintSet:
    """The paper's (E, C, M, T) tuple — the default stack and the one
    the golden trajectories pin."""
    return ConstraintSet([
        _proxy("energy", lambda b: b.energy),
        _proxy("comm", lambda b: b.comm_mb),
        _proxy("memory", lambda b: b.memory),
        _proxy("temp", lambda b: b.temp),
    ])


# registered constraints, instantiable by name. Each factory returns a
# fresh Constraint so instances never share state.
CONSTRAINT_REGISTRY: Dict[str, Callable[[], Constraint]] = {}


def register_constraint(name: str,
                        factory: Callable[[], Constraint]) -> None:
    """Make ``name`` resolvable by ``make_constraints`` specs. Re-registering
    a name overwrites (last wins), so experiments can shadow built-ins."""
    CONSTRAINT_REGISTRY[name] = factory


register_constraint("energy", lambda: _proxy("energy", lambda b: b.energy))
register_constraint("comm", lambda: _proxy("comm", lambda b: b.comm_mb))
register_constraint("memory", lambda: _proxy("memory", lambda b: b.memory))
register_constraint("temp", lambda: _proxy("temp", lambda b: b.temp))


register_constraint("wire_mb", lambda: Constraint(
    # the *measured* wire bytes (quantized payload + scales), not the
    # Appendix-A.1 comm proxy — held to the same comm budget, and its
    # dual joins the comm group so violation drives compression (q)
    name="wire_mb", measure=lambda rep: rep.wire_mb_actual,
    budget_of=lambda b: b.comm_mb, knob_group="comm"))

register_constraint("energy_true", lambda: Constraint(
    # beyond-paper 'true compute': energy including the grad-accum
    # microbatches Eq. 8 adds (the A.1 proxy deliberately omits them)
    name="energy_true", measure=lambda rep: rep.energy_true,
    budget_of=lambda b: b.energy, knob_group="energy"))

register_constraint("latency", lambda: Constraint(
    # straggler pressure: the client's simulated arrival time against
    # one deadline unit. Observational (no knob group) — pair it with a
    # DeadlineAwareKnobPolicy to act on it.
    name="latency", measure=lambda rep: rep.arrival_time,
    budget_of=lambda b: 1.0, knob_group=None))


ConstraintSpec = Union[str, Constraint, ConstraintSet,
                       Sequence[Union[str, Constraint]], None]


def make_constraints(spec: ConstraintSpec = "paper") -> ConstraintSet:
    """Resolve a constraint-stack spec:

        "paper"                     the four proxies (default)
        "paper+wire_mb"             the four plus registered extras
        ["energy", Constraint(...)] mixed names / instances
        ConstraintSet               passthrough
    """
    if spec is None:
        return paper_constraints()
    if isinstance(spec, ConstraintSet):
        return spec
    if isinstance(spec, Constraint):
        return ConstraintSet([spec])
    if isinstance(spec, str):
        spec = spec.split("+")
    out: list = []
    for item in spec:
        if isinstance(item, Constraint):
            out.append(item)
        elif item == "paper":
            out.extend(paper_constraints())
        elif item in CONSTRAINT_REGISTRY:
            out.append(CONSTRAINT_REGISTRY[item]())
        else:
            raise ValueError(
                f"unknown constraint {item!r}; options: paper, "
                f"{', '.join(sorted(CONSTRAINT_REGISTRY))}, or a "
                f"Constraint instance")
    return ConstraintSet(out)
