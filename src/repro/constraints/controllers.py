"""Dual controllers: how a constraint's multiplier answers its ratio.

The paper's dual ascent (Eq. 4) is one member of a family of scalar
control laws mapping the violation signal ``dz(u/b)`` (the dead-zoned
usage ratio) to the next multiplier. A ``DualController`` runs that law
for *every* registered constraint of *every* device profile — state, if
any (PI integrals), is keyed per ``"profile:constraint"`` so one
controller instance serves a heterogeneous fleet.

Shared invariants every controller must keep (property-tested):

    0 <= lambda <= lambda_max                     (dual feasibility)
    ratio inside the +-deadzone band -> lambda is stationary
    sustained violation  -> lambda non-decreasing (pressure builds)
    sustained slack      -> lambda non-increasing (pressure decays)

``DeadzoneSubgradient`` is the paper's Eq. 4 bit-for-bit (the golden
trajectories pin it through the default CAFLL stack; the seed's
``repro.core.duals.dual_update`` now delegates here). ``AdaptiveStep``
scales the step by the violation magnitude — large excursions close
faster without raising eta's steady-state chatter. ``PIController`` is
a positional PI law on the dead-zoned error: the proportional term
reacts instantly, the (anti-windup-clamped) integral carries the
steady-state pressure.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Mapping, Optional, Union

import jax
import jax.numpy as jnp

from repro.configs.base import DualConfig
from repro.core.duals import deadzone


def _clip(lam: float, cfg: DualConfig) -> float:
    return float(min(max(lam, 0.0), cfg.lambda_max))


def dual_config_for(base: DualConfig, overrides: Optional[Mapping[str, Any]],
                    name: str) -> DualConfig:
    """Resolve constraint ``name``'s effective DualConfig.

    ``overrides`` is the ``fl.dual_overrides`` mapping: constraint name
    -> either a full ``DualConfig`` or a dict of field overrides applied
    on top of the shared ``base`` (e.g. ``{"latency": {"eta": 1.0}}``
    runs the latency dual at a faster learning rate without touching
    the eta the paper's four proxies share). Unknown-field overrides
    raise, so typos cannot silently fall back to the shared config.
    """
    if not overrides or name not in overrides:
        return base
    ov = overrides[name]
    if isinstance(ov, DualConfig):
        return ov
    return dataclasses.replace(base, **dict(ov))


def resolve_dual_configs(base: DualConfig,
                         overrides: Optional[Mapping[str, Any]],
                         names: Iterable[str]) -> Dict[str, DualConfig]:
    """Resolve every constraint's effective DualConfig at once, with
    the unknown-name fail-fast both consumers (``CAFLL`` and the proxy
    control loop) must agree on: an override keyed by a constraint not
    in ``names`` raises instead of being silently dropped."""
    names = tuple(names)
    unknown = set(overrides or ()) - set(names)
    if unknown:
        raise ValueError(
            f"fl.dual_overrides names unregistered constraints "
            f"{sorted(unknown)}; this stack has {list(names)}")
    return {n: dual_config_for(base, overrides, n) for n in names}


class DualController:
    """One dual-ascent law, applied independently per constraint.

        step(key, lam, ratio, cfg) -> new lambda

    ``key`` identifies the (profile, constraint) stream for stateful
    laws; stateless laws ignore it. ``reset`` clears any such state.
    """

    name = "base"

    def reset(self) -> None:
        pass

    def step(self, key: str, lam: float, ratio: float,
             cfg: DualConfig) -> float:
        raise NotImplementedError

    def state_snapshot(self) -> Dict[str, Any]:
        return {"name": self.name}


class DeadzoneSubgradient(DualController):
    """The paper's Eq. 4: lambda <- clip(lambda + eta * dz(u/b)).
    Stateless; arithmetic identical to the seed's ``dual_update``."""

    name = "deadzone"

    def step(self, key: str, lam: float, ratio: float,
             cfg: DualConfig) -> float:
        lam = lam + cfg.eta * deadzone(ratio, cfg.deadzone)
        return float(min(max(lam, 0.0), cfg.lambda_max))


class AdaptiveStep(DualController):
    """Violation-magnitude-scaled subgradient: the effective step is
    ``eta * min(1 + gain * |dz|, max_scale) * dz`` — a 5x budget blowout
    closes in a handful of rounds instead of eta-paced dozens, while
    near-band behaviour (|dz| -> 0) matches the paper's law, keeping
    steady-state oscillation no worse than deadzone's."""

    name = "adaptive"

    def __init__(self, gain: float = 2.0, max_scale: float = 5.0):
        assert gain >= 0.0 and max_scale >= 1.0
        self.gain = gain
        self.max_scale = max_scale

    def step(self, key: str, lam: float, ratio: float,
             cfg: DualConfig) -> float:
        dz = deadzone(ratio, cfg.deadzone)
        scale = min(self.max_scale, 1.0 + self.gain * abs(dz))
        return _clip(lam + cfg.eta * scale * dz, cfg)


class PIController(DualController):
    """Positional PI on the dead-zoned error:

        I_t    = clip(I_{t-1} + dz, 0, lambda_max / ki)   (anti-windup)
        lambda = clip(kp * dz + ki * I_t)

    Gains are expressed relative to the configured eta (``kp = kp_scale
    * eta`` etc.) so one DualConfig drives every controller family. The
    proportional term gives an immediate response the pure-integral
    paper law lacks; the windup clamp keeps the integral inside the
    range where it can still move lambda, so recovery after a long
    violation is not delayed by accumulated excess."""

    name = "pi"

    def __init__(self, kp_scale: float = 2.0, ki_scale: float = 1.0):
        assert kp_scale >= 0.0 and ki_scale >= 0.0
        assert kp_scale > 0.0 or ki_scale > 0.0, "PI with both gains 0"
        self.kp_scale = kp_scale
        self.ki_scale = ki_scale
        self._integral: Dict[str, float] = {}

    def reset(self) -> None:
        self._integral.clear()

    def step(self, key: str, lam: float, ratio: float,
             cfg: DualConfig) -> float:
        dz = deadzone(ratio, cfg.deadzone)
        kp = self.kp_scale * cfg.eta
        ki = self.ki_scale * cfg.eta
        i = self._integral.get(key)
        if i is None:
            # first sight of this stream: seed the integral from the
            # incoming multiplier so a warm start (init_duals) is held,
            # not snapped to kp*dz + 0 on the first update
            i = (lam / ki) if ki > 0.0 else 0.0
        if dz != 0.0:
            i = i + dz
            if ki > 0.0:
                i = min(max(i, 0.0), cfg.lambda_max / ki)
        self._integral[key] = i
        return _clip(kp * dz + ki * i, cfg)

    def state_snapshot(self) -> Dict[str, Any]:
        return {"name": self.name, "integrals": dict(self._integral)}


def dual_step_jnp(lam: jax.Array, ratio: jax.Array, eta: float,
                  delta: float, lambda_max: float) -> jax.Array:
    """Traceable (vectorized) twin of ``DeadzoneSubgradient.step``:
    the paper's Eq. 4 over a whole constraint stack at once.

        lambda <- clip(lambda + eta * dz(ratio), 0, lambda_max)

    Matches the scalar law elementwise (pinned by tests); being pure
    jnp it is also the entry the trace analysis prices — the scalar
    ``deadzone`` is a Python branch and cannot be traced."""
    x = ratio - 1.0
    dz = jnp.where(jnp.abs(x) <= delta, jnp.zeros_like(x), x)
    return jnp.clip(lam + eta * dz, 0.0, lambda_max)


# ---------------------------------------------------------------------------
# trace-analysis entry points (repro.analysis.trace)
# ---------------------------------------------------------------------------


def _dual_build() -> Any:
    from repro.configs import get_fl_config
    cfg = get_fl_config().duals

    def fn(lam: jax.Array, ratio: jax.Array) -> jax.Array:
        return dual_step_jnp(lam, ratio, cfg.eta, cfg.deadzone,
                             cfg.lambda_max)

    sds = jax.ShapeDtypeStruct((4,), jnp.float32)
    return fn, (sds, sds)


def trace_entry_points() -> List[Any]:
    """Declared traceable surface: one dual ascent step over the
    paper's four multipliers."""
    from repro.analysis.trace.registry import EntryPoint
    return [EntryPoint(
        name="constraints.dual_update",
        path="src/repro/constraints/controllers.py", line=199,
        build=_dual_build,
        note="Eq. 4 dead-zoned dual ascent, 4 constraints")]


CONTROLLERS = ("deadzone", "adaptive", "pi")

ControllerSpec = Union[str, DualController, None]


def make_controller(spec: ControllerSpec = "deadzone",
                    **kw: Any) -> DualController:
    """Resolve a controller spec: an instance passes through; strings
    name a law ("deadzone", "adaptive", "pi")."""
    if spec is None:
        return DeadzoneSubgradient()
    if isinstance(spec, DualController):
        return spec
    name = spec.lower()
    if name in ("deadzone", "subgradient"):
        return DeadzoneSubgradient(**kw)
    if name == "adaptive":
        return AdaptiveStep(**kw)
    if name == "pi":
        return PIController(**kw)
    raise ValueError(f"unknown dual controller {spec!r}; "
                     f"options: {', '.join(CONTROLLERS)}")
