"""Knob policies: duals -> training knobs (and, optionally, the server
deadline).

The paper's pi(lambda) (Eq. 5-7 + the compression rule) is one choice
of how the dual pressure steers the client configuration; a
``KnobPolicy`` makes it pluggable. Policies also get a per-round
``observe`` hook with the round's composition (``RoundPlan``), the
delivered reports, and the live ``FleetDynamics`` — this is where
*server-side* knobs live: ``DeadlineAwareKnobPolicy`` widens the
straggler deadline when the dropped fraction starves the dual update
(no reports -> no usage telemetry -> duals frozen at their last value
while the fleet burns budget), using the per-client arrival times the
engine has exposed since the aggregator redesign.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Union

from repro.configs.base import FLConfig
from repro.core.duals import DualState
from repro.core.policy import Knobs, policy
from repro.constraints.constraint import ConstraintSet


class KnobPolicy:
    """Maps the dual state to this round's knobs.

        knobs(duals, fl)              -> Knobs           (Eq. 5-7 seat)
        observe(plan, reports, dyn)   -> None            (round telemetry)

    ``observe`` fires once per round after constraint accounting; the
    default is a no-op, so purely dual-driven policies stay pure.
    """

    name = "base"

    def reset(self) -> None:
        pass

    def knobs(self, duals: DualState, fl: FLConfig) -> Knobs:
        raise NotImplementedError

    def observe(self, plan, reports: Sequence, dynamics) -> None:
        pass

    def state_snapshot(self) -> Dict[str, Any]:
        return {"name": self.name}


class PaperKnobPolicy(KnobPolicy):
    """The paper's Eq. 5-7 mapping + compression rule, generalized to an
    arbitrary constraint stack: per-constraint duals are folded into the
    four knob groups (``Constraint.knob_group``) and handed to the
    original mapping. With the paper's four constraints the fold is the
    identity, so the default stack is bit-for-bit the seed's
    ``core.policy.policy`` (the golden trajectories pin it)."""

    name = "paper"

    def __init__(self, constraints: Optional[ConstraintSet] = None):
        self.constraints = constraints

    def knobs(self, duals, fl):
        lam = duals.lam
        if self.constraints is not None:
            lam = self.constraints.grouped_lam(lam)
        return policy(DualState(lam=lam), fl)


class DeadlineAwareKnobPolicy(KnobPolicy):
    """Dual-aware deadline control, wrapped around any base policy.

    Under a tight straggler deadline the constraint loop can deadlock:
    every sampled client misses, no report reaches the server, the dual
    update starves (usage telemetry is exactly the reports), so the
    duals never shrink the knobs that would make clients faster — and
    the carry-over debt boost makes the next attempt slower still.

    This policy watches each round's reported fraction. When it falls
    below ``min_report_frac`` it widens the deadline toward the arrival
    time the target fraction would have needed (the engine's per-client
    wall-clock draws, ``plan.times``) plus ``headroom`` — sitting
    exactly on the needed time would re-drop the fleet on the next
    float-rounding wobble — capped at ``max_scale`` x the original
    deadline. When the fleet fully reports it relaxes the deadline by
    ``relax`` per round, but never below what this round's slowest
    arrival (plus headroom) needed, so relaxation cannot re-starve the
    very clients it just recovered. The training-knob mapping is
    delegated to ``base`` untouched.
    """

    name = "deadline_aware"

    def __init__(self, base: Optional[KnobPolicy] = None,
                 min_report_frac: float = 0.5, widen: float = 1.3,
                 max_scale: float = 4.0, relax: float = 0.9,
                 headroom: float = 1.05):
        assert 0.0 < min_report_frac <= 1.0
        assert widen > 1.0 and max_scale >= 1.0 and 0.0 < relax <= 1.0
        assert headroom >= 1.0
        self.base = base or PaperKnobPolicy()
        self.min_report_frac = min_report_frac
        self.widen = widen
        self.max_scale = max_scale
        self.relax = relax
        self.headroom = headroom
        self.scale = 1.0
        self._base_deadline: Optional[float] = None
        self._strag = None              # the straggler model we widened

    def reset(self) -> None:
        self.base.reset()
        if self._strag is not None and self._base_deadline is not None:
            # undo the widening: otherwise a later run (or a fresh
            # engine sharing this instance) would capture the widened
            # deadline as its new base and ratchet upward forever
            self._strag.deadline = self._base_deadline
        self.scale = 1.0
        self._base_deadline = None
        self._strag = None

    def knobs(self, duals, fl):
        return self.base.knobs(duals, fl)

    def _needed_scale(self, time: float) -> float:
        return time * self.headroom / self._base_deadline

    def observe(self, plan, reports, dynamics) -> None:
        strag = getattr(dynamics, "stragglers", None)
        deadline = getattr(strag, "deadline", None)
        if deadline is None or not plan.sampled:
            return                      # no deadline to control
        if self._base_deadline is None:
            self._base_deadline = deadline
            self._strag = strag
        frac = len(plan.survivors) / len(plan.sampled)
        if frac < self.min_report_frac:
            # widen at least multiplicatively, and directly to the
            # arrival time the target fraction would have needed when
            # the round's wall-clock draws say where that is
            scale = self.scale * self.widen
            if plan.times:
                k = max(0, math.ceil(self.min_report_frac
                                     * len(plan.times)) - 1)
                scale = max(scale, self._needed_scale(sorted(plan.times)[k]))
            self.scale = min(self.max_scale, scale)
        elif frac >= 1.0 and self.scale > 1.0:
            # a fully reporting fleet earns a tighter deadline, bounded
            # by what its slowest member demonstrably needed
            floor = max((self._needed_scale(t) for t in plan.times),
                        default=1.0)
            self.scale = min(self.scale,
                             max(1.0, self.scale * self.relax, floor))
        strag.deadline = self._base_deadline * self.scale

    def state_snapshot(self):
        return {"name": self.name, "scale": self.scale,
                "base_deadline": self._base_deadline,
                "base_policy": self.base.state_snapshot()}


KNOB_POLICIES = ("paper", "deadline_aware")

KnobPolicySpec = Union[str, KnobPolicy, None]


def _thread_constraints(pol: KnobPolicy,
                        constraints: Optional[ConstraintSet]) -> None:
    """Fill an unspecified constraint fold (``PaperKnobPolicy`` built
    with ``constraints=None``) with the strategy's set, recursing into
    wrapper policies' ``base`` — so ``knob_policy=DeadlineAwareKnobPolicy()``
    behaves identically to the ``"deadline_aware"`` string spec under a
    custom constraint stack. An explicitly-set fold is left alone."""
    if constraints is None:
        return
    if isinstance(pol, PaperKnobPolicy) and pol.constraints is None:
        pol.constraints = constraints
    base = getattr(pol, "base", None)
    if isinstance(base, KnobPolicy):
        _thread_constraints(base, constraints)


def make_knob_policy(spec: KnobPolicySpec = "paper",
                     constraints: Optional[ConstraintSet] = None,
                     **kw) -> KnobPolicy:
    """Resolve a knob-policy spec: strings name a policy; instances pass
    through. Either way the strategy's constraint set is threaded into
    any paper mapping whose fold was left unspecified."""
    if spec is None:
        spec = "paper"
    if isinstance(spec, KnobPolicy):
        _thread_constraints(spec, constraints)
        return spec
    name = spec.lower()
    if name == "paper":
        return PaperKnobPolicy(constraints=constraints, **kw)
    if name in ("deadline_aware", "deadline"):
        kw.setdefault("base", PaperKnobPolicy(constraints=constraints))
        return DeadlineAwareKnobPolicy(**kw)
    raise ValueError(f"unknown knob policy {spec!r}; "
                     f"options: {', '.join(KNOB_POLICIES)}")
