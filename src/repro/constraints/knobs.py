"""Knob policies: duals -> training knobs (and, optionally, the server
deadline).

The paper's pi(lambda) (Eq. 5-7 + the compression rule) is one choice
of how the dual pressure steers the client configuration; a
``KnobPolicy`` makes it pluggable. Policies also get a per-round
``observe`` hook with the round's composition (``RoundPlan``), the
delivered reports, and the live ``FleetDynamics`` — this is where
*server-side* knobs live: ``DeadlineAwareKnobPolicy`` widens the
straggler deadline when the dropped fraction starves the dual update
(no reports -> no usage telemetry -> duals frozen at their last value
while the fleet burns budget), using the per-client arrival times the
engine has exposed since the aggregator redesign — and, when a
``latency`` constraint is registered, *tightens* the deadline from
that constraint's dual, closing the latency loop on the axis
``time_mode="wall_clock"`` makes measurable (the deadline is the
simulated cost of a straggler-bound round).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Union

from repro.configs.base import FLConfig
from repro.core.duals import DualState
from repro.core.policy import Knobs, policy
from repro.constraints.constraint import ConstraintSet


class KnobPolicy:
    """Maps the dual state to this round's knobs.

        knobs(duals, fl)              -> Knobs           (Eq. 5-7 seat)
        observe(plan, reports, dyn)   -> None            (round telemetry)

    ``observe`` fires once per round after constraint accounting; the
    default is a no-op, so purely dual-driven policies stay pure.
    """

    name = "base"

    def reset(self) -> None:
        pass

    def knobs(self, duals: DualState, fl: FLConfig) -> Knobs:
        raise NotImplementedError

    def observe(self, plan: Any, reports: Sequence,
                dynamics: Any) -> None:
        pass

    def state_snapshot(self) -> Dict[str, Any]:
        return {"name": self.name}


class PaperKnobPolicy(KnobPolicy):
    """The paper's Eq. 5-7 mapping + compression rule, generalized to an
    arbitrary constraint stack: per-constraint duals are folded into the
    four knob groups (``Constraint.knob_group``) and handed to the
    original mapping. With the paper's four constraints the fold is the
    identity, so the default stack is bit-for-bit the seed's
    ``core.policy.policy`` (the golden trajectories pin it)."""

    name = "paper"

    def __init__(self, constraints: Optional[ConstraintSet] = None):
        self.constraints = constraints

    def knobs(self, duals: DualState, fl: FLConfig) -> Knobs:
        lam = duals.lam
        if self.constraints is not None:
            lam = self.constraints.grouped_lam(lam)
        return policy(DualState(lam=lam), fl)


class DeadlineAwareKnobPolicy(KnobPolicy):
    """Dual-aware deadline control, wrapped around any base policy.

    Under a tight straggler deadline the constraint loop can deadlock:
    every sampled client misses, no report reaches the server, the dual
    update starves (usage telemetry is exactly the reports), so the
    duals never shrink the knobs that would make clients faster — and
    the carry-over debt boost makes the next attempt slower still.

    This policy watches each round's reported fraction. When it falls
    below ``min_report_frac`` it widens the deadline toward the arrival
    time the target fraction would have needed (the engine's per-client
    wall-clock draws, ``plan.times``) plus ``headroom`` — sitting
    exactly on the needed time would re-drop the fleet on the next
    float-rounding wobble — capped at ``max_scale`` x the original
    deadline. When the fleet fully reports it relaxes the deadline by
    ``relax`` per round, but never below what this round's slowest
    arrival (plus headroom) needed, so relaxation cannot re-starve the
    very clients it just recovered. The training-knob mapping is
    delegated to ``base`` untouched.

    **The latency-dual closed loop.** With a ``latency`` constraint
    registered (``fl.constraints="paper+latency"``) the policy also
    reads that constraint's multiplier — the Lagrangian pressure that
    arrivals are running past the latency budget — and *tightens* the
    deadline from it: each observe pulls the scale toward
    ``latency_budget / base_deadline`` (the scale at which one round
    costs exactly the budget) with strength ``min(1, latency_gain *
    lam)``, bounded below by ``min_scale``. Tightening only engages
    when the fleet is reporting adequately (``frac >=
    min_report_frac``): starvation recovery keeps priority, so the two
    arms cannot deadlock — the dual can only speed rounds up once there
    are reports feeding it. When the pressure clears (lam back to 0) a
    below-base scale drifts back toward 1.0 at the ``relax`` rate, so
    a transient spike cannot ratchet the tightened deadline (and its
    discarded work) forever. Under ``time_mode="wall_clock"`` the
    deadline *is* the round's cost ceiling, closing the loop the
    ROADMAP names: latency dual -> deadline -> simulated seconds ->
    arrival ratios -> latency dual. Without a latency dual (the
    default stacks) the multiplier is always 0 and behaviour is
    unchanged.
    """

    name = "deadline_aware"

    def __init__(self, base: Optional[KnobPolicy] = None,
                 min_report_frac: float = 0.5, widen: float = 1.3,
                 max_scale: float = 4.0, relax: float = 0.9,
                 headroom: float = 1.05, latency_name: str = "latency",
                 latency_gain: float = 0.5, latency_budget: float = 1.0,
                 min_scale: float = 0.25):
        assert 0.0 < min_report_frac <= 1.0
        assert widen > 1.0 and max_scale >= 1.0 and 0.0 < relax <= 1.0
        assert headroom >= 1.0
        assert latency_gain >= 0.0 and latency_budget > 0.0
        assert 0.0 < min_scale <= 1.0
        self.base = base or PaperKnobPolicy()
        self.min_report_frac = min_report_frac
        self.widen = widen
        self.max_scale = max_scale
        self.relax = relax
        self.headroom = headroom
        self.latency_name = latency_name
        self.latency_gain = latency_gain
        self.latency_budget = latency_budget
        self.min_scale = min_scale
        self.scale = 1.0
        self._base_deadline: Optional[float] = None
        self._strag = None              # the straggler model we widened
        self._latency_lam = 0.0         # worst latency dual seen this round
        self._last_latency_lam = 0.0    # pressure the last observe applied

    def reset(self) -> None:
        self.base.reset()
        if self._strag is not None and self._base_deadline is not None:
            # undo the widening: otherwise a later run (or a fresh
            # engine sharing this instance) would capture the widened
            # deadline as its new base and ratchet upward forever
            self._strag.deadline = self._base_deadline
        self.scale = 1.0
        self._base_deadline = None
        self._strag = None
        self._latency_lam = 0.0
        self._last_latency_lam = 0.0

    def knobs(self, duals: DualState, fl: FLConfig) -> Knobs:
        # the engine calls knobs() once per device profile before the
        # round runs: remember the worst latency pressure across
        # profiles for this round's observe()
        self._latency_lam = max(self._latency_lam,
                                duals.lam.get(self.latency_name, 0.0))
        return self.base.knobs(duals, fl)

    def _needed_scale(self, time: float) -> float:
        assert self._base_deadline is not None
        return time * self.headroom / self._base_deadline

    def observe(self, plan: Any, reports: Sequence,
                dynamics: Any) -> None:
        lam, self._latency_lam = self._latency_lam, 0.0
        self._last_latency_lam = lam
        strag = getattr(dynamics, "stragglers", None)
        deadline = getattr(strag, "deadline", None)
        if deadline is None or not plan.sampled:
            return                      # no deadline to control
        if self._base_deadline is None:
            self._base_deadline = deadline
            self._strag = strag
        frac = len(plan.survivors) / len(plan.sampled)
        if frac < self.min_report_frac:
            # widen at least multiplicatively, and directly to the
            # arrival time the target fraction would have needed when
            # the round's wall-clock draws say where that is
            scale = self.scale * self.widen
            if plan.times:
                k = max(0, math.ceil(self.min_report_frac
                                     * len(plan.times)) - 1)
                scale = max(scale, self._needed_scale(sorted(plan.times)[k]))
            self.scale = min(self.max_scale, scale)
        elif frac >= 1.0 and self.scale > 1.0:
            # a fully reporting fleet earns a tighter deadline, bounded
            # by what its slowest member demonstrably needed
            floor = max((self._needed_scale(t) for t in plan.times),
                        default=1.0)
            self.scale = min(self.scale,
                             max(1.0, self.scale * self.relax, floor))
        if lam > 0.0 and frac >= self.min_report_frac:
            # latency dual pressure: pull the deadline toward the scale
            # at which one round costs the latency budget; dual ascent
            # (not this policy) decides how hard to pull
            target = max(self.min_scale,
                         self.latency_budget / self._base_deadline)
            w = min(1.0, self.latency_gain * lam)
            pulled = (1.0 - w) * self.scale + w * target
            self.scale = max(self.min_scale, min(self.scale, pulled))
        elif lam <= 0.0 and self.scale < 1.0 and \
                frac >= self.min_report_frac:
            # pressure gone: drift back toward the base deadline at the
            # relax rate — a transient latency spike must not ratchet
            # the tightened deadline (and its discarded work) forever;
            # if arrivals re-violate the budget the dual rises and
            # tightens again, closing the loop in both directions
            self.scale = min(1.0, self.scale / self.relax)
        strag.deadline = self._base_deadline * self.scale

    def state_snapshot(self) -> Dict[str, Any]:
        return {"name": self.name, "scale": self.scale,
                "base_deadline": self._base_deadline,
                # the pressure the most recent observe() actually
                # applied (the accumulator is consumed each round)
                "latency_lam": self._last_latency_lam,
                "base_policy": self.base.state_snapshot()}


KNOB_POLICIES = ("paper", "deadline_aware")

KnobPolicySpec = Union[str, KnobPolicy, None]


def _thread_constraints(pol: KnobPolicy,
                        constraints: Optional[ConstraintSet]) -> None:
    """Fill an unspecified constraint fold (``PaperKnobPolicy`` built
    with ``constraints=None``) with the strategy's set, recursing into
    wrapper policies' ``base`` — so ``knob_policy=DeadlineAwareKnobPolicy()``
    behaves identically to the ``"deadline_aware"`` string spec under a
    custom constraint stack. An explicitly-set fold is left alone."""
    if constraints is None:
        return
    if isinstance(pol, PaperKnobPolicy) and pol.constraints is None:
        pol.constraints = constraints
    base = getattr(pol, "base", None)
    if isinstance(base, KnobPolicy):
        _thread_constraints(base, constraints)


def make_knob_policy(spec: KnobPolicySpec = "paper",
                     constraints: Optional[ConstraintSet] = None,
                     **kw: Any) -> KnobPolicy:
    """Resolve a knob-policy spec: strings name a policy; instances pass
    through. Either way the strategy's constraint set is threaded into
    any paper mapping whose fold was left unspecified."""
    if spec is None:
        spec = "paper"
    if isinstance(spec, KnobPolicy):
        _thread_constraints(spec, constraints)
        return spec
    name = spec.lower()
    if name == "paper":
        return PaperKnobPolicy(constraints=constraints, **kw)
    if name in ("deadline_aware", "deadline"):
        kw.setdefault("base", PaperKnobPolicy(constraints=constraints))
        return DeadlineAwareKnobPolicy(**kw)
    raise ValueError(f"unknown knob policy {spec!r}; "
                     f"options: {', '.join(KNOB_POLICIES)}")
