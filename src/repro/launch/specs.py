"""ShapeDtypeStruct input specs and sharding recipes for every
(architecture x input-shape x mesh) combination.

The sharding recipe is Megatron-orientation tensor parallelism on the
``model`` axis combined with FSDP-style parameter sharding on the
``data`` axis (XLA/GSPMD inserts the gathers), expert parallelism for MoE
(expert dim on ``model``), and batch data-parallel over (pod, data).
``long_500k`` (batch=1) shards the KV-cache sequence dim over ``data``
instead of the batch. The recipe lives in one table so §Perf iterations
can swap rules per-name.
"""
from __future__ import annotations

import re
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.launch.mesh import batch_axes


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Batch specs for train/prefill; decode adds cache specs separately."""
    b, s = shape.global_batch, shape.seq_len
    out = {}
    if cfg.encdec:
        s_src, s_tgt = s // 2, s - s // 2
        if shape.kind == "decode":
            s_src = min(s_src, 4096)        # fixed encoder memory at decode
        out["src_embeds"] = sds((b, s_src, cfg.frontend.embed_dim), jnp.float32)
        out["tokens"] = sds((b, s_tgt), jnp.int32)
        if shape.kind == "train":
            out["targets"] = sds((b, s_tgt), jnp.int32)
        return out
    n_text = s
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        out["patch_embeds"] = sds((b, cfg.frontend.num_prefix_tokens,
                                   cfg.frontend.embed_dim), jnp.float32)
        n_text = s - cfg.frontend.num_prefix_tokens
    out["tokens"] = sds((b, n_text), jnp.int32)
    if shape.kind == "train":
        out["targets"] = sds((b, n_text), jnp.int32)
    return out


def cache_specs(model, cfg: ModelConfig, shape: InputShape):
    """Decode-cache ShapeDtypeStructs via eval_shape (no allocation)."""
    long = shape.name == "long_500k"
    if cfg.encdec:
        return jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                     long=long, src_len=4096))
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, long=long))


# ---------------------------------------------------------------------------
# sharding recipe
# ---------------------------------------------------------------------------

# name-pattern -> spec builder; first match wins. dp = data(+pod for params
# we keep params on data only; batch uses pod too), mp = model.
def _recipe(dp, mp):
    return [
        # --- MoE expert banks (E, in, out): expert-parallel on model ---
        (r"ffn/(expert_gate|expert_up)$", P(mp, dp, None)),
        (r"ffn/expert_down$", P(mp, None, dp)),
        (r"ffn/router$", P(dp, None)),
        # --- MLA ---
        (r"attn/w_dq$", P(dp, mp)),
        (r"attn/w_uq$", P(None, mp)),
        (r"attn/w_dkv$", P(dp, None)),
        (r"attn/w_uk$", P(None, mp)),
        (r"attn/w_uv$", P(None, mp)),
        (r"attn/w_kr$", P(dp, None)),
        # --- attention (megatron orientation) ---
        (r"(attn|self_attn|cross_attn)/w[qkv]$", P(dp, mp)),
        (r"(attn|self_attn|cross_attn)/b[qkv]$", P(mp)),
        (r"(attn|self_attn|cross_attn)/wo$", P(mp, dp)),
        # --- dense MLP ---
        (r"(ffn|shared)/(w_gate|w_up)$", P(dp, mp)),
        (r"(ffn|shared)/b_up$", P(mp)),
        (r"(ffn|shared)/w_down$", P(mp, dp)),
        (r"(ffn|shared)/b_down$", P(dp)),
        # --- xLSTM ---
        (r"mlstm/w_up$", P(dp, mp)),
        (r"mlstm/conv_w$", P(None, mp)),
        (r"mlstm/w[qkv]$", P(dp, mp)),
        (r"mlstm/w_[if]$", P(dp, None)),
        (r"mlstm/(skip_scale|gn_scale)$", P(mp)),
        (r"mlstm/w_down$", P(mp, dp)),
        (r"slstm/w_in$", P(dp, mp)),
        (r"slstm/b_in$", P(mp)),
        (r"slstm/r_blocks$", P(None, None, None, None)),
        (r"slstm/gn_scale$", P(None)),
        (r"slstm/w_up$", P(dp, mp)),
        (r"slstm/w_down$", P(mp, dp)),
        # --- RG-LRU ---
        (r"rec/(w_gate_branch|w_rec_branch)$", P(dp, mp)),
        (r"rec/conv_w$", P(None, mp)),
        (r"rec/w_[ri]$", P(dp, mp)),
        (r"rec/lambda_raw$", P(mp)),
        (r"rec/w_out$", P(mp, dp)),
        # --- io ---
        (r"io/embed$", P(mp, dp)),
        (r"io/head$", P(dp, mp)),
        (r"io/pos_embed$", P(None, None)),
        (r"io/frontend_proj$", P(None, dp)),
        # --- norms & everything 1-D: replicated ---
        (r".*", None),
    ]


def _leaf_spec(path: str, shape, recipe, n_lead: int) -> P:
    for pat, spec in recipe:
        if re.search(pat, path):
            if spec is None:
                return P()
            parts = list(spec) + [None] * max(0, len(shape) - n_lead - len(spec))
            parts = parts[: len(shape) - n_lead]
            return P(*([None] * n_lead + parts))
    return P()


def _tree_paths(tree, prefix=""):
    # PartitionSpec subclasses tuple (JAX >= 0.4.x): a spec is a LEAF,
    # never a container — recursing into it would give a spec tree and
    # its matching shape tree different paths for the same parameter
    # (e.g. '/io/embed/0' vs '/io/embed'), so every tuple-valued leaf
    # type must stop the walk here.
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _tree_paths(tree[k], f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)) and not isinstance(tree, P):
        for i, v in enumerate(tree):
            yield from _tree_paths(v, f"{prefix}/{i}")
    else:
        yield prefix, tree


def _divisible(dim: int, mesh, axis) -> bool:
    if axis is None:
        return True
    names = axis if isinstance(axis, tuple) else (axis,)
    n = 1
    for name in names:
        n *= mesh.shape[name]
    return dim % n == 0


RECIPES = {
    # (dp_axis, mp_axis): Megatron-TP on `model` + FSDP on `data`
    "default": ("data", "model"),
    # serving: params replicated over data (no per-step FSDP gathers),
    # TP over model — the beyond-paper decode optimization (§Perf)
    "tp_serve": (None, "model"),
    # pure ZeRO/FSDP over the combined (data, model) axes — no tensor
    # parallelism; right for small-hidden recurrent archs (xLSTM) whose
    # head counts cannot cover a 16-way model axis (§Perf)
    "fsdp": (("data", "model"), None),
}


def param_shardings(mesh, params_shapes, cfg: ModelConfig,
                    recipe_name: str = "default"):
    """NamedSharding tree matching the shape tree. Dims that do not divide
    their mesh axis fall back to replicated on that dim (e.g. seamless
    vocab 256206 on a 16-way axis)."""
    dp, mp = RECIPES[recipe_name]
    recipe = _recipe(dp, mp)

    def shard_one(path, leaf):
        n_lead = 1 if "/units/" in path or path.endswith("units") or \
            re.search(r"/(units|enc|dec)/", path) else 0
        spec = _leaf_spec(path, leaf.shape, recipe, n_lead)
        fixed = []
        for dim, axis in zip(leaf.shape, tuple(spec) + (None,) * (len(leaf.shape) - len(spec))):
            fixed.append(axis if _divisible(dim, mesh, axis) else None)
        return NamedSharding(mesh, P(*fixed))

    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(tree[k], f"{prefix}/{k}") for k in tree}
        if isinstance(tree, (list, tuple)):
            vals = [walk(v, f"{prefix}/{i}") for i, v in enumerate(tree)]
            return type(tree)(vals) if not isinstance(tree, tuple) else tuple(vals)
        return shard_one(prefix, tree)

    return walk(params_shapes)


def batch_shardings(mesh, batch_specs, shape: InputShape):
    """tokens/targets/embeds: batch over (pod, data); batch=1 -> replicated."""
    bx = batch_axes(mesh)
    b = shape.global_batch
    ax = bx if _divisible(b, mesh, tuple(bx)) else (
        ("data",) if _divisible(b, mesh, "data") else None)

    def one(leaf):
        spec = [ax] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_specs)


def cache_shardings(mesh, cache_spec_tree, cfg: ModelConfig,
                    shape: InputShape):
    """KV caches: batch over (pod,data) when divisible; otherwise (long_500k,
    batch=1) shard the sequence/buffer dim over data. Head dims shard over
    model when divisible; recurrent states shard features over model."""
    bx = batch_axes(mesh)
    b = shape.global_batch
    batch_ok = _divisible(b, mesh, tuple(bx))
    data_ok = _divisible(b, mesh, "data")
    b_ax = tuple(bx) if batch_ok else ("data" if data_ok else None)

    def one(path, leaf):
        n_lead = 1 if (re.search(r"/(units|self)/", path) or "/units" in path
                       or re.search(r"/cross_[kv]", path)) else 0
        dims = leaf.shape[n_lead:]
        spec = [None] * n_lead
        if len(dims) == 0:          # index scalar
            return NamedSharding(mesh, P(*spec) if spec else P())
        rest = [None] * len(dims)
        rest[0] = b_ax
        # (B, S, KVH, D) / (B, S, R): pick seq or head sharding
        if len(dims) >= 2 and b_ax is None and dims[1] % mesh.shape["data"] == 0 \
                and dims[1] > 1024:
            rest[1] = "data"        # sequence-sharded cache (batch=1)
        if len(dims) == 4 and _divisible(dims[2], mesh, "model"):
            rest[2] = "model"       # kv heads cover the model axis
        elif (len(dims) >= 3 and rest[1] is None and dims[1] >= 4096
                and _divisible(dims[1], mesh, "model")):
            # GQA kv-heads (8) cannot cover a 16-way model axis: shard the
            # cache SEQUENCE over `model` instead (distributed-softmax
            # decode). §Perf iteration: cache/device 16x down, kills the
            # whole-cache reshard all-gathers.
            rest[1] = "model"
        # recurrent states (B, H, Dk, Dv) / (B, D): shard features on model
        if re.search(r"/(C|n|h|c|m)$", path):
            rest = [None] * len(dims)
            rest[0] = b_ax
            for i in range(len(dims) - 1, 0, -1):
                if _divisible(dims[i], mesh, "model") and dims[i] >= 16:
                    rest[i] = "model"
                    break
        spec = spec + rest
        return NamedSharding(mesh, P(*spec))


    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(tree[k], f"{prefix}/{k}") for k in tree}
        if isinstance(tree, (list, tuple)):
            vals = [walk(v, f"{prefix}/{i}") for i, v in enumerate(tree)]
            return type(tree)(vals) if not isinstance(tree, tuple) else tuple(vals)
        return one(prefix, tree)

    return walk(cache_spec_tree)


def replicated(mesh):
    return NamedSharding(mesh, P())
