"""Step functions (train / prefill / decode) for the distributed runtime.

These are the functions the dry-run lowers for every (arch x shape x mesh)
combination and the ones a real deployment would pjit. The CAFL-L layer
sits above: a "client" in the production mapping is a mesh slice running
``train_step`` with the policy's (k) freezing mask folded in as a traced
mask tree (one executable for every k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape
from repro.models.zoo import Model
from repro.optim import Optimizer, apply_updates


def make_train_step(model: Model, optimizer: Optimizer,
                    with_freezing_mask: bool = False, microbatches: int = 1):
    """(params, opt_state, batch[, mask]) -> (params, opt_state, loss).

    ``microbatches > 1`` scans gradient accumulation over batch slices —
    the paper's own token-budget mechanism (Eq. 8) doubling as the TPU
    activation-memory lever: working set scales with B/microbatches while
    tokens-per-step stay constant (§Perf pair 3).
    """

    def grads_of(params, batch):
        (loss, _), grads = jax.value_and_grad(model.train_loss, has_aux=True)(
            params, batch)
        return loss, grads

    def accumulate(params, batch):
        if microbatches == 1:
            return grads_of(params, batch)
        split = jax.tree.map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                + x.shape[1:]), batch)

        def body(carry, mb):
            loss_acc, gacc = carry
            loss, grads = grads_of(params, mb)
            gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                gacc, grads)
            return (loss_acc + loss, gacc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (loss_sum, gsum), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), split)
        scale = 1.0 / microbatches
        return loss_sum * scale, jax.tree.map(lambda g: g * scale, gsum)

    def train_step(params, opt_state, batch, mask=None):
        loss, grads = accumulate(params, batch)
        if mask is not None:
            grads = jax.tree.map(lambda g, m: g * m.astype(g.dtype), grads, mask)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        if mask is not None:
            updates = jax.tree.map(lambda u, m: u * m.astype(u.dtype),
                                   updates, mask)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    if not with_freezing_mask:
        return lambda p, o, b: train_step(p, o, b, None)
    return train_step


def make_prefill_step(model: Model, shape: InputShape):
    long = shape.name == "long_500k"

    def prefill_step(params, batch):
        return model.prefill(params, batch, use_decode_window=long)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return decode_step
