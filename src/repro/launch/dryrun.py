"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
and extract memory / cost / collective roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results are written incrementally to results/dryrun/<arch>__<shape>__<mesh>.json
(existing files are skipped — the matrix run is resumable).
"""
# The 512 placeholder devices MUST be configured before any jax import —
# jax locks the device count on first backend initialisation.
import os
_N_DEV = os.environ.get("DRYRUN_DEVICES", "512")
os.environ["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={_N_DEV} "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import INPUT_SHAPES, ARCH_IDS, get_config  # noqa: E402
from repro.launch import specs as S                            # noqa: E402
from repro.launch.mesh import (                                # noqa: E402
    HBM_BW, ICI_BW, PEAK_FLOPS_BF16, activate_mesh, make_production_mesh)
from repro.launch.steps import (                               # noqa: E402
    make_decode_step, make_prefill_step, make_train_step)
from repro.models import build                                 # noqa: E402
from repro.optim import make_optimizer                         # noqa: E402

RESULTS_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str):
    """-> (comp_lines: name -> [instruction lines], entry name)."""
    comp_lines = {}
    entry = None
    comp = None
    for line in hlo_text.splitlines():
        if line.rstrip().endswith("{") and "(" in line:
            m = re.match(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
            if m:
                comp = m.group(2)
                comp_lines[comp] = []
                if m.group(1):
                    entry = comp
                continue
        if comp is not None:
            comp_lines.setdefault(comp, []).append(line)
    return comp_lines, entry


def _comp_multipliers(comp_lines: dict, entry):
    """Per-computation execution multiplier from the call graph: while-loop
    bodies get their trip count (XLA's known_trip_count, falling back to
    the largest constant in the loop condition — lax.scan lowers to
    `counter < N`); fusion/call/cond targets inherit their caller's count.
    Returns (mult, called_set, unknown_trips)."""
    edges = []
    called = set()
    unknown_trips = 0
    for parent, lines in comp_lines.items():
        for line in lines:
            if re.search(r"\bwhile\(", line):
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                mc = re.search(r"condition=%?([\w\.\-]+)", line)
                mt = re.search(r"trip_count[\"':\s=\{]*n?[\"':\s]*(\d+)", line)
                if mb:
                    if mt:
                        t = int(mt.group(1))
                    else:
                        t = 1
                        cond_lines = comp_lines.get(mc.group(1), []) if mc else []
                        consts = [int(x) for cl in cond_lines
                                  for x in re.findall(r"constant\((\d+)\)", cl)]
                        if consts:
                            t = max(consts)
                        else:
                            unknown_trips += 1
                    edges.append((parent, mb.group(1), t))
                    if mc:
                        edges.append((parent, mc.group(1), t))
            for mm in re.finditer(
                    r"(?:to_apply|calls|branch_computations|true_computation|"
                    r"false_computation|called_computations)="
                    r"[\{]?%?([\w\.\-]+)", line):
                edges.append((parent, mm.group(1), 1))
                called.add(mm.group(1))

    mult = {c: 0 for c in comp_lines}
    if entry:
        mult[entry] = 1
    else:
        mult = {c: 1 for c in comp_lines}
    changed = True
    while changed:
        changed = False
        for p, b, t in edges:
            if p in mult and b in mult and mult[p] * t > mult[b]:
                mult[b] = mult[p] * t
                changed = True
    for c in mult:
        if mult[c] == 0:
            mult[c] = 1  # unreached by our walk — count once, never drop
    # innermost-loop trip per computation: while bodies get their own trip;
    # computations called from a body inherit the caller's (fusions etc.)
    own_trip = {c: 1 for c in comp_lines}
    changed = True
    while changed:
        changed = False
        for p, b, t in edges:
            cand = t if t > 1 else own_trip.get(p, 1)
            if b in own_trip and cand > own_trip[b]:
                own_trip[b] = cand
                changed = True
    return mult, called, unknown_trips, own_trip


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-device result bytes of collective ops in post-SPMD HLO,
    weighted by loop trip counts."""
    comp_lines, entry = _split_computations(hlo_text)
    mult, _, unknown_trips, _ = _comp_multipliers(comp_lines, entry)
    per_op = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for comp, lines in comp_lines.items():
        m = mult[comp]
        for line in lines:
            for c in _COLLECTIVES:
                if re.search(rf"\b{c}(-start)?\(", line) and "=" in line:
                    lhs = line.split("=", 1)[0]
                    b = _shape_bytes(lhs)
                    if b == 0:
                        b = _shape_bytes(line.split("=", 1)[1])
                    per_op[c] += b * m
                    counts[c] += m
    return {"bytes_per_device": per_op, "counts": counts,
            "total_bytes_per_device": sum(per_op.values()),
            "unknown_trip_counts": unknown_trips}


_DOT_RE = re.compile(r"=\s*\S+\s+dot\(")


def hlo_costs(hlo_text: str) -> dict:
    """Trip-count-aware per-device FLOPs and HBM bytes from post-SPMD HLO.

    XLA's compiled.cost_analysis() counts while-loop bodies ONCE (verified
    empirically — flops identical for 2- vs 8-iteration scans), which makes
    it useless for scan-over-layers models; this walker multiplies by the
    loop trip counts instead.

    FLOPs: 2 * prod(result_dims) * prod(contracted_dims) per dot op, plus
    1 flop/element for non-dot ops (elementwise estimate).
    Bytes: operand + result bytes of top-level instructions (fusion
    interiors excluded — they stay in registers/VMEM).
    """
    comp_lines, entry = _split_computations(hlo_text)
    mult, called, unknown_trips, own_trip = _comp_multipliers(comp_lines, entry)

    # name -> dims table (post-opt HLO references operands by name only)
    def_re = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\w+)\[([\d,]*)\]")
    shapes = {}
    for lines in comp_lines.values():
        for line in lines:
            md = def_re.match(line)
            if md:
                dims = [int(x) for x in md.group(3).split(",") if x]
                shapes[md.group(1)] = (dims, md.group(2))

    flops = 0.0
    dot_flops = 0.0
    bytes_acc = 0.0
    dot_misses = 0
    for comp, lines in comp_lines.items():
        m = mult[comp]
        top_level = comp not in called   # fusion interiors don't touch HBM
        for line in lines:
            md = def_re.match(line)
            if not md:
                continue
            res_dims = [int(x) for x in md.group(3).split(",") if x]
            res_dt = md.group(2)
            rn = 1
            for dd in res_dims:
                rn *= dd
            res_bytes = rn * _DTYPE_BYTES.get(res_dt, 4)
            # rhs body after the result shape
            rhs = line.split("=", 1)[1]
            mop = re.match(r"\s*\S+\s+([\w\-]+)", rhs)
            opname = mop.group(1) if mop else ""
            if _DOT_RE.search(line):
                mo = re.search(r"dot\(\s*%?([\w\.\-]+)", rhs)
                mc_ = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                cn = 1
                if mo and mo.group(1) in shapes and mc_:
                    lhs_dims = shapes[mo.group(1)][0]
                    for ci in (int(x) for x in mc_.group(1).split(",") if x):
                        if ci < len(lhs_dims):
                            cn *= lhs_dims[ci]
                else:
                    dot_misses += 1
                f = 2.0 * rn * cn
                flops += f * m
                dot_flops += f * m
            else:
                flops += rn * m  # elementwise estimate
            if not top_level:
                continue
            # --- HBM-traffic model per top-level instruction ---
            if opname in ("parameter", "constant", "tuple", "get-tuple-element",
                          "bitcast", "while", "conditional", "call",
                          "after-all", "iota", "partition-id", "replica-id"):
                continue  # views / control flow: interiors counted separately
            trip = own_trip.get(comp, 1)
            iname = md.group(1)  # instruction name encodes fused ops
            if opname in ("dynamic-slice", "slice", "gather"):
                bytes_acc += 2 * res_bytes * m        # read slice + write
                continue
            if (opname in ("dynamic-update-slice", "scatter")
                    or (opname == "fusion" and "dynamic-update-slice" in iname)):
                # in-place slice write inside a loop: the buffer is written
                # fully ONCE across the loop, not per iteration
                bytes_acc += 2 * res_bytes * m / max(trip, 1)
                continue
            sliced_read = opname == "fusion" and "dynamic-slice" in iname
            b = res_bytes                              # result write
            for op in re.findall(r"%([\w\.\-]+)", rhs.split("metadata")[0]):
                if op in shapes:
                    dims, dt = shapes[op]
                    n = 1
                    for dd in dims:
                        n *= dd
                    ob = n * _DTYPE_BYTES.get(dt, 4)
                    if trip > 1 and opname == "fusion" and ob > res_bytes \
                            and not re.search(r"dot|reduce|conv", iname):
                        # big buffer consumed by a smaller-output fusion in
                        # a loop body => sliced access; cap at one full read
                        # per loop (ob/trip) or the output size
                        ob = max(res_bytes if not sliced_read else 0,
                                 ob / trip)
                    b += ob
            bytes_acc += b * m
    return {"flops": flops, "dot_flops": dot_flops, "bytes": bytes_acc,
            "unknown_trip_counts": unknown_trips, "dot_misses": dot_misses}


def model_flops(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode: D = new tokens only."""
    model = build(cfg)
    counts = model.param_count()
    n = counts["active"]
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def build_lowered(arch: str, shape_name: str, multi_pod: bool,
                  opt_name: str = "adamw", recipe: str = "default",
                  microbatches: int = 1):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    model = build(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    if recipe == "fsdp":    # pure ZeRO: batch covers every mesh axis
        os.environ["REPRO_BATCH_AXES"] = "pod,data,model"
    else:
        os.environ.pop("REPRO_BATCH_AXES", None)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_shard = S.param_shardings(mesh, params_shapes, cfg, recipe)
    repl = S.replicated(mesh)

    if shape.kind == "train":
        optimizer = make_optimizer(opt_name, 1e-4)
        opt_shapes = jax.eval_shape(optimizer.init, params_shapes)
        # optimizer state mirrors the parameter tree's sharding
        from repro.optim.optimizers import AdamState
        if isinstance(opt_shapes, AdamState):
            o_shard = AdamState(mu=p_shard, nu=p_shard, count=repl)
        elif opt_shapes == ():
            o_shard = repl
        else:
            o_shard = p_shard
        batch_specs = S.input_specs(cfg, shape)
        b_shard = S.batch_shardings(mesh, batch_specs, shape)
        step = make_train_step(model, optimizer,
                               microbatches=microbatches)
        jitted = jax.jit(step,
                         in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, repl),
                         donate_argnums=(0, 1))
        with activate_mesh(mesh):
            lowered = jitted.lower(params_shapes, opt_shapes, batch_specs)
    elif shape.kind == "prefill":
        batch_specs = S.input_specs(cfg, shape)
        b_shard = S.batch_shardings(mesh, batch_specs, shape)
        step = make_prefill_step(model, shape)
        jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
        with activate_mesh(mesh):
            lowered = jitted.lower(params_shapes, batch_specs)
    else:  # decode
        c_specs = S.cache_specs(model, cfg, shape)
        c_shard = S.cache_shardings(mesh, c_specs, cfg, shape)
        tok_spec = S.sds((shape.global_batch, 1), jnp.int32)
        t_shard = S.batch_shardings(mesh, tok_spec, shape)
        step = make_decode_step(model)
        jitted = jax.jit(step,
                         in_shardings=(p_shard, c_shard, t_shard),
                         out_shardings=(repl, c_shard),
                         donate_argnums=(1,))
        with activate_mesh(mesh):
            lowered = jitted.lower(params_shapes, c_specs, tok_spec)
    return lowered, mesh, cfg, shape


def analyze(lowered, compiled, mesh, cfg, shape) -> dict:
    n_chips = mesh.devices.size
    out = {"n_chips": int(n_chips)}
    try:
        mem = compiled.memory_analysis()
        out["memory"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        live = (out["memory"].get("argument_size_in_bytes", 0)
                + out["memory"].get("temp_size_in_bytes", 0))
        out["memory"]["per_device_total_gb"] = live / 1e9
        out["memory"]["fits_v5e_16gb"] = bool(live < 16e9)
    except Exception as e:  # pragma: no cover
        out["memory_error"] = repr(e)
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        # NOTE: XLA counts while bodies once — kept only for reference.
        out["cost_xla_one_body"] = {
            k: float(cost[k]) for k in ("flops", "bytes accessed")
            if k in cost}
    except Exception as e:  # pragma: no cover
        out["cost_error"] = repr(e)
    hlo = compiled.as_text()
    out["collectives"] = collective_bytes_from_hlo(hlo)
    out["cost"] = hlo_costs(hlo)
    out["hlo_bytes"] = len(hlo)

    flops_per_dev = out["cost"]["dot_flops"]   # MXU work (roofline compute)
    bytes_per_dev = out["cost"]["bytes"]
    coll_per_dev = out["collectives"]["total_bytes_per_device"]
    mf = model_flops(cfg, shape)
    out["roofline"] = {
        "hlo_flops_per_device": flops_per_dev,
        "hlo_flops_with_elementwise": out["cost"]["flops"],
        "hlo_bytes_per_device": bytes_per_dev,
        "collective_bytes_per_device": coll_per_dev,
        "t_compute_s": flops_per_dev / PEAK_FLOPS_BF16,
        "t_memory_s": bytes_per_dev / HBM_BW,
        "t_collective_s": coll_per_dev / ICI_BW,
        "model_flops_total": mf,
        "model_flops_per_device": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / flops_per_dev
        if flops_per_dev else None,
    }
    terms = {k: out["roofline"][f"t_{k}_s"]
             for k in ("compute", "memory", "collective")}
    out["roofline"]["dominant"] = max(terms, key=terms.get)
    return out


class _FakeCompiled:
    """Re-analysis stand-in built from a cached HLO dump."""

    def __init__(self, hlo):
        self._hlo = hlo

    def as_text(self):
        return self._hlo

    def memory_analysis(self):
        raise RuntimeError("no memory analysis in reanalyze mode")

    def cost_analysis(self):
        raise RuntimeError("no xla cost analysis in reanalyze mode")


def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: str = RESULTS_DIR, force: bool = False,
            opt_name: str = "adamw", reanalyze: bool = False,
            recipe: str = "default") -> dict:
    import gzip
    mesh_name = "multipod" if multi_pod else "singlepod"
    os.makedirs(out_dir, exist_ok=True)
    stem = f"{arch}__{shape_name}__{mesh_name}"
    if recipe != "default":
        stem += f"__{recipe}"
    path = os.path.join(out_dir, f"{stem}.json")
    hlo_path = os.path.join(out_dir, f"{stem}.hlo.gz")
    if os.path.exists(path) and not force and not reanalyze:
        with open(path) as f:
            return json.load(f)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "error"}
    t0 = time.time()
    try:
        if reanalyze and os.path.exists(hlo_path) and os.path.exists(path):
            with open(path) as f:
                old = json.load(f)
            with gzip.open(hlo_path, "rt") as f:
                hlo = f.read()
            cfg = get_config(arch)
            shape = INPUT_SHAPES[shape_name]
            mesh = make_production_mesh(multi_pod=multi_pod)
            rec.update(analyze(None, _FakeCompiled(hlo), mesh, cfg, shape))
            rec["memory"] = old.get("memory")       # keep compile-time facts
            rec["lower_s"] = old.get("lower_s")
            rec["compile_s"] = old.get("compile_s")
            rec["status"] = "ok"
            print(f"RE  {arch:24s} {shape_name:12s} {mesh_name:9s} "
                  f"dom={rec['roofline']['dominant']}", flush=True)
        else:
            lowered, mesh, cfg, shape = build_lowered(arch, shape_name,
                                                      multi_pod, opt_name,
                                                      recipe=recipe)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            with gzip.open(hlo_path, "wt") as f:
                f.write(compiled.as_text())
            rec.update(analyze(lowered, compiled, mesh, cfg, shape))
            rec["status"] = "ok"
            rec["lower_s"] = t1 - t0
            rec["compile_s"] = t2 - t1
            print(f"OK  {arch:24s} {shape_name:12s} {mesh_name:9s} "
                  f"lower {t1-t0:6.1f}s compile {t2-t1:6.1f}s "
                  f"dom={rec['roofline']['dominant']}", flush=True)
    except Exception as e:
        rec["error"] = traceback.format_exc()
        print(f"ERR {arch:24s} {shape_name:12s} {mesh_name:9s}: {e!r}",
              flush=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute analysis from cached .hlo.gz (no compile)")
    ap.add_argument("--opt", default="adamw")
    ap.add_argument("--recipe", default="default",
                    choices=["default", "tp_serve", "fsdp"])
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args(argv)

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    n_ok = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_one(arch, shape, mp, out_dir=args.out,
                              force=args.force, opt_name=args.opt,
                              reanalyze=args.reanalyze,
                              recipe=args.recipe)
                n_ok += rec.get("status") == "ok"
                n_err += rec.get("status") != "ok"
    print(f"done: {n_ok} ok, {n_err} errors")


if __name__ == "__main__":
    main()
