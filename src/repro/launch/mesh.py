"""Production meshes.

Single pod: v5e-256 as (data=16, model=16).
Multi-pod:  2 pods = 512 chips as (pod=2, data=16, model=16); the ``pod``
axis carries pure data parallelism across the DCN/ICI boundary.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module does not touch jax device state — only
launch/dryrun.py sets the 512-device XLA flag, before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    import os
    override = os.environ.get("REPRO_MESH_OVERRIDE")  # e.g. "2,4" / "2,2,2"
    if override:
        shape = tuple(int(x) for x in override.split(","))
        axes = ("pod", "data", "model")[-len(shape):]
        return jax.make_mesh(shape, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def activate_mesh(mesh):
    """Version-compat mesh activation for ``with`` blocks.

    ``jax.sharding.set_mesh`` (newest JAX) and ``jax.sharding.use_mesh``
    (0.5.x) install the mesh as the ambient sharding context; on older
    releases (<= 0.4.x) neither exists and ``Mesh`` itself is the
    context manager. All three enter/exit the same way, so the launch
    path asks for whichever this JAX provides.
    """
    import jax.sharding
    for name in ("set_mesh", "use_mesh"):
        fn = getattr(jax.sharding, name, None)
        if fn is not None:
            return fn(mesh)
    return mesh


def batch_axes(mesh) -> tuple:
    """Mesh axes a global-batch dimension shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


# TPU v5e hardware constants for the roofline model (per chip).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link
