"""FL training driver (the paper's experiment).

    PYTHONPATH=src python -m repro.launch.train --method both \
        --rounds 25 --out results/fl

Writes <out>_<method>.json (round-by-round history) and
<out>_<method>.ckpt (final params).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

from repro.checkpointing import save
from repro.configs import get_config, get_fl_config
from repro.core import run_federated
from repro.data import load_corpus
from repro.models import build


def history_to_json(result):
    return {
        "method": result.method,
        "summary": result.summary(),
        "history": [dataclasses.asdict(r) for r in result.history],
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="charlm-shakespeare")
    ap.add_argument("--method", default="both", choices=["cafl", "fedavg", "both"])
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--out", default="results/fl")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    ds = load_corpus()
    cfg = get_config(args.arch)
    if cfg.vocab_size < ds.vocab_size:
        cfg = cfg.replace(vocab_size=ds.vocab_size)
    fl = get_fl_config()
    if args.rounds:
        fl = fl.replace(rounds=args.rounds)
    if args.seed is not None:
        fl = fl.replace(seed=args.seed)
    model = build(cfg)
    os.makedirs(os.path.dirname(os.path.abspath(args.out)) or ".", exist_ok=True)

    methods = ["fedavg", "cafl"] if args.method == "both" else [args.method]
    log = (lambda *a, **k: None) if args.quiet else print
    for method in methods:
        result = run_federated(model, fl, ds, method=method, log=log)
        path = f"{args.out}_{method}.json"
        with open(path, "w") as f:
            json.dump(history_to_json(result), f, indent=1)
        save(f"{args.out}_{method}.ckpt", result.final_params)
        print(f"[{method}] saved {path}; summary:", result.summary())


if __name__ == "__main__":
    main()
