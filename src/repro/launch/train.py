"""FL training driver (the paper's experiment), on the composable engine.

    PYTHONPATH=src python -m repro.launch.train --method both \
        --rounds 25 --out results/fl

Writes <out>_<method>.json (round-by-round history) and
<out>_<method>.ckpt (final params) via engine callbacks.
"""
from __future__ import annotations

import argparse
import os

from repro.configs import get_config, get_fl_config
from repro.data import load_corpus
from repro.fl import (CheckpointCallback, FederatedEngine,
                      HistoryWriterCallback, LoggingCallback)
from repro.models import build


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="charlm-shakespeare")
    ap.add_argument("--method", default="both",
                    help='"cafl", "fedavg", "both", or any strategy name '
                         'the engine resolves (e.g. "fedadam", "cafl+adam")')
    ap.add_argument("--executor", default="sequential",
                    choices=["sequential", "batched"])
    ap.add_argument("--server-opt", default="",
                    help='server optimizer composed onto the method '
                         '("adam" = FedAdam, "momentum" = FedAvgM)')
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--out", default="results/fl")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    ds = load_corpus()
    cfg = get_config(args.arch)
    if cfg.vocab_size < ds.vocab_size:
        cfg = cfg.replace(vocab_size=ds.vocab_size)
    fl = get_fl_config().replace(executor=args.executor,
                                 server_opt=args.server_opt)
    if args.rounds:
        fl = fl.replace(rounds=args.rounds)
    if args.seed is not None:
        fl = fl.replace(seed=args.seed)
    model = build(cfg)
    os.makedirs(os.path.dirname(os.path.abspath(args.out)) or ".",
                exist_ok=True)

    methods = ["fedavg", "cafl"] if args.method == "both" else [args.method]
    for method in methods:
        path = f"{args.out}_{method}.json"
        callbacks = [HistoryWriterCallback(path),
                     CheckpointCallback(f"{args.out}_{method}.ckpt")]
        if not args.quiet:
            callbacks.append(LoggingCallback())
        engine = FederatedEngine(model, fl, ds, strategy=method,
                                 callbacks=callbacks)
        result = engine.run()
        print(f"[{method}] saved {path}; summary:", result.summary())


if __name__ == "__main__":
    main()
