"""Pure-jnp oracles for every Pallas kernel (the correctness reference)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# blockwise symmetric mid-rise quantization (the CAFL-L wire format)
# ---------------------------------------------------------------------------


def quantize_blocks_ref(x2d, bits: int):
    """x2d: (n_blocks, block) fp -> (codes int8, scales fp32).

    Mid-rise uniform quantizer: scale = absmax / L with L = 2^(bits-1);
    code = clip(floor(x / scale), -L, L-1); dequant = (code + 0.5) * scale.
    """
    L = 2 ** (bits - 1)
    absmax = jnp.max(jnp.abs(x2d.astype(jnp.float32)), axis=1, keepdims=True)
    scale = absmax / L
    safe = jnp.where(scale > 0, scale, 1.0)
    codes = jnp.clip(jnp.floor(x2d.astype(jnp.float32) / safe), -L, L - 1)
    return codes.astype(jnp.int8), scale[:, 0]


def dequantize_blocks_ref(codes, scales):
    return (codes.astype(jnp.float32) + 0.5) * scales[:, None]


def quantize_dequantize_ref(x, bits: int, block: int = 256):
    """Arbitrary-shape tensor -> wire round-trip, same shape/dtype."""
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    codes, scales = quantize_blocks_ref(blocks, bits)
    deq = dequantize_blocks_ref(codes, scales)
    # exact-zero blocks stay zero (scale==0)
    deq = jnp.where(scales[:, None] > 0, deq, 0.0)
    return deq.reshape(-1)[:n].reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention (causal, optional window + softcap), fp32 math
# ---------------------------------------------------------------------------


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None,
                        softcap=None, scale=None):
    """q: (B,Sq,H,D), k/v: (B,Sk,KVH,D) -> (B,Sq,H,D). Naive O(S^2) oracle."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, kvh, g, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bqkgd,blkd->bkgql", qg, kf) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgql,blkd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)
