"""Pure-jnp oracles for every Pallas kernel (the correctness reference)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# blockwise symmetric mid-tread quantization (the CAFL-L wire format)
# ---------------------------------------------------------------------------


def quantize_blocks_ref(x2d, bits: int):
    """x2d: (n_blocks, block) fp -> (codes int8, scales fp32).

    Mid-tread uniform quantizer: scale = absmax / (L-1) with
    L = 2^(bits-1); code = clip(rint(x / scale), -(L-1), L-1);
    dequant = code * scale. Zero-preserving: an exact-zero input maps
    to code 0 and dequantizes to exactly 0.0 — a mid-rise code would
    bias it to +0.5*scale, which destroys wire sparsity (every
    coordinate a top-k sparsifier zeroes out would come back nonzero).
    """
    L = 2 ** (bits - 1)
    absmax = jnp.max(jnp.abs(x2d.astype(jnp.float32)), axis=1, keepdims=True)
    # explicit fp32 reciprocal multiply: XLA may or may not fold a
    # constant division into one depending on context, and the 1-ulp
    # scale difference flips codes at half-integer boundaries — this
    # keeps ref and Pallas bit-identical
    scale = absmax * jnp.float32(1.0 / (L - 1))
    safe = jnp.where(scale > 0, scale, 1.0)
    codes = jnp.clip(jnp.rint(x2d.astype(jnp.float32) / safe), -(L - 1),
                     L - 1)
    return codes.astype(jnp.int8), scale[:, 0]


def dequantize_blocks_ref(codes, scales):
    # code 0 -> exactly 0.0; all-zero blocks (scale 0) stay zero for free
    return codes.astype(jnp.float32) * scales[:, None]


def topk_mask_ref(absx, k: int):
    """absx: (n_blocks, block) -> bool mask keeping exactly ``k`` per
    row, largest magnitudes first, ties broken toward the lower index.

    Branch- and sort-free: rank_i = #{j : a_j > a_i} + #{j < i : a_j ==
    a_i}; keep rank < k. O(block^2) comparisons, but every op is an
    elementwise compare / reduction the VPU vectorizes — the same
    expression runs inside the Pallas kernel, so the two paths agree
    bit-for-bit.
    """
    rows, block = absx.shape
    if k >= block:
        return jnp.ones((rows, block), bool)
    a_i = absx[:, :, None]                      # (rows, i, 1)
    a_j = absx[:, None, :]                      # (rows, 1, j)
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    ahead = (a_j > a_i) | ((a_j == a_i) & (j_idx < i_idx)[None])
    rank = jnp.sum(ahead.astype(jnp.int32), axis=2)
    return rank < k


def quantize_topk_blocks_ref(x2d, bits: int, k: int):
    """Fused quantize + per-block top-k sparsify:
    (n_blocks, block) fp -> (codes int8, scales f32, mask int8).

    The scale is the *dense* absmax (top-k keeps the largest-magnitude
    entry, so sparsifying never changes it); dropped coordinates get
    code 0, which the mid-tread dequantizer maps to exactly 0.0 — the
    sparse wire tuple needs no separate dequantize path.
    """
    x = x2d.astype(jnp.float32)
    codes, scales = quantize_blocks_ref(x, bits)
    keep = topk_mask_ref(jnp.abs(x), k)
    codes = jnp.where(keep, codes, jnp.int8(0))
    return codes.astype(jnp.int8), scales, keep.astype(jnp.int8)


def quantize_dequantize_ref(x, bits: int, block: int = 256, topk=None):
    """Arbitrary-shape tensor -> wire round-trip, same shape/dtype."""
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    if topk is not None and topk < block:
        codes, scales, _ = quantize_topk_blocks_ref(blocks, bits, topk)
    else:
        codes, scales = quantize_blocks_ref(blocks, bits)
    deq = dequantize_blocks_ref(codes, scales)
    return deq.reshape(-1)[:n].reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# fixed-point masked sum (secure-aggregation cohort fold)
# ---------------------------------------------------------------------------

#: Column sums of 16-bit digits stay exact in uint32 up to this many
#: clients per fold (sum <= C * 0xffff < 2^32).
MASKED_SUM_MAX_CLIENTS = 1 << 16


def masked_sum_ref(hi, lo):
    """(C, n) uint32 limb pairs -> ((n,), (n,)) summed mod 2^64.

    TPU (and jnp without x64) has no uint64, so the uint64 modular-mask
    algebra ``MaskedSumAggregator`` runs is carried as (hi, lo) uint32
    limb pairs, and the cohort fold uses radix-2^16 column reduction:
    split each limb into two 16-bit digits, column-sum every digit
    (exact in uint32 for C <= 2^16 clients), then ripple the carries.
    One bandwidth-bound pass over the stacked cohort instead of C
    sequential accumulations.
    """
    assert hi.shape == lo.shape and hi.shape[0] <= MASKED_SUM_MAX_CLIENTS
    hi = hi.astype(jnp.uint32)
    lo = lo.astype(jnp.uint32)
    mask16 = jnp.uint32(0xFFFF)
    s0 = jnp.sum(lo & mask16, axis=0, dtype=jnp.uint32)
    s1 = jnp.sum(lo >> 16, axis=0, dtype=jnp.uint32)
    s2 = jnp.sum(hi & mask16, axis=0, dtype=jnp.uint32)
    s3 = jnp.sum(hi >> 16, axis=0, dtype=jnp.uint32)
    d0 = s0 & mask16
    t1 = s1 + (s0 >> 16)
    d1 = t1 & mask16
    t2 = s2 + (t1 >> 16)
    d2 = t2 & mask16
    t3 = s3 + (t2 >> 16)          # carry past bit 64 drops: mod 2^64
    d3 = t3 & mask16
    return d2 | (d3 << 16), d0 | (d1 << 16)


# ---------------------------------------------------------------------------
# flash attention (causal, optional window + softcap), fp32 math
# ---------------------------------------------------------------------------


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None,
                        softcap=None, scale=None):
    """q: (B,Sq,H,D), k/v: (B,Sk,KVH,D) -> (B,Sq,H,D). Naive O(S^2) oracle."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, kvh, g, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bqkgd,blkd->bkgql", qg, kf) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgql,blkd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)
