"""Pallas-TPU blockwise quantization kernel — the CAFL-L communication
hot spot (every round quantizes the full update tree at q>0).

Wire format: 1-D blocks of ``block`` values; per-block fp32 absmax scale;
zero-preserving mid-tread codes (see kernels/ref.py — code 0 dequantizes
to exactly 0.0, which the top-k sparse wire format in kernels/wire.py
relies on). Tiling: ROWS_PER_TILE blocks x block
values per kernel invocation — (8, 256) fp32 = 8 KiB in VMEM, lane-dim
256 is a multiple of 128 so loads/stores are register-aligned; the
reduction (absmax) runs along the minor axis on the VPU.

Validated against ref.quantize_blocks_ref in interpret mode on CPU
(tests/test_kernels_quantize.py); on TPU the same kernel runs compiled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_PER_TILE = 8


def _quantize_kernel(x_ref, codes_ref, scales_ref, *, bits: int):
    x = x_ref[...].astype(jnp.float32)                    # (ROWS, block)
    L = 2 ** (bits - 1)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)   # (ROWS, 1)
    # reciprocal multiply, not division: bit-identical to the ref twin
    # (see ref.quantize_blocks_ref)
    scale = absmax * jnp.float32(1.0 / (L - 1))
    safe = jnp.where(scale > 0, scale, 1.0)
    # mid-tread: rint keeps exact zeros at code 0 (zero-preserving)
    codes = jnp.clip(jnp.rint(x / safe), -(L - 1), L - 1)
    codes_ref[...] = codes.astype(jnp.int8)
    scales_ref[...] = scale[:, 0]


def _dequantize_kernel(codes_ref, scales_ref, out_ref):
    codes = codes_ref[...].astype(jnp.float32)
    # code 0 -> exactly 0.0; all-zero blocks (scale 0) stay zero for free
    out_ref[...] = codes * scales_ref[...][:, None]


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def quantize_blocks(x2d, bits: int, interpret: bool = True):
    """x2d: (n_blocks, block) -> (codes int8, scales f32)."""
    n, block = x2d.shape
    assert n % ROWS_PER_TILE == 0, "pad n_blocks to ROWS_PER_TILE"
    grid = (n // ROWS_PER_TILE,)
    return pl.pallas_call(
        functools.partial(_quantize_kernel, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS_PER_TILE, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((ROWS_PER_TILE, block), lambda i: (i, 0)),
                   pl.BlockSpec((ROWS_PER_TILE,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n, block), jnp.int8),
                   jax.ShapeDtypeStruct((n,), jnp.float32)],
        interpret=interpret,
    )(x2d)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize_blocks(codes, scales, interpret: bool = True):
    n, block = codes.shape
    assert n % ROWS_PER_TILE == 0
    grid = (n // ROWS_PER_TILE,)
    return pl.pallas_call(
        _dequantize_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS_PER_TILE, block), lambda i: (i, 0)),
                  pl.BlockSpec((ROWS_PER_TILE,), lambda i: (i,))],
        out_specs=pl.BlockSpec((ROWS_PER_TILE, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, block), jnp.float32),
        interpret=interpret,
    )(codes, scales)
