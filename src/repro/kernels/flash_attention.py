"""Pallas-TPU flash attention (causal / sliding-window / softcap, GQA).

TPU adaptation of the standard flash pattern: the MXU consumes
(BLK_Q x D) x (D x BLK_K) tiles from VMEM; the online-softmax running
stats (m, l) and the output accumulator live in VMEM scratch and persist
across the minor-most grid axis (the kv-block axis), which TPU iterates
sequentially per (batch, head, q-block) — so no HBM traffic for the
accumulator. Causal skipping uses @pl.when: blocks strictly above the
diagonal do no work (they still occupy grid slots; the q-chunked exact
slicing used by the pure-JAX path in models/layers.py is the compile-time
alternative).

Layout: (B, H, S, D) — the ops.py wrapper transposes from the model's
(B, S, H, D).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLK_Q = 128
DEFAULT_BLK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, causal, window, softcap, blk_q, blk_k, n_k):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * blk_q
    k_start = ik * blk_k

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)               # (blk_q, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (blk_k, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
        mask = jnp.ones((blk_q, blk_k), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_ref[...] = m_new

    if causal:
        # skip blocks strictly above the diagonal (and, with a window,
        # blocks entirely below it): no MXU work, no stat updates.
        run = k_start <= q_start + blk_q - 1
        if window is not None:
            run &= k_start + blk_k - 1 > q_start - window
        pl.when(run)(_body)
    else:
        _body()

    @pl.when(ik == n_k - 1)
    def _final():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "scale", "blk_q", "blk_k",
                                             "interpret"))
def flash_attention_bhsd(q, k, v, *, causal=True, window=None, softcap=None,
                         scale=None, blk_q=DEFAULT_BLK_Q, blk_k=DEFAULT_BLK_K,
                         interpret=True):
    """q: (B,H,Sq,D); k,v: (B,KVH,Sk,D) -> (B,H,Sq,D)."""
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, sk)
    assert sq % blk_q == 0 and sk % blk_k == 0, "pad seq to block multiple"
    n_q, n_k = sq // blk_q, sk // blk_k
    grid = (b, h, n_q, n_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, blk_q=blk_q, blk_k=blk_k, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, blk_k, d),
                         lambda ib, ih, iq, ik, g=g: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, blk_k, d),
                         lambda ib, ih, iq, ik, g=g: (ib, ih // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, d),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, d), jnp.float32),   # output accumulator
            pltpu.VMEM((blk_q,), jnp.float32),     # running max m
            pltpu.VMEM((blk_q,), jnp.float32),     # running denom l
        ],
        interpret=interpret,
    )(q, k, v)
