"""Pallas-TPU wire-path kernels — the CAFL-L communication hot path
fused end to end: quantize -> per-block top-k sparsify -> fixed-point
masked sum -> dequantize.

Three kernels (each with a pure-jnp twin in ``kernels/ref.py`` and
backend dispatch in ``kernels/ops.py``):

``quantize_topk_blocks``
    Fused blockwise mid-tread quantization + exactly-k magnitude
    sparsification emitting the sparse wire tuple ``(codes int8,
    scales f32, mask int8)``. The scale is the dense absmax (top-k
    keeps the largest entry), dropped coordinates get code 0, and the
    zero-preserving mid-tread dequantizer maps code 0 to exactly 0.0 —
    so the dense dequantize epilogue serves the sparse format too.
    Selection is rank-by-pairwise-comparison (no sort, no scatter):
    O(block^2) compares, all VPU-friendly elementwise/reduction ops,
    identical expression to the reference so the paths agree
    bit-for-bit.

``masked_sum_limbs``
    The secure-aggregation cohort fold: sums C clients' uint64
    fixed-point masked vectors mod 2^64 in one bandwidth-bound pass.
    TPU has no 64-bit integers, so values arrive as (hi, lo) uint32
    limb pairs and the kernel does radix-2^16 column reduction —
    split each limb into two 16-bit digits, column-sum (exact in
    uint32 for C <= 2^16), ripple carries. Modular sums are
    associative, so the result is bit-exact vs the sequential NumPy
    oracle in ``MaskedSumAggregator``.

``dequantize_blocks`` (re-exported from ``kernels/quantize``)
    The dequantize epilogue: ``codes * scale`` per block. Shared by
    the dense and sparse formats because code 0 -> 0.0 exactly.

Validated against the twins in interpret mode on CPU
(tests/test_wire_kernels.py); on TPU the same kernels run compiled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quantize import ROWS_PER_TILE
from repro.kernels.quantize import dequantize_blocks  # noqa: F401  (epilogue)

#: Column tile of the masked-sum kernel: 512 uint32 lanes = 2 KiB per
#: limb row in VMEM, a multiple of the 128-lane register width.
LIMB_TILE = 512


# ---------------------------------------------------------------------------
# (a) fused quantize + per-block top-k sparsify
# ---------------------------------------------------------------------------


def _quantize_topk_kernel(x_ref, codes_ref, scales_ref, mask_ref, *,
                          bits: int, k: int):
    x = x_ref[...].astype(jnp.float32)                    # (ROWS, block)
    block = x.shape[1]
    L = 2 ** (bits - 1)
    absx = jnp.abs(x)
    absmax = jnp.max(absx, axis=1, keepdims=True)         # (ROWS, 1)
    # reciprocal multiply, not division: bit-identical to the ref twin
    scale = absmax * jnp.float32(1.0 / (L - 1))
    safe = jnp.where(scale > 0, scale, 1.0)
    codes = jnp.clip(jnp.rint(x / safe), -(L - 1), L - 1)
    # exactly-k selection by pairwise rank, ties -> lower index (same
    # expression as ref.topk_mask_ref: bit-identical across backends)
    a_i = absx[:, :, None]
    a_j = absx[:, None, :]
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    ahead = (a_j > a_i) | ((a_j == a_i) & (j_idx < i_idx)[None])
    rank = jnp.sum(ahead.astype(jnp.int32), axis=2)
    keep = rank < k
    codes_ref[...] = jnp.where(keep, codes, 0.0).astype(jnp.int8)
    scales_ref[...] = scale[:, 0]
    mask_ref[...] = keep.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bits", "k", "interpret"))
def quantize_topk_blocks(x2d, bits: int, k: int, interpret: bool = True):
    """x2d: (n_blocks, block) -> (codes int8, scales f32, mask int8)."""
    n, block = x2d.shape
    assert n % ROWS_PER_TILE == 0, "pad n_blocks to ROWS_PER_TILE"
    grid = (n // ROWS_PER_TILE,)
    return pl.pallas_call(
        functools.partial(_quantize_topk_kernel, bits=bits, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS_PER_TILE, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((ROWS_PER_TILE, block), lambda i: (i, 0)),
                   pl.BlockSpec((ROWS_PER_TILE,), lambda i: (i,)),
                   pl.BlockSpec((ROWS_PER_TILE, block), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, block), jnp.int8),
                   jax.ShapeDtypeStruct((n,), jnp.float32),
                   jax.ShapeDtypeStruct((n, block), jnp.int8)],
        interpret=interpret,
    )(x2d)


# ---------------------------------------------------------------------------
# (b) fixed-point masked sum over a stacked cohort
# ---------------------------------------------------------------------------


def _masked_sum_kernel(hi_ref, lo_ref, hi_out, lo_out):
    hi = hi_ref[...]                                      # (C, TILE) uint32
    lo = lo_ref[...]
    mask16 = jnp.uint32(0xFFFF)
    # radix-2^16 column reduction: 16-bit digit sums are exact in
    # uint32 for C <= 2^16 clients, then ripple the carries
    s0 = jnp.sum(lo & mask16, axis=0, dtype=jnp.uint32)
    s1 = jnp.sum(lo >> 16, axis=0, dtype=jnp.uint32)
    s2 = jnp.sum(hi & mask16, axis=0, dtype=jnp.uint32)
    s3 = jnp.sum(hi >> 16, axis=0, dtype=jnp.uint32)
    d0 = s0 & mask16
    t1 = s1 + (s0 >> 16)
    d1 = t1 & mask16
    t2 = s2 + (t1 >> 16)
    d2 = t2 & mask16
    t3 = s3 + (t2 >> 16)          # carry past bit 64 drops: mod 2^64
    d3 = t3 & mask16
    hi_out[...] = d2 | (d3 << 16)
    lo_out[...] = d0 | (d1 << 16)


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_sum_limbs(hi, lo, interpret: bool = True):
    """(C, n) uint32 limb pairs -> ((n,), (n,)) cohort sum mod 2^64."""
    c, n = hi.shape
    assert hi.shape == lo.shape
    assert n % LIMB_TILE == 0, "pad columns to LIMB_TILE"
    grid = (n // LIMB_TILE,)
    return pl.pallas_call(
        _masked_sum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((c, LIMB_TILE), lambda i: (0, i)),
                  pl.BlockSpec((c, LIMB_TILE), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((LIMB_TILE,), lambda i: (i,)),
                   pl.BlockSpec((LIMB_TILE,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.uint32),
                   jax.ShapeDtypeStruct((n,), jnp.uint32)],
        interpret=interpret,
    )(hi, lo)
