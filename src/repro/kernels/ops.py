"""jit'd public wrappers around the Pallas kernels, with backend dispatch.

On TPU the compiled kernels run; on CPU (this container) the same kernel
bodies execute in interpret mode for validation, and the hot paths used
inside the FL simulation loop fall back to the pure-jnp reference (which
XLA fuses well on CPU). ``FORCE_BACKEND`` lets tests pin either path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import quantize as qk
from repro.kernels import flash_attention as fak

FORCE_BACKEND: Optional[str] = None   # None | "pallas" | "ref"


def _use_pallas() -> bool:
    if FORCE_BACKEND == "pallas":
        return True
    if FORCE_BACKEND == "ref":
        return False
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# quantization
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("bits", "block"))
def _qdq_ref(x, bits: int, block: int):
    return ref.quantize_dequantize_ref(x, bits, block)


def quantize_dequantize(x, *, bits: int, block: int = 256):
    """Wire round-trip (quantize then dequantize), any shape."""
    if not _use_pallas():
        return _qdq_ref(x, bits, block)
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % (block * qk.ROWS_PER_TILE)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    interp = jax.default_backend() != "tpu"
    codes, scales = qk.quantize_blocks(blocks, bits, interpret=interp)
    deq = qk.dequantize_blocks(codes, scales, interpret=interp)
    return deq.reshape(-1)[:n].reshape(shape).astype(dtype)


def quantize_wire(x, *, bits: int, block: int = 256):
    """-> (codes int8 (n_blocks, block), scales f32 (n_blocks,), n_valid)."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % (block * qk.ROWS_PER_TILE)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    if _use_pallas():
        interp = jax.default_backend() != "tpu"
        codes, scales = qk.quantize_blocks(blocks, bits, interpret=interp)
    else:
        codes, scales = ref.quantize_blocks_ref(blocks, bits)
    return codes, scales, n


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None):
    """Model layout (B, S, H, D); dispatches Pallas (TPU) vs reference."""
    if not _use_pallas():
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       softcap=softcap, scale=scale)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    interp = jax.default_backend() != "tpu"
    out = fak.flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                                   softcap=softcap, scale=scale,
                                   interpret=interp)
    return out.transpose(0, 2, 1, 3)
