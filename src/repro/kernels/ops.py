"""jit'd public wrappers around the Pallas kernels, with backend dispatch.

On TPU the compiled kernels run; on CPU (this container) the same kernel
bodies execute in interpret mode for validation, and the hot paths used
inside the FL simulation loop fall back to the pure-jnp reference (which
XLA fuses well on CPU). ``FORCE_BACKEND`` lets tests pin either path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels import quantize as qk
from repro.kernels import wire as wk
from repro.kernels import flash_attention as fak

FORCE_BACKEND: Optional[str] = None   # None | "pallas" | "ref"


def _use_pallas() -> bool:
    if FORCE_BACKEND == "pallas":
        return True
    if FORCE_BACKEND == "ref":
        return False
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# quantization
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("bits", "block", "topk"))
def _qdq_ref(x, bits: int, block: int, topk):
    return ref.quantize_dequantize_ref(x, bits, block, topk=topk)


def quantize_dequantize(x, *, bits: int, block: int = 256,
                        topk: Optional[int] = None):
    """Wire round-trip (quantize then dequantize), any shape.

    ``topk`` keeps only the k largest-magnitude codes per block (the
    sparse wire format); dropped coordinates round-trip to exactly 0.0.
    """
    if not _use_pallas():
        return _qdq_ref(x, bits, block, topk)
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % (block * qk.ROWS_PER_TILE)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    interp = jax.default_backend() != "tpu"
    if topk is not None and topk < block:
        codes, scales, _ = wk.quantize_topk_blocks(blocks, bits, topk,
                                                   interpret=interp)
    else:
        codes, scales = qk.quantize_blocks(blocks, bits, interpret=interp)
    deq = qk.dequantize_blocks(codes, scales, interpret=interp)
    return deq.reshape(-1)[:n].reshape(shape).astype(dtype)


_dequantize_blocks_ref_jit = jax.jit(ref.dequantize_blocks_ref)


def dequantize_blocks(codes, scales):
    """Decode wire blocks: (n_blocks, block) int8 codes x per-block f32
    scales -> (n_blocks, block) f32 (code 0 -> exactly 0.0).

    The server-side half of the wire round-trip, dispatched like every
    other kernel: the Pallas ``quantize.dequantize_blocks`` kernel on
    TPU, the pure-jnp ``dequantize_blocks_ref`` twin elsewhere.
    """
    if not _use_pallas():
        return _dequantize_blocks_ref_jit(codes, scales)
    interp = jax.default_backend() != "tpu"
    return qk.dequantize_blocks(codes, scales, interpret=interp)


@functools.partial(jax.jit, static_argnames=("bits", "topk"))
def _quantize_wire_ref(blocks, bits: int, topk):
    if topk is not None:
        return ref.quantize_topk_blocks_ref(blocks, bits, topk)
    codes, scales = ref.quantize_blocks_ref(blocks, bits)
    return codes, scales, None


def quantize_wire(x, *, bits: int, block: int = 256,
                  topk: Optional[int] = None):
    """Quantize a tensor into the wire tuple actually shipped.

    -> ``(codes int8 (n_blocks, block), scales f32 (n_blocks,),
    mask int8 (n_blocks, block) | None, n_valid)`` with exactly
    ``n_blocks = ceil(n / block)`` on every backend: the Pallas path
    pads to ``block * ROWS_PER_TILE`` tiles internally but the pad
    blocks are stripped before return, so ``core.compression.wire_bytes``
    and the tuple's nbytes agree. ``mask`` is None for the dense format.
    """
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    n_blocks = -(-n // block) if n else 0
    if n == 0:
        return (jnp.zeros((0, block), jnp.int8), jnp.zeros((0,), jnp.float32),
                None if topk is None or topk >= block else
                jnp.zeros((0, block), jnp.int8), 0)
    if topk is not None and topk >= block:
        topk = None
    if _use_pallas():
        pad = (-n) % (block * qk.ROWS_PER_TILE)
        if pad:
            flat = jnp.pad(flat, (0, pad))
        blocks = flat.reshape(-1, block)
        interp = jax.default_backend() != "tpu"
        if topk is not None:
            codes, scales, mask = wk.quantize_topk_blocks(blocks, bits, topk,
                                                          interpret=interp)
            return (codes[:n_blocks], scales[:n_blocks], mask[:n_blocks], n)
        codes, scales = qk.quantize_blocks(blocks, bits, interpret=interp)
        return codes[:n_blocks], scales[:n_blocks], None, n
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    codes, scales, mask = _quantize_wire_ref(blocks, bits, topk)
    return codes, scales, mask, n


# ---------------------------------------------------------------------------
# fixed-point masked sum (secure-aggregation cohort fold)
# ---------------------------------------------------------------------------

MASKED_SUM_MAX_CLIENTS = ref.MASKED_SUM_MAX_CLIENTS


def split_limbs(u64: np.ndarray):
    """NumPy uint64 (C, n) -> ((C, n) hi, (C, n) lo) uint32 limb pairs."""
    u64 = np.ascontiguousarray(u64, dtype=np.uint64)
    return ((u64 >> np.uint64(32)).astype(np.uint32),
            (u64 & np.uint64(0xFFFFFFFF)).astype(np.uint32))


def merge_limbs(hi: np.ndarray, lo: np.ndarray) -> np.ndarray:
    """(hi, lo) uint32 -> NumPy uint64, elementwise."""
    return ((np.asarray(hi, dtype=np.uint64) << np.uint64(32))
            | np.asarray(lo, dtype=np.uint64))


_masked_sum_ref_jit = jax.jit(ref.masked_sum_ref)


def masked_sum(hi, lo):
    """Sum C clients' uint64 vectors mod 2^64, carried as uint32 limbs.

    hi/lo: (C, n) uint32 -> ((n,) hi, (n,) lo) uint32. Bit-exact on
    every backend (modular sums are associative); the Pallas kernel
    does it in one bandwidth-bound pass over the stacked cohort.
    """
    hi = jnp.asarray(hi, dtype=jnp.uint32)
    lo = jnp.asarray(lo, dtype=jnp.uint32)
    c, n = hi.shape
    if c > MASKED_SUM_MAX_CLIENTS:
        raise ValueError(
            f"masked_sum supports at most {MASKED_SUM_MAX_CLIENTS} clients "
            f"per fold, got {c}")
    if not _use_pallas():
        return _masked_sum_ref_jit(hi, lo)
    pad = (-n) % wk.LIMB_TILE
    if pad:
        hi = jnp.pad(hi, ((0, 0), (0, pad)))
        lo = jnp.pad(lo, ((0, 0), (0, pad)))
    interp = jax.default_backend() != "tpu"
    hi_s, lo_s = wk.masked_sum_limbs(hi, lo, interpret=interp)
    return hi_s[:n], lo_s[:n]


def masked_sum_u64(vals: np.ndarray) -> np.ndarray:
    """Host-level cohort fold: (C, n) uint64 -> (n,) sum mod 2^64.

    The ``MaskedSumAggregator`` flush path. One fused pass over the
    stacked cohort on every backend: the Pallas limb kernel on TPU,
    a single NumPy ``add.reduce`` (uint64 wraps mod 2^64 natively) on
    CPU where 32-bit limb emulation can't win. ``FORCE_BACKEND``
    pins the limb paths for bit-compat validation.
    """
    vals = np.ascontiguousarray(vals, dtype=np.uint64)
    c = vals.shape[0]
    if c > MASKED_SUM_MAX_CLIENTS:
        raise ValueError(
            f"masked_sum supports at most {MASKED_SUM_MAX_CLIENTS} clients "
            f"per fold, got {c}")
    if FORCE_BACKEND is None and jax.default_backend() != "tpu":
        return np.add.reduce(vals, axis=0)
    hi, lo = split_limbs(vals)
    hi_s, lo_s = masked_sum(hi, lo)
    return merge_limbs(np.asarray(hi_s), np.asarray(lo_s))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None):
    """Model layout (B, S, H, D); dispatches Pallas (TPU) vs reference."""
    if not _use_pallas():
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       softcap=softcap, scale=scale)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    interp = jax.default_backend() != "tpu"
    out = fak.flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                                   softcap=softcap, scale=scale,
                                   interpret=interp)
    return out.transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# trace-analysis entry points (repro.analysis.trace)
# ---------------------------------------------------------------------------


def _wire_build(bits: int, topk: Optional[int]):
    def build():
        x = jax.ShapeDtypeStruct((1 << 16,), jnp.float32)

        def fn(t):
            return quantize_wire(t, bits=bits, topk=topk)

        return fn, (x,)
    return build


def _masked_sum_build():
    hi = jax.ShapeDtypeStruct((8, 4096), jnp.uint32)
    lo = jax.ShapeDtypeStruct((8, 4096), jnp.uint32)
    return masked_sum, (hi, lo)


def trace_entry_points() -> list:
    """Declared traceable surfaces: the wire pipeline at both formats
    plus the secure-aggregation cohort fold (all pure uint32/f32 —
    TRACE001 proves no 64-bit promotion sneaks onto the wire path)."""
    from repro.analysis.trace.registry import EntryPoint
    path = "src/repro/kernels/ops.py"
    return [
        EntryPoint(name="kernels.wire_dense", path=path, line=94,
                   build=_wire_build(8, None),
                   note="dense int8 wire tuple, 64k params"),
        EntryPoint(name="kernels.wire_topk", path=path, line=94,
                   build=_wire_build(2, 64),
                   note="2-bit top-64 sparse wire tuple, 64k params"),
        EntryPoint(name="kernels.masked_sum", path=path, line=157,
                   build=_masked_sum_build,
                   note="uint64-as-limbs cohort fold, C=8, n=4096"),
    ]
