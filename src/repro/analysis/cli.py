"""``python -m repro.analysis`` — run the rule engine from the shell.

Exit codes: 0 clean (or everything suppressed by the baseline),
1 new findings (or stale baseline entries), 2 usage error. CI runs
``python -m repro.analysis --baseline ANALYSIS_BASELINE.json`` and
fails on any finding the committed baseline doesn't already own.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, List, Optional, Sequence, Tuple

from repro.analysis.baseline import DEFAULT_BASELINE, Baseline
from repro.analysis.engine import (DEFAULT_CODE_PATHS, Analyzer,
                                   default_rules)
from repro.analysis.findings import assign_occurrences

EXIT_CLEAN = 0
EXIT_FINDINGS = 1
EXIT_USAGE = 2


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX-aware static analysis for the repro tree.")
    p.add_argument("paths", nargs="*", default=None,
                   help=f"files/directories to scan (default: "
                        f"{', '.join(DEFAULT_CODE_PATHS)})")
    p.add_argument("--root", default=".",
                   help="repo root the paths are relative to")
    p.add_argument("--baseline", default=None, metavar="FILE",
                   help=f"suppression baseline to diff against "
                        f"(e.g. {DEFAULT_BASELINE})")
    p.add_argument("--update-baseline", action="store_true",
                   help="rewrite --baseline with the current findings "
                        "and exit 0")
    p.add_argument("--rules", default=None,
                   help="comma-separated rule ids to run (default: all)")
    p.add_argument("--list-rules", action="store_true",
                   help="print rule metadata and exit")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="machine-readable JSON on stdout")
    p.add_argument("--trace", action="store_true",
                   help="also trace the registered entry points to "
                        "jaxprs, run the TRACE rules and the static "
                        "memory gate, and diff TRACE_BUDGETS.json "
                        "(--update-baseline re-records the table)")
    p.add_argument("--sched", action="store_true",
                   help="also run the schedule-determinism sanitizer: "
                        "replay the sched scenarios under adversarial "
                        "legal event permutations, check the happens-"
                        "before graph for uncertified races (SCHED005) "
                        "and fail on any permutation mismatch")
    return p


def _select_rules(spec: Optional[str]
                  ) -> Tuple[Optional[List[Any]], Optional[str]]:
    rules = default_rules()
    if spec is None:
        return rules, None
    wanted = [s.strip() for s in spec.split(",") if s.strip()]
    known = {r.id for r in rules}
    unknown = [w for w in wanted if w not in known]
    if unknown:
        return None, (f"unknown rule(s) {', '.join(unknown)}; "
                      f"available: {', '.join(sorted(known))}")
    return [r for r in rules if r.id in wanted], None


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    rules, err = _select_rules(args.rules)
    if err:
        print(err, file=sys.stderr)
        return EXIT_USAGE

    if args.list_rules:
        for r in rules:
            print(f"{r.id}  {r.title}")
            print(f"    why:  {r.rationale}")
            print(f"    fix:  {r.hint}")
        return EXIT_CLEAN

    paths = args.paths if args.paths else None
    kwargs = {"rules": rules}
    if paths:
        kwargs["code_paths"] = paths
    result = Analyzer(args.root, **kwargs).run()
    findings = list(result.findings)
    rules_run = list(result.rules_run)

    trace_report = None
    if args.trace:
        # lazy: tracing imports jax and the model stack
        from repro.analysis.trace import run_trace
        trace_report = run_trace(args.root,
                                 update=args.update_baseline)
        findings = assign_occurrences(findings + trace_report.findings)
        rules_run += trace_report.rules_run

    sched_report = None
    if args.sched:
        # lazy: the sanitizer scenarios run the engine (jax + model)
        from repro.analysis.sched import run_sched
        sched_report = run_sched(args.root, update=args.update_baseline)
        findings = assign_occurrences(findings + sched_report.findings)
        rules_run += sched_report.rules_run

    baseline_path = args.baseline
    if baseline_path is None and os.path.exists(
            os.path.join(args.root, DEFAULT_BASELINE)) and not paths:
        baseline_path = os.path.join(args.root, DEFAULT_BASELINE)

    if args.update_baseline:
        if baseline_path is None:
            if paths:
                print("--update-baseline needs --baseline FILE when "
                      "scanning explicit paths", file=sys.stderr)
                return EXIT_USAGE
            baseline_path = os.path.join(args.root, DEFAULT_BASELINE)
        Baseline.from_findings(findings).save(baseline_path)
        print(f"baseline written: {baseline_path} "
              f"({len(findings)} findings)")
        if trace_report is not None:
            from repro.analysis.trace import DEFAULT_TRACE_TABLE
            print(f"trace table written: "
                  f"{os.path.join(args.root, DEFAULT_TRACE_TABLE)} "
                  f"({len(trace_report.traced)} entries)")
        return EXIT_CLEAN

    if baseline_path is not None:
        base = Baseline.load(baseline_path)
        new, suppressed, stale = base.diff(findings)
    else:
        new, suppressed, stale = list(findings), [], []
    problems = list(trace_report.problems) if trace_report else []
    if sched_report is not None:
        problems += list(sched_report.problems)

    if args.as_json:
        payload = {
            "files_scanned": result.files_scanned,
            "rules": rules_run,
            "new": [f.to_json() for f in new],
            "suppressed": [f.to_json() for f in suppressed],
            "stale_baseline": stale,
        }
        if trace_report is not None:
            payload["trace"] = {
                "entries": trace_report.rows_json(),
                "gate": [r.to_json() for r in trace_report.gate],
                "problems": list(trace_report.problems),
            }
        if sched_report is not None:
            payload["sched"] = {
                "scenarios": sched_report.rows_json(),
                "problems": list(sched_report.problems),
            }
        print(json.dumps(payload, indent=2))
    else:
        for f in new:
            print(f.format())
        for e in stale:
            print(f"{e['path']}:{e['line']}: STALE baseline entry for "
                  f"{e['rule']} (finding no longer exists; run "
                  f"--update-baseline to drop it)")
        if trace_report is not None:
            from repro.analysis.trace import format_report
            print()
            print(format_report(trace_report))
            for pr in trace_report.problems:
                print(f"TRACE PROBLEM: {pr}")
        if sched_report is not None:
            from repro.analysis.sched import format_sched_report
            print()
            print(format_sched_report(sched_report))
            for pr in sched_report.problems:
                print(f"SCHED PROBLEM: {pr}")
        print(f"\n{result.files_scanned} files, "
              f"{len(rules_run)} rules: "
              f"{len(new)} new finding(s), {len(suppressed)} suppressed "
              f"by baseline, {len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'}"
              + (f", {len(problems)} runtime problem(s)"
                 if trace_report is not None or sched_report is not None
                 else ""))

    return EXIT_FINDINGS if (new or stale or problems) else EXIT_CLEAN
