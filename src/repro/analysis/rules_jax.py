"""JAX discipline rules: PRNG keys, jit static args, import-time device
work, and Python-loop hot paths.

These encode the invariants the federated stack leans on: client draws
must be stream-deterministic (key reuse silently correlates clients),
jit caches must stay warm (array-valued static args recompile every
call), importing a module must not touch the device (breaks
``jax.config`` ordering and multiprocess launch), and the engine's
per-client control plane must stay visibly loop-free as the ROADMAP's
million-client vectorization lands.
"""
from __future__ import annotations

import ast
import copy
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.engine import (ModuleRule, ParsedModule, call_name,
                                   dotted_name, is_main_guard,
                                   is_type_checking_guard, register_rule)

# names (as dotted paths) that produce / transform PRNG keys
_KEY_MAKERS = ("jax.random.PRNGKey", "jax.random.key",
               "jax.random.fold_in", "jax.random.wrap_key_data")
_SPLIT = ("jax.random.split",)
_WEAK_KEY_PARAM = re.compile(r"^(rng|key|prng_?key)s?$|(_rng|_key)s?$")


@dataclass
class _KeyState:
    """Per-key bookkeeping inside one scope."""

    consumed: int = 0
    split_line: Optional[int] = None
    first_use_line: Optional[int] = None
    loop_depth_defined: int = 0
    weak: bool = False            # parameter-derived: only flag use-after-split
    # constant-subscript slots of a split() key array
    slots: Dict[object, "_KeyState"] = field(default_factory=dict)
    is_array: bool = False        # result of split(k, n): consumed via [i]


class _ScopeWalker:
    """Straight-line walk of one function (or module) body, tracking
    which names hold PRNG keys and where they are consumed."""

    def __init__(self, rule: "PRNGKeyReuse", mod: ParsedModule):
        self.rule = rule
        self.mod = mod
        self.findings: List = []
        self.keys: Dict[str, _KeyState] = {}
        self.loop_depth = 0

    # -- helpers -----------------------------------------------------------

    def _fresh(self, weak: bool = False, is_array: bool = False) -> _KeyState:
        return _KeyState(loop_depth_defined=self.loop_depth, weak=weak,
                         is_array=is_array)

    def _consume(self, name: str, state: _KeyState, node: ast.AST,
                 via_split: bool, carry: bool = False) -> None:
        line = getattr(node, "lineno", 0)
        if state.split_line is not None:
            self.findings.append(self.rule.make_finding(
                self.mod, node,
                f"PRNG key '{name}' used after jax.random.split "
                f"(split at line {state.split_line}); the parent key is "
                f"spent once split"))
        elif not state.weak and state.consumed >= 1:
            self.findings.append(self.rule.make_finding(
                self.mod, node,
                f"PRNG key '{name}' consumed twice (first use at line "
                f"{state.first_use_line}); two consumers of one key draw "
                f"correlated randomness"))
        elif (not carry and not state.weak
              and self.loop_depth > state.loop_depth_defined):
            self.findings.append(self.rule.make_finding(
                self.mod, node,
                f"PRNG key '{name}' consumed inside a loop but created "
                f"outside it; every iteration draws the same stream",
                hint="split or fold_in the key per iteration"))
        state.consumed += 1
        if state.first_use_line is None:
            state.first_use_line = line
        if via_split:
            state.split_line = line

    def _key_state_for_arg(self, arg: ast.AST
                           ) -> Optional[Tuple[str, _KeyState]]:
        """The tracked key a call argument refers to, if any."""
        if isinstance(arg, ast.Name) and arg.id in self.keys:
            st = self.keys[arg.id]
            return (arg.id, st) if not st.is_array else None
        if (isinstance(arg, ast.Subscript)
                and isinstance(arg.value, ast.Name)
                and arg.value.id in self.keys):
            parent = self.keys[arg.value.id]
            if not parent.is_array:
                return None
            idx = arg.slice
            if isinstance(idx, ast.Constant):
                slot = parent.slots.setdefault(idx.value, self._fresh())
                return (f"{arg.value.id}[{idx.value!r}]", slot)
        return None

    def _value_makes_key(self, value: ast.AST) -> Optional[str]:
        """'key' | 'array' when the RHS produces a key / key array."""
        if not isinstance(value, ast.Call):
            if (isinstance(value, ast.Subscript)
                    and isinstance(value.value, ast.Name)
                    and value.value.id in self.keys
                    and self.keys[value.value.id].is_array):
                return "key"
            return None
        name = call_name(value)
        if name in _KEY_MAKERS:
            return "key"
        if name in _SPLIT:
            return "array"
        return None

    # -- the walk ----------------------------------------------------------

    def walk(self, body: List[ast.stmt]) -> None:
        for stmt in body:
            self.visit_stmt(stmt)

    def visit_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return              # nested scopes walked separately
        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
            self.visit_expr_children(stmt.iter if hasattr(stmt, "iter")
                                     else stmt.test)
            self.loop_depth += 1
            self.walk(stmt.body)
            self.loop_depth -= 1
            self.walk(stmt.orelse)
            return
        if isinstance(stmt, ast.If):
            self.visit_expr_children(stmt.test)
            # branches are alternatives: consumptions in one must not
            # count against the other, so walk each from a snapshot and
            # keep the heavier outcome per key
            before = copy.deepcopy(self.keys)
            self.walk(stmt.body)
            after_body = self.keys
            self.keys = copy.deepcopy(before)
            self.walk(stmt.orelse)
            for name, st in after_body.items():
                cur = self.keys.get(name)
                if cur is None or st.consumed > cur.consumed:
                    self.keys[name] = st
            return
        if isinstance(stmt, ast.Try):
            self.walk(stmt.body)
            for h in stmt.handlers:
                self.walk(h.body)
            self.walk(stmt.orelse)
            self.walk(stmt.finalbody)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self.visit_expr_children(item.context_expr)
            self.walk(stmt.body)
            return
        if isinstance(stmt, ast.Assign):
            # the carry pattern `key, sub = jax.random.split(key)` (or
            # `key = fold_in(key, i)`) rebinds the spent key in the same
            # statement — legal every loop iteration
            kind = self._value_makes_key(stmt.value)
            carry_names = (self._rebound_names(stmt.targets)
                           if kind is not None else set())
            self.visit_expr_children(stmt.value, carry_names=carry_names)
            for tgt in stmt.targets:
                self._bind(tgt, kind, stmt.value)
            return
        if isinstance(stmt, ast.AugAssign):
            self.visit_expr_children(stmt.value)
            return
        if isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self.visit_expr_children(stmt.value)
            self._bind(stmt.target, self._value_makes_key(stmt.value),
                       stmt.value)
            return
        self.visit_expr_children(stmt)

    def _bind(self, target: ast.AST, kind: Optional[str],
              value: ast.AST) -> None:
        if isinstance(target, (ast.Tuple, ast.List)):
            # a, b = jax.random.split(k) unpacks to fresh keys
            if kind == "array":
                for el in target.elts:
                    if isinstance(el, ast.Name):
                        self.keys[el.id] = self._fresh()
            else:
                for el in target.elts:
                    self._bind(el, None, value)
            return
        if not isinstance(target, ast.Name):
            return
        if kind == "key":
            self.keys[target.id] = self._fresh()
        elif kind == "array":
            self.keys[target.id] = self._fresh(is_array=True)
        elif target.id in self.keys:
            del self.keys[target.id]   # reassigned to a non-key

    @staticmethod
    def _rebound_names(targets: List[ast.AST]) -> Set[str]:
        out: Set[str] = set()
        for tgt in targets:
            if isinstance(tgt, ast.Name):
                out.add(tgt.id)
            elif isinstance(tgt, (ast.Tuple, ast.List)):
                out |= {el.id for el in tgt.elts
                        if isinstance(el, ast.Name)}
        return out

    def visit_expr_children(self, node: Optional[ast.AST],
                            carry_names: Set[str] = frozenset()) -> None:
        if node is None:
            return
        if isinstance(node, ast.IfExp):
            # ternary branches are alternatives, like If statements
            self.visit_expr_children(node.test, carry_names)
            before = copy.deepcopy(self.keys)
            self.visit_expr_children(node.body, carry_names)
            after_body = self.keys
            self.keys = before
            self.visit_expr_children(node.orelse, carry_names)
            for name, st in after_body.items():
                cur = self.keys.get(name)
                if cur is None or st.consumed > cur.consumed:
                    self.keys[name] = st
            return
        if isinstance(node, ast.Call):
            via_split = call_name(node) in _SPLIT
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                ref = self._key_state_for_arg(arg)
                if ref is not None:
                    self._consume(ref[0], ref[1], node, via_split,
                                  carry=ref[0] in carry_names)
                else:
                    self.visit_expr_children(arg, carry_names)
            self.visit_expr_children(node.func, carry_names)
            return
        for child in ast.iter_child_nodes(node):
            self.visit_expr_children(child, carry_names)


@register_rule
class PRNGKeyReuse(ModuleRule):
    """JAX001 — a PRNG key consumed twice, after a split, or in a loop."""

    id = "JAX001"
    title = "PRNG key reuse"
    rationale = ("Client shards and model init draw from explicit keys; "
                 "reusing a key (or its parent after a split) makes two "
                 "draws identical, silently correlating clients.")
    hint = ("split the key (`k1, k2 = jax.random.split(key)`) or fold in "
            "a counter (`jax.random.fold_in(key, i)`) per consumer")

    def check_module(self, mod: ParsedModule) -> List:
        findings: List = []
        scopes: List[Tuple[List[ast.stmt], List[ast.arg]]] = [
            (mod.tree.body, [])]
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                args = (node.args.posonlyargs + node.args.args
                        + node.args.kwonlyargs)
                scopes.append((node.body, args))
        for body, params in scopes:
            w = _ScopeWalker(self, mod)
            for p in params:
                if _WEAK_KEY_PARAM.search(p.arg):
                    w.keys[p.arg] = _KeyState(weak=True)
            w.walk(body)
            findings.extend(w.findings)
        return findings


# ---------------------------------------------------------------------------
# JAX002 — array-valued / unhashable static jit arguments
# ---------------------------------------------------------------------------

_JIT_NAMES = ("jax.jit", "pjit", "jax.pjit")


def _jit_call_static_info(call: ast.Call) -> Optional[Tuple[Set[int],
                                                            Set[str]]]:
    """(static positions, static names) declared by a jax.jit(...) or
    functools.partial(jax.jit, ...) call; None when not a jit call."""
    name = call_name(call)
    inner = call
    if name in ("functools.partial", "partial"):
        if not (call.args and isinstance(call.args[0], (ast.Name,
                                                        ast.Attribute))
                and dotted_name(call.args[0]) in _JIT_NAMES):
            return None
    elif name not in _JIT_NAMES:
        return None
    nums: Set[int] = set()
    names: Set[str] = set()
    for kw in inner.keywords:
        if kw.arg == "static_argnums":
            for c in ast.walk(kw.value):
                if isinstance(c, ast.Constant) and isinstance(c.value, int):
                    nums.add(c.value)
        elif kw.arg == "static_argnames":
            for c in ast.walk(kw.value):
                if isinstance(c, ast.Constant) and isinstance(c.value, str):
                    names.add(c.value)
    return nums, names


_ARRAYISH_CALLS = re.compile(
    r"^(jnp|jax\.numpy)\.|^np\.(array|asarray|arange|ones|zeros)$"
    r"|^jax\.(device_put|random\.)")


def _is_unhashable_or_array(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        return bool(_ARRAYISH_CALLS.search(call_name(node)))
    return False


@register_rule
class StaticArgAbuse(ModuleRule):
    """JAX002 — unhashable / array-valued values for static jit args."""

    id = "JAX002"
    title = "non-hashable or array-valued static jit argument"
    rationale = ("A static_argnums argument is hashed into the jit cache "
                 "key: arrays raise, lists/dicts raise, and a fresh value "
                 "per call recompiles every round.")
    hint = ("pass arrays as traced (non-static) arguments; keep static "
            "args hashable scalars/tuples")

    def check_module(self, mod: ParsedModule) -> List:
        findings: List = []
        # map: local callable name -> (static positions, static names)
        jitted: Dict[str, Tuple[Set[int], Set[str]]] = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value,
                                                           ast.Call):
                info = _jit_call_static_info(node.value)
                if info is not None and (info[0] or info[1]):
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            jitted[tgt.id] = info
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if isinstance(dec, ast.Call):
                        info = _jit_call_static_info(dec)
                        if info is not None and (info[0] or info[1]):
                            # positions shift by bound args? plain defs only
                            jitted[node.name] = info
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            # direct decl check: static_argnums values must be ints
            info = _jit_call_static_info(node)
            if info is not None:
                for kw in node.keywords:
                    if kw.arg == "static_argnums" and _is_unhashable_or_array(
                            kw.value) and not isinstance(
                            kw.value, (ast.List, ast.Tuple)):
                        findings.append(self.make_finding(
                            mod, node,
                            "static_argnums must be ints or an int "
                            "sequence"))
            # call-site check against locally declared static positions
            name = call_name(node)
            if name in jitted:
                nums, names = jitted[name]
                for i, arg in enumerate(node.args):
                    if i in nums and _is_unhashable_or_array(arg):
                        findings.append(self.make_finding(
                            mod, node,
                            f"argument {i} of '{name}' is declared static "
                            f"but receives an array/unhashable value"))
                for kw in node.keywords:
                    if kw.arg in names and _is_unhashable_or_array(kw.value):
                        findings.append(self.make_finding(
                            mod, node,
                            f"argument '{kw.arg}' of '{name}' is declared "
                            f"static but receives an array/unhashable "
                            f"value"))
        return findings


# ---------------------------------------------------------------------------
# JAX003 — device computation at import time
# ---------------------------------------------------------------------------

_DEVICE_CALL = re.compile(
    r"^(jnp|jax\.numpy)\.|^jax\.random\.|^jax\.device_put$|^jax\.make_array")
#: wrappers that *define* computation without running it — allowed at
#: import time (jit/vmap/grad return functions; pallas_call builds one)
_DEFINING = re.compile(
    r"^jax\.(jit|vmap|pmap|grad|value_and_grad|checkpoint|custom_vjp|"
    r"custom_jvp)$|^functools\.partial$|^partial$|pallas_call")


@register_rule
class ImportTimeDeviceWork(ModuleRule):
    """JAX003 — jnp/device computation executed at module import."""

    id = "JAX003"
    title = "device computation at import time"
    rationale = ("Import-time jnp work initializes the backend before "
                 "jax.config / JAX_PLATFORMS can take effect, breaks "
                 "subprocess launch, and hides compile cost in import.")
    hint = ("move the computation into a function or lazy cache; module "
            "scope may only *define* jitted callables, not run them")

    def _flag_calls(self, mod: ParsedModule, node: ast.AST,
                    findings: List) -> None:
        # manual walk so Lambda bodies are skipped: a lambda at module
        # scope only *defines* computation, it doesn't run it
        stack = [node]
        while stack:
            n = stack.pop()
            if isinstance(n, ast.Lambda):
                continue
            if isinstance(n, ast.Call):
                name = call_name(n)
                if _DEVICE_CALL.search(name) and not _DEFINING.search(name):
                    findings.append(self.make_finding(
                        mod, n, f"'{name}(...)' runs on the device at "
                                f"import time"))
            stack.extend(ast.iter_child_nodes(n))

    def _walk_toplevel(self, mod: ParsedModule, body: List[ast.stmt],
                       findings: List) -> None:
        for stmt in body:
            if is_main_guard(stmt) or is_type_checking_guard(stmt):
                continue
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # default-arg expressions evaluate at import time
                a = stmt.args
                for d in list(a.defaults) + [d for d in a.kw_defaults if d]:
                    self._flag_calls(mod, d, findings)
                continue
            if isinstance(stmt, ast.ClassDef):
                self._walk_toplevel(mod, stmt.body, findings)
                continue
            if isinstance(stmt, (ast.If, ast.Try, ast.With)):
                for sub in ast.iter_child_nodes(stmt):
                    if isinstance(sub, ast.stmt):
                        self._walk_toplevel(mod, [sub], findings)
                continue
            self._flag_calls(mod, stmt, findings)

    def check_module(self, mod: ParsedModule) -> List:
        findings: List = []
        self._walk_toplevel(mod, mod.tree.body, findings)
        return findings


# ---------------------------------------------------------------------------
# JAX004 — Python loops over per-client state in the engine hot path
# ---------------------------------------------------------------------------

_CLIENTISH = re.compile(
    r"client|survivor|sampled|cohort|fleet|participant|roster")


@register_rule
class PerClientPythonLoop(ModuleRule):
    """JAX004 — per-client Python for-loop in fl/engine.py|dynamics.py."""

    id = "JAX004"
    title = "Python loop over per-client state in a hot path"
    rationale = ("The round control plane iterates Python-side per "
                 "client, capping fleets at thousands; the ROADMAP's "
                 "million-client item rewrites these as jitted array "
                 "programs over client-state arrays.")
    hint = ("vectorize over a client axis (vmap / masked array program); "
            "new hot-path code must not add per-client Python loops")
    paths = ("src/repro/fl/engine.py", "src/repro/fl/dynamics.py")

    def check_module(self, mod: ParsedModule) -> List:
        findings: List = []
        funcs = [n for n in ast.walk(mod.tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        for fn in funcs:
            for node in ast.walk(fn):
                if not isinstance(node, ast.For):
                    continue
                try:
                    text = ast.unparse(node.iter) + " " + ast.unparse(
                        node.target)
                except Exception:
                    text = ""
                if _CLIENTISH.search(text):
                    findings.append(self.make_finding(
                        mod, node,
                        f"per-client Python loop over "
                        f"'{ast.unparse(node.iter)}' in "
                        f"{fn.name}()"))
        return findings
