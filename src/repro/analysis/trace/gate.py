"""The static feasibility gate: traced costs vs the committed table
and the paper's memory budget.

Two checks ride on every ``python -m repro.analysis --trace`` run:

1. **Ratchet** — each entry's peak/flops/transfer numbers must match
   the committed ``TRACE_BUDGETS.json`` row (small tolerance for jax
   version noise). A regression fails; an improvement is reported so it
   can be banked with ``--trace --update-baseline``.

2. **Memory gate** — peak bytes are converted to the paper's relative
   memory units through the calibration entry (the client step at
   *baseline* knobs defines ``Table-1 FedAvg memory = 0.31`` units,
   mirroring ``core.resources.calibrate``) and every ``gated`` entry is
   checked against ``Budgets.memory`` through the Constraint API. The
   baseline client step itself deliberately violates the budget
   (0.31 > 0.26) — that is the paper's Fig. 2 starting point and the
   negative control pinned in tests — so only the *adapted* operating
   point is gated.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

from repro.analysis.findings import Finding
from repro.analysis.trace.registry import TracedEntry, traced_entries
from repro.analysis.trace.rules import run_trace_rules, trace_rule_ids

DEFAULT_TRACE_TABLE = "TRACE_BUDGETS.json"
TRACE_TABLE_VERSION = 1
#: static costs are deterministic given code; the band only absorbs
#: jax-version changes to canonicalization (re-record when it moves)
PEAK_RTOL = 0.02


def _memory_budget_units() -> float:
    """Budgets.memory resolved through the Constraint API (the same
    ``budget_of`` the dual update reads), in relative proxy units."""
    from repro.configs import get_fl_config
    from repro.constraints import make_constraints

    budgets = get_fl_config().budgets
    cs = make_constraints("paper")
    mem = next(c for c in cs if c.name == "memory")
    return float(mem.budget_of(budgets))


def _baseline_units() -> float:
    from repro.core.resources import TABLE1_FEDAVG
    return float(TABLE1_FEDAVG["memory"])


@dataclass
class GateRow:
    """One entry's memory-gate accounting (in paper proxy units)."""

    entry: str
    peak_bytes: int
    memory_units: float
    budget_units: float
    gated: bool
    violated: bool

    def to_json(self) -> Dict[str, Any]:
        return {"entry": self.entry, "peak_bytes": self.peak_bytes,
                "memory_units": round(self.memory_units, 6),
                "budget_units": self.budget_units, "gated": self.gated,
                "violated": self.violated}


@dataclass
class TraceReport:
    """Everything one --trace run produced."""

    traced: List[TracedEntry] = field(default_factory=list)
    findings: List[Finding] = field(default_factory=list)
    gate: List[GateRow] = field(default_factory=list)
    problems: List[str] = field(default_factory=list)
    rules_run: List[str] = field(default_factory=list)

    def rows_json(self) -> List[Dict[str, Any]]:
        out = []
        for t in self.traced:
            row = {"entry": t.entry.name, **t.cost.to_json()}
            if t.entry.donatable:
                row["aliased_outputs"] = t.aliased_outputs
                row["donatable_leaves"] = t.donatable_leaves
            out.append(row)
        return out


def memory_gate(traced: Sequence[TracedEntry]) -> List[GateRow]:
    """Convert peaks to units via the calibration entry and test every
    gated entry against the memory budget."""
    cal = [t for t in traced if t.entry.calibration]
    if not cal:
        return []
    cal_peak = cal[0].cost.peak_bytes
    if cal_peak <= 0:
        return []
    base_units = _baseline_units()
    budget = _memory_budget_units()
    rows: List[GateRow] = []
    for t in traced:
        if not (t.entry.gated or t.entry.calibration):
            continue
        units = base_units * t.cost.peak_bytes / cal_peak
        rows.append(GateRow(
            entry=t.entry.name, peak_bytes=t.cost.peak_bytes,
            memory_units=units, budget_units=budget,
            gated=t.entry.gated,
            violated=units > budget))
    return rows


def load_table(path: str) -> Optional[Dict[str, Any]]:
    if not os.path.exists(path):
        return None
    with open(path, "r", encoding="utf-8") as fh:
        raw = json.load(fh)
    if raw.get("version") != TRACE_TABLE_VERSION:
        raise ValueError(
            f"{path}: unsupported trace table version "
            f"{raw.get('version')!r} (expected {TRACE_TABLE_VERSION})")
    return raw


def build_table(traced: Sequence[TracedEntry],
                gate_rows: Sequence[GateRow]) -> Dict[str, Any]:
    units = {r.entry: r for r in gate_rows}
    entries: Dict[str, Any] = {}
    for t in traced:
        row: Dict[str, Any] = dict(t.cost.to_json())
        g = units.get(t.entry.name)
        if g is not None:
            row["memory_units"] = round(g.memory_units, 6)
            row["gated"] = g.gated
        entries[t.entry.name] = row
    return {
        "version": TRACE_TABLE_VERSION,
        "budget": {"memory_units": _memory_budget_units(),
                   "baseline_units": _baseline_units()},
        "entries": entries,
    }


def save_table(table: Dict[str, Any], path: str) -> None:
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(table, fh, indent=2, sort_keys=True)
        fh.write("\n")


def diff_table(table: Optional[Dict[str, Any]],
               traced: Sequence[TracedEntry]) -> List[str]:
    """Ratchet: current costs vs the committed table rows."""
    problems: List[str] = []
    if table is None:
        problems.append(
            f"no committed trace table ({DEFAULT_TRACE_TABLE}); run "
            f"--trace --update-baseline to record one")
        return problems
    rows = table.get("entries", {})
    for t in traced:
        row = rows.get(t.entry.name)
        if row is None:
            problems.append(
                f"entry '{t.entry.name}' is not in the committed trace "
                f"table; re-record with --trace --update-baseline")
            continue
        old = int(row.get("peak_bytes", 0))
        new = t.cost.peak_bytes
        if old and new > old * (1.0 + PEAK_RTOL):
            problems.append(
                f"entry '{t.entry.name}' peak regressed: {new} B > "
                f"recorded {old} B (+{(new / old - 1) * 100:.1f}%)")
    current = {t.entry.name for t in traced}
    for name in sorted(set(rows) - current):
        problems.append(
            f"trace table row '{name}' no longer has a registered "
            f"entry; re-record with --trace --update-baseline")
    return problems


def run_trace(root: str = ".", table_path: Optional[str] = None,
              update: bool = False) -> TraceReport:
    """Trace every registered entry, run the TRACE rules, apply the
    memory gate and the committed-table ratchet.

    ``update=True`` rewrites the table instead of diffing against it
    (findings still flow to the caller for the shared baseline).
    """
    traced = list(traced_entries())
    report = TraceReport(traced=traced,
                         findings=run_trace_rules(traced),
                         rules_run=trace_rule_ids())
    report.gate = memory_gate(traced)
    for row in report.gate:
        if row.gated and row.violated:
            report.problems.append(
                f"memory gate: entry '{row.entry}' static estimate "
                f"{row.memory_units:.3f} units exceeds Budgets.memory "
                f"= {row.budget_units:.2f}")

    path = table_path or os.path.join(root, DEFAULT_TRACE_TABLE)
    if update:
        save_table(build_table(traced, report.gate), path)
    else:
        report.problems.extend(diff_table(load_table(path), traced))
    return report


def format_report(report: TraceReport) -> str:
    """The human-readable --trace section."""
    lines = [f"trace: {len(report.traced)} entry point(s), "
             f"{len(report.rules_run)} TRACE rules"]
    width = max((len(t.entry.name) for t in report.traced), default=0)
    for t in report.traced:
        c = t.cost
        extra = ""
        if t.entry.donatable:
            extra = (f"  donated {t.aliased_outputs}/"
                     f"{t.donatable_leaves}")
        lines.append(
            f"  {t.entry.name:<{width}}  peak {_fmt_bytes(c.peak_bytes):>10}"
            f"  flops {_fmt_count(c.flops):>8}"
            f"  xfer {_fmt_bytes(c.transfer_bytes):>8}{extra}")
    for row in report.gate:
        tag = ("VIOLATED" if row.violated else "ok") if row.gated else \
            "calibration"
        lines.append(
            f"  gate[memory] {row.entry}: {row.memory_units:.3f} / "
            f"{row.budget_units:.2f} units ({tag})")
    return "\n".join(lines)


def _fmt_bytes(n: int) -> str:
    if n >= 1 << 20:
        return f"{n / (1 << 20):.2f} MiB"
    if n >= 1 << 10:
        return f"{n / (1 << 10):.1f} KiB"
    return f"{n} B"


def _fmt_count(n: int) -> str:
    if n >= 10 ** 9:
        return f"{n / 1e9:.2f} G"
    if n >= 10 ** 6:
        return f"{n / 1e6:.2f} M"
    if n >= 10 ** 3:
        return f"{n / 1e3:.1f} k"
    return str(n)
