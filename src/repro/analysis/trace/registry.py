"""Traceable entry points: what gets traced, and under which shapes.

Hot modules *declare* their own entry points by defining a module-level
``trace_entry_points() -> list[EntryPoint]`` hook (``repro.core.client``,
``repro.fl.executor``, ``repro.fl.aggregator``, ``repro.kernels.ops``,
``repro.constraints.controllers``); ``collect_entry_points`` imports
those modules and gathers the declarations, so the shapes live next to
the code they describe and this package never hard-codes model guts.

An ``EntryPoint`` is lazy: ``build()`` constructs the callable and its
example arguments (real tiny-model params where cheap,
``jax.ShapeDtypeStruct`` where only shapes matter) on first trace.
Declared example shapes are the contract — the committed
``TRACE_BUDGETS.json`` rows are only comparable while the declarations
stay fixed, so changing a declaration is a table re-record, same as the
bench ratchet.
"""
from __future__ import annotations

import contextlib
import functools
import importlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax

from repro.analysis.trace.cost import (JaxprCost, cost_of_jaxpr,
                                       unwrap_pjit)

#: modules whose ``trace_entry_points()`` hooks feed the registry
TRACE_ENTRY_MODULES: Tuple[str, ...] = (
    "repro.core.client",
    "repro.fl.executor",
    "repro.fl.aggregator",
    "repro.kernels.ops",
    "repro.constraints.controllers",
)

#: charlm dims every declared entry shares (kept tiny so tracing is
#: cheap; the *ratios* between operating points are what the gate uses)
TRACE_MODEL = {"vocab": 64, "num_layers": 2, "d_model": 32, "num_heads": 2,
               "head_dim": 16, "d_ff": 64, "seq_len": 64}


@dataclass(frozen=True)
class EntryPoint:
    """One registered traceable callable + its declared example shapes."""

    name: str                     # e.g. "fl.client_update_step"
    path: str                     # repo-relative module declaring it
    line: int                     # decl anchor for findings
    build: Callable[[], Tuple[Callable[..., Any], Tuple[Any, ...]]]
    #: argnums whose buffers an update-style step *should* donate
    #: (TRACE002 verifies the compiled artifact actually aliases them)
    donatable: Tuple[int, ...] = ()
    #: >=2 marks an aggregation combine over a client cohort (TRACE003)
    cohort: int = 0
    #: participates in the Budgets.memory static feasibility gate
    gated: bool = False
    #: the baseline-knobs twin whose peak defines bytes-per-memory-unit
    calibration: bool = False
    #: trace under jax.experimental.enable_x64() (fixture entries)
    x64: bool = False
    #: TRACE rule ids intentionally suppressed for this entry
    allow: Tuple[str, ...] = ()
    note: str = ""


@dataclass
class TracedEntry:
    """One entry point after tracing: the IR plus its static cost."""

    entry: EntryPoint
    closed_jaxpr: Any
    cost: JaxprCost
    donatable_leaves: int = 0     # leaves under the donatable argnums
    aliased_outputs: int = -1     # buffers XLA aliased; -1 = not a jit
    unit_bytes: int = 0           # largest per-client leaf (TRACE003)


def charlm_trace_setup(b: int, seq: Optional[int] = None,
                       model: Optional[Dict[str, int]] = None) -> Any:
    """Shared tiny char-LM fixture for the fl.* entry declarations:
    a real ``ClientRunner`` (params initialised — they are a few kB)
    plus a shape-only batch."""
    from repro.configs import get_config, get_fl_config
    from repro.core.client import ClientRunner
    from repro.models import build

    dims = dict(TRACE_MODEL, **(model or {}))
    seq = dims["seq_len"] if seq is None else seq
    cfg = get_config("charlm-shakespeare").replace(
        vocab_size=dims["vocab"], num_layers=dims["num_layers"],
        d_model=dims["d_model"], num_heads=dims["num_heads"],
        num_kv_heads=dims["num_heads"], head_dim=dims["head_dim"],
        d_ff=dims["d_ff"])
    fl = get_fl_config().replace(seq_len=seq)
    mdl = build(cfg)
    runner = ClientRunner(mdl, fl, data=None, resources=None)
    params = mdl.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, seq), jax.numpy.int32),
        "targets": jax.ShapeDtypeStruct((b, seq), jax.numpy.int32),
    }
    return runner, params, batch


def collect_entry_points(
        extra_modules: Sequence[str] = ()) -> List[EntryPoint]:
    """Import the declaring modules and gather every entry point."""
    entries: List[EntryPoint] = []
    for modname in tuple(TRACE_ENTRY_MODULES) + tuple(extra_modules):
        mod = importlib.import_module(modname)
        hook = getattr(mod, "trace_entry_points", None)
        if hook is None:
            continue
        entries.extend(hook())
    names = [e.name for e in entries]
    if len(set(names)) != len(names):
        dupes = sorted({n for n in names if names.count(n) > 1})
        raise ValueError(f"duplicate trace entry points: {dupes}")
    return entries


def _leaf_count(args: Tuple[Any, ...], argnums: Sequence[int]) -> int:
    return sum(len(jax.tree.leaves(args[i])) for i in argnums)


def _count_aliased(fn: Callable[..., Any],
                   args: Tuple[Any, ...]) -> int:
    """How many output buffers the lowered artifact aliases to donated
    inputs (``tf.aliasing_output`` in the StableHLO text) — the ground
    truth TRACE002 compares the declaration against."""
    lower = getattr(fn, "lower", None)
    if lower is None:
        return -1
    try:
        text = lower(*args).as_text()
    except Exception:                                  # pragma: no cover
        return -1
    return text.count("tf.aliasing_output")


def trace_entry(entry: EntryPoint) -> TracedEntry:
    """Trace one entry point to a jaxpr and run the cost model on it."""
    fn, args = entry.build()

    def ctx() -> Any:
        return (jax.experimental.enable_x64() if entry.x64
                else contextlib.nullcontext())

    with ctx():
        closed = unwrap_pjit(jax.make_jaxpr(fn)(*args))

    # map donated argnums -> flattened invar indices (pytree args
    # flatten in order, matching the unwrapped jaxpr's invars)
    donated_leaves: List[int] = []
    offset = 0
    for i, a in enumerate(args):
        n = len(jax.tree.leaves(a))
        if i in entry.donatable:
            donated_leaves.extend(range(offset, offset + n))
        offset += n

    cost = cost_of_jaxpr(closed, donated=donated_leaves)
    traced = TracedEntry(
        entry=entry, closed_jaxpr=closed, cost=cost,
        donatable_leaves=len(donated_leaves),
        unit_bytes=_cohort_unit_bytes(entry, args))
    if entry.donatable:
        with ctx():
            traced.aliased_outputs = _count_aliased(fn, args)
    return traced


def _cohort_unit_bytes(entry: EntryPoint, args: Tuple[Any, ...]) -> int:
    """Largest single-client leaf for TRACE003's O(C*P) threshold: an
    aggregation combine materializing ``cohort * max_leaf`` bytes in one
    value has stacked the cohort densely."""
    if entry.cohort < 2:
        return 0
    leaves = [leaf for a in args for leaf in jax.tree.leaves(a)]
    sizes = [int(leaf.size) * int(leaf.dtype.itemsize)
             for leaf in leaves
             if hasattr(leaf, "size") and hasattr(leaf, "dtype")]
    return max(sizes, default=0)


@functools.lru_cache(maxsize=1)
def traced_entries() -> Tuple[TracedEntry, ...]:
    """Trace every registered entry once per process (tests, the CLI
    gate and the bench all share the result)."""
    return tuple(trace_entry(e) for e in collect_entry_points())
