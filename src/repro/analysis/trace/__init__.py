"""repro.analysis.trace — jaxpr-level static analysis.

Where the AST half of ``repro.analysis`` reads source text, this half
traces registered entry points (client update step, batched executor
body, aggregator combines, the wire kernels, the dual update) to
jaxprs under declared example shapes, runs a static cost model over
them (peak live bytes via linear-scan liveness, flops, host-transfer
bytes), evaluates the TRACE rule family on the traced IR, and gates
the peak-memory estimate against ``Budgets.memory`` through the
Constraint API — a pre-run static feasibility check.

    PYTHONPATH=src python -m repro.analysis --trace [--json]

The committed ``TRACE_BUDGETS.json`` is the cost table the CI ratchet
diffs against; ``--trace --update-baseline`` re-records it (and folds
any TRACE findings into ``ANALYSIS_BASELINE.json``).
"""
from __future__ import annotations

from repro.analysis.trace.cost import (JaxprCost, aval_bytes,
                                       cost_of_jaxpr, iter_eqns,
                                       unwrap_pjit)
from repro.analysis.trace.gate import (DEFAULT_TRACE_TABLE, GateRow,
                                       TraceReport, format_report,
                                       memory_gate, run_trace)
from repro.analysis.trace.registry import (EntryPoint, TracedEntry,
                                           charlm_trace_setup,
                                           collect_entry_points,
                                           trace_entry, traced_entries)
from repro.analysis.trace.rules import (TraceRule, register_trace_rule,
                                        run_trace_rules, trace_rule_ids,
                                        trace_rules)

__all__ = [
    "DEFAULT_TRACE_TABLE", "EntryPoint", "GateRow", "JaxprCost",
    "TraceReport", "TraceRule", "TracedEntry", "aval_bytes",
    "charlm_trace_setup", "collect_entry_points", "cost_of_jaxpr",
    "format_report", "iter_eqns", "memory_gate", "register_trace_rule",
    "run_trace", "run_trace_rules", "trace_entry", "trace_rule_ids",
    "trace_rules", "traced_entries", "unwrap_pjit",
]
