"""Static cost model over jaxprs: peak live bytes, flops, transfers.

The estimate is *pre-XLA*: it walks the traced jaxpr, not the compiled
executable, so it is an upper-ish bound on what an unfused execution
would materialize. That is exactly the right side to gate on — XLA
fusion only shrinks the live set, so a jaxpr-level peak under the
memory budget stays under it after compilation (the bracket test in
``tests/test_analysis_trace.py`` pins the relation against
``Compiled.memory_analysis()`` on the real client step).

Peak live bytes come from a linear-scan liveness pass over the
equations: every value's lifetime is [defining eqn, last reading eqn],
jaxpr outputs and *non-donated* inputs live to the end (the caller
holds them), donated inputs die at their last read — which is how
buffer donation turns into a statically visible memory win. Control
flow recurses: ``scan``/``while`` bodies contribute their own peak on
top of the carried operands (flops scaled by the trip count where it
is known), ``cond`` contributes its worst branch.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Sequence, Set, Tuple

from jax.core import ClosedJaxpr, Jaxpr, JaxprEqn, Literal, Var

#: primitives that are pure data movement: no flops charged.
_MOVEMENT = {
    "broadcast_in_dim", "reshape", "transpose", "slice", "dynamic_slice",
    "dynamic_update_slice", "concatenate", "pad", "rev", "squeeze",
    "gather", "scatter", "iota", "copy", "stop_gradient", "split",
}

#: host-boundary primitives: bytes crossing them count as transfers
#: (and trip TRACE004 — nothing inside a steady-state jit should).
TRANSFER_PRIMITIVES = {
    "pure_callback", "io_callback", "debug_callback", "callback",
    "device_put",
}


def aval_bytes(aval: Any) -> int:
    """Concrete byte size of an abstract value (0 for tokens etc.)."""
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return int(math.prod(shape)) * int(dtype.itemsize)


def aval_elems(aval: Any) -> int:
    shape = getattr(aval, "shape", None)
    if shape is None:
        return 0
    return int(math.prod(shape))


@dataclass
class JaxprCost:
    """What one traced entry point statically costs."""

    peak_bytes: int = 0          # max live set incl. inputs/outputs
    flops: int = 0               # scan-scaled floating/integer op count
    transfer_bytes: int = 0      # bytes crossing host boundaries in-jit
    input_bytes: int = 0         # h2d at call boundary (args + consts)
    output_bytes: int = 0        # d2h/result at call boundary
    eqns: int = 0                # total equations walked (recursive)
    notes: List[str] = field(default_factory=list)

    def to_json(self) -> Dict[str, Any]:
        return {
            "peak_bytes": self.peak_bytes, "flops": self.flops,
            "transfer_bytes": self.transfer_bytes,
            "input_bytes": self.input_bytes,
            "output_bytes": self.output_bytes, "eqns": self.eqns,
        }


# ---------------------------------------------------------------------------
# per-equation flop model
# ---------------------------------------------------------------------------


def _dot_general_flops(eqn: JaxprEqn) -> int:
    lhs, rhs = (v.aval for v in eqn.invars[:2])
    (lc, rc), (lb, _rb) = eqn.params["dimension_numbers"]
    batch = math.prod(lhs.shape[d] for d in lb) if lb else 1
    contract = math.prod(lhs.shape[d] for d in lc) if lc else 1
    m = math.prod(s for d, s in enumerate(lhs.shape)
                  if d not in set(lc) | set(lb))
    n = math.prod(s for d, s in enumerate(rhs.shape)
                  if d not in set(rc) | set(_rb))
    return 2 * batch * m * n * contract


def eqn_flops(eqn: JaxprEqn) -> int:
    """Flops for one equation, its own sub-jaxprs excluded (those are
    charged by the recursive walk)."""
    name = eqn.primitive.name
    if name in _MOVEMENT or _sub_jaxprs(eqn):
        return 0
    if name == "dot_general":
        return _dot_general_flops(eqn)
    if name.startswith("reduce_") or name.startswith("cum")\
            or name == "argmax" or name == "argmin":
        return sum(aval_elems(v.aval) for v in eqn.invars
                   if not isinstance(v, Literal))
    if name in ("sort", "top_k"):
        n = max((aval_elems(v.aval) for v in eqn.invars
                 if not isinstance(v, Literal)), default=0)
        return n * max(1, int(math.log2(n)) if n > 1 else 1)
    return sum(aval_elems(v.aval) for v in eqn.outvars)


# ---------------------------------------------------------------------------
# sub-jaxpr discovery + recursive walk
# ---------------------------------------------------------------------------


def _as_closed(j: Any) -> Optional[ClosedJaxpr]:
    if isinstance(j, ClosedJaxpr):
        return j
    if isinstance(j, Jaxpr):
        return ClosedJaxpr(j, [])
    return None


def _sub_jaxprs(eqn: JaxprEqn) -> List[Tuple[ClosedJaxpr, int, bool]]:
    """-> [(sub_jaxpr, flop_multiplier, alternative)] for control-flow /
    call primitives. ``alternative`` marks mutually-exclusive bodies
    (cond branches): their peaks max instead of summing."""
    name = eqn.primitive.name
    if name == "scan":
        length = int(eqn.params.get("length", 1))
        sub = _as_closed(eqn.params["jaxpr"])
        return [(sub, length, False)] if sub else []
    if name == "while":
        out = []
        for key in ("cond_jaxpr", "body_jaxpr"):
            sub = _as_closed(eqn.params.get(key))
            if sub:
                out.append((sub, 1, False))
        return out
    if name == "cond":
        return [(s, 1, True) for b in eqn.params.get("branches", ())
                if (s := _as_closed(b))]
    out = []
    for val in eqn.params.values():
        sub = _as_closed(val)
        if sub is not None:
            out.append((sub, 1, False))
    return out


def iter_eqns(closed: ClosedJaxpr) -> Iterator[Tuple[JaxprEqn, int]]:
    """Every equation in the jaxpr, recursively, with its nesting depth
    — the traversal the TRACE rules share."""

    def walk(jaxpr: Jaxpr, depth: int) -> Iterator[Tuple[JaxprEqn, int]]:
        for eqn in jaxpr.eqns:
            yield eqn, depth
            for sub, _, _ in _sub_jaxprs(eqn):
                yield from walk(sub.jaxpr, depth + 1)

    yield from walk(closed.jaxpr, 0)


def unwrap_pjit(closed: ClosedJaxpr) -> ClosedJaxpr:
    """Peel the trivial outer pjit wrapper ``make_jaxpr(jit(f))``
    produces, so liveness sees the real equations and donated argument
    indices line up with the inner jaxpr's invars."""
    while (len(closed.jaxpr.eqns) == 1
           and closed.jaxpr.eqns[0].primitive.name == "pjit"
           and list(closed.jaxpr.eqns[0].invars) == list(closed.jaxpr.invars)
           and list(closed.jaxpr.eqns[0].outvars)
           == list(closed.jaxpr.outvars)):
        closed = closed.jaxpr.eqns[0].params["jaxpr"]
    return closed


def _eqn_io_bytes(eqn: JaxprEqn) -> Tuple[int, int]:
    in_b = sum(aval_bytes(v.aval) for v in eqn.invars
               if not isinstance(v, Literal))
    out_b = sum(aval_bytes(v.aval) for v in eqn.outvars)
    return in_b, out_b


def cost_of_jaxpr(closed: ClosedJaxpr,
                  donated: Sequence[int] = ()) -> JaxprCost:
    """Static cost of one traced callable.

    ``donated`` indexes the (flattened) jaxpr invars whose buffers the
    caller donates: those die at their last read instead of being
    pinned for the whole call.
    """
    cost = JaxprCost()
    donated_set = set(donated)
    jaxpr = closed.jaxpr
    invars: List[Var] = list(jaxpr.invars)
    const_bytes = sum(aval_bytes(v.aval) for v in jaxpr.constvars)
    cost.input_bytes = sum(aval_bytes(v.aval) for v in invars) + const_bytes
    cost.output_bytes = sum(aval_bytes(v.aval) for v in jaxpr.outvars
                            if not isinstance(v, Literal))
    peak, flops, xfer, neqns, notes = _walk_cost(
        jaxpr, const_bytes,
        pinned={id(v) for i, v in enumerate(invars)
                if i not in donated_set})
    cost.peak_bytes = peak
    cost.flops = flops
    cost.transfer_bytes = xfer
    cost.eqns = neqns
    cost.notes = notes
    return cost


def _walk_cost(jaxpr: Jaxpr, const_bytes: int,
               pinned: Set[int]) -> Tuple[int, int, int, int, List[str]]:
    """Linear-scan liveness over one jaxpr body.

    -> (peak_bytes, flops, transfer_bytes, eqn_count, notes). ``pinned``
    holds ``id()``s of invars the caller still owns (non-donated).
    """
    eqns = jaxpr.eqns
    last_use: Dict[int, int] = {}
    for i, eqn in enumerate(eqns):
        for v in eqn.invars:
            if isinstance(v, Var):
                last_use[id(v)] = i
    end = len(eqns)
    outvar_ids = {id(v) for v in jaxpr.outvars if isinstance(v, Var)}
    for v in list(jaxpr.invars) + list(jaxpr.constvars):
        if id(v) in pinned or id(v) in outvar_ids:
            last_use[id(v)] = end
    for v in jaxpr.outvars:
        if isinstance(v, Var):
            last_use[id(v)] = end

    live = const_bytes + sum(aval_bytes(v.aval) for v in jaxpr.invars)
    peak = live
    flops = 0
    xfer = 0
    neqns = 0
    notes: List[str] = []
    for i, eqn in enumerate(eqns):
        neqns += 1
        in_b, out_b = _eqn_io_bytes(eqn)
        name = eqn.primitive.name
        if name in TRANSFER_PRIMITIVES:
            xfer += in_b + out_b
        flops += eqn_flops(eqn)

        # control flow: the body's internal peak rides on top of the
        # operands already counted in the outer live set
        extra = 0
        alt_extra = 0
        for sub, mult, alternative in _sub_jaxprs(eqn):
            s_const = sum(aval_bytes(v.aval)
                          for v in sub.jaxpr.constvars)
            s_peak, s_flops, s_xfer, s_eqns, s_notes = _walk_cost(
                sub.jaxpr, s_const,
                pinned={id(v) for v in sub.jaxpr.invars})
            s_extra = max(0, s_peak - in_b - out_b)
            if alternative:
                alt_extra = max(alt_extra, s_extra)
            else:
                extra += s_extra
            flops += s_flops * mult
            xfer += s_xfer * mult
            neqns += s_eqns
            notes.extend(s_notes)
        if name == "while":
            notes.append("while-loop trip count unknown: flops counted "
                         "for one iteration")
        extra += alt_extra

        live += out_b
        peak = max(peak, live + extra)
        for v in eqn.invars:
            if isinstance(v, Var) and last_use.get(id(v)) == i:
                live -= aval_bytes(v.aval)
                last_use[id(v)] = -1        # freed once
        for v in eqn.outvars:
            if id(v) not in last_use:        # never read, not an output
                live -= aval_bytes(v.aval)
    return peak, flops, xfer, neqns, notes
