"""TRACE rules: discipline checks evaluated on traced jaxprs.

Where the AST rules (``rules_jax`` / ``rules_repro``) see source text,
these see what XLA will actually be asked to materialize. Findings flow
through the same ``Finding``/baseline machinery: a finding anchors at
the entry point's *declaration* site and fingerprints on a stable
``trace:<entry>:<detail>`` snippet, so line drift in the traced code
never churns the committed baseline.

TRACE001  dtype promotion — a 64-bit value appears in a jaxpr whose
          inputs are all narrower (f32->f64 / weak-type widening), or
          reaches an entry output / wire buffer.
TRACE002  missed buffer donation — an update-style entry declares
          donatable params/opt-state args, but the compiled artifact
          aliases fewer output buffers than those args have leaves.
TRACE003  dense per-client materialization — an aggregation combine
          produces a single value of >= cohort * max-client-leaf bytes
          (the O(C*P) stack the incremental combine exists to avoid).
TRACE004  host callbacks / transfers inside jit — callback or
          device_put primitives in a steady-state traced entry.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Type

from repro.analysis.findings import Finding
from repro.analysis.trace.cost import (TRANSFER_PRIMITIVES, aval_bytes,
                                       iter_eqns)
from repro.analysis.trace.registry import TracedEntry

_WIDE_DTYPES = {"float64", "int64", "uint64", "complex128"}

#: staging a handful of scalars (pre-staged combine weights, step
#: counts) is the *endorsed* pattern — TRACE004 only flags device_put
#: once the moved bytes stop looking like scalars; callbacks always fire
DEVICE_PUT_MIN_BYTES = 4096


class TraceRule:
    """Base: metadata + one ``check`` over a traced entry."""

    id: str = ""
    title: str = ""
    rationale: str = ""
    hint: str = ""

    def check(self, traced: TracedEntry) -> List[Finding]:
        raise NotImplementedError

    def finding(self, traced: TracedEntry, detail: str,
                message: str) -> Finding:
        ep = traced.entry
        return Finding(rule=self.id, path=ep.path, line=ep.line,
                       message=f"[{ep.name}] {message}", hint=self.hint,
                       snippet=f"trace:{ep.name}:{detail}")


_TRACE_RULES: Dict[str, Type[TraceRule]] = {}


def register_trace_rule(cls: Type[TraceRule]) -> Type[TraceRule]:
    assert cls.id, f"{cls.__name__} needs a rule id"
    _TRACE_RULES[cls.id] = cls
    return cls


def trace_rules() -> List[TraceRule]:
    return [cls() for _, cls in sorted(_TRACE_RULES.items())]


def trace_rule_ids() -> List[str]:
    return sorted(_TRACE_RULES)


def run_trace_rules(traced: Sequence[TracedEntry],
                    rules: Sequence[TraceRule] = ()) -> List[Finding]:
    """Every rule over every traced entry, honoring per-entry allows."""
    ruleset = list(rules) if rules else trace_rules()
    findings: List[Finding] = []
    for t in traced:
        for rule in ruleset:
            if rule.id in t.entry.allow:
                continue
            findings.extend(rule.check(t))
    return findings


def _dtype_name(aval: object) -> str:
    dt = getattr(aval, "dtype", None)
    return str(dt) if dt is not None else ""


@register_trace_rule
class DtypePromotion(TraceRule):
    """TRACE001 — widening to 64-bit inside a traced entry."""

    id = "TRACE001"
    title = "dtype promotion to 64-bit in traced entry"
    rationale = ("The wire and update paths are specified in f32 (and "
                 "narrower wire formats): a silent f64/i64 promotion "
                 "doubles the very bytes the memory and comm budgets "
                 "meter, and usually enters through a weak-typed host "
                 "scalar.")
    hint = ("pin the scalar/array dtype at the source (jnp.float32, "
            "np.asarray(..., np.float32)); keep x64 mode off the hot "
            "path")

    def check(self, traced: TracedEntry) -> List[Finding]:
        out: List[Finding] = []
        seen: set = set()
        for eqn, _ in iter_eqns(traced.closed_jaxpr):
            outs_wide = [v for v in eqn.outvars
                         if _dtype_name(v.aval) in _WIDE_DTYPES]
            if not outs_wide:
                continue
            ins_wide = any(_dtype_name(v.aval) in _WIDE_DTYPES
                           for v in eqn.invars)
            if ins_wide:
                continue                   # already wide upstream
            detail = (f"widen:{eqn.primitive.name}:"
                      f"{_dtype_name(outs_wide[0].aval)}")
            if detail in seen:
                continue
            seen.add(detail)
            out.append(self.finding(
                traced, detail,
                f"'{eqn.primitive.name}' widens to "
                f"{_dtype_name(outs_wide[0].aval)} from narrower inputs"))
        jaxpr = traced.closed_jaxpr.jaxpr
        wide_out = [v for v in jaxpr.outvars
                    if _dtype_name(getattr(v, 'aval', None))
                    in _WIDE_DTYPES]
        wide_in = any(_dtype_name(v.aval) in _WIDE_DTYPES
                      for v in jaxpr.invars)
        if wide_out and not wide_in:
            out.append(self.finding(
                traced, f"wide-output:{_dtype_name(wide_out[0].aval)}",
                f"entry output is {_dtype_name(wide_out[0].aval)} but "
                f"every input is narrower (promotion reaches the "
                f"output/wire buffer)"))
        return out


@register_trace_rule
class MissedDonation(TraceRule):
    """TRACE002 — declared-donatable buffers not actually aliased."""

    id = "TRACE002"
    title = "missed buffer donation in jitted update step"
    rationale = ("An update step that rebinds params/opt-state every "
                 "call can donate those buffers; without donation the "
                 "old and new copies are live simultaneously and the "
                 "client's peak memory roughly doubles on its largest "
                 "state — the exact quantity Budgets.memory gates.")
    hint = ("jit with donate_argnums=(...) covering the rebound "
            "state args (and keep shared/reused args, e.g. params "
            "under an outer loop that still reads them, undonated)")

    def check(self, traced: TracedEntry) -> List[Finding]:
        ep = traced.entry
        if not ep.donatable or traced.aliased_outputs < 0:
            return []
        expected = traced.donatable_leaves
        actual = traced.aliased_outputs
        if actual >= expected:
            return []
        return [self.finding(
            traced, "missed-donation",
            f"only {actual} of {expected} declared-donatable buffers "
            f"are aliased in the compiled step (donate_argnums missing "
            f"or ineffective)")]


@register_trace_rule
class DenseCohortMaterialization(TraceRule):
    """TRACE003 — O(C*P) value materialized inside an aggregation."""

    id = "TRACE003"
    title = "dense per-client materialization in aggregation"
    rationale = ("Server combines must stay O(P): stacking the cohort "
                 "into one (C, ...) array scales server peak memory "
                 "with cohort size, which is how aggregation quietly "
                 "busts the memory budget at exactly the moment the "
                 "paper scales clients.")
    hint = ("fold incrementally (weighted add per client, as "
            "core.aggregation.aggregate does) instead of "
            "stacking/concatenating the cohort axis")

    def check(self, traced: TracedEntry) -> List[Finding]:
        ep = traced.entry
        if ep.cohort < 2 or traced.unit_bytes <= 0:
            return []
        threshold = ep.cohort * traced.unit_bytes
        out: List[Finding] = []
        seen: set = set()
        for eqn, _ in iter_eqns(traced.closed_jaxpr):
            for v in eqn.outvars:
                if aval_bytes(v.aval) >= threshold:
                    detail = f"dense-cohort:{eqn.primitive.name}"
                    if detail in seen:
                        continue
                    seen.add(detail)
                    out.append(self.finding(
                        traced, detail,
                        f"'{eqn.primitive.name}' materializes "
                        f"{aval_bytes(v.aval)} B >= cohort({ep.cohort}) "
                        f"* largest client leaf ({traced.unit_bytes} B)"))
        return out


@register_trace_rule
class HostCallbackInJit(TraceRule):
    """TRACE004 — host boundary crossings inside a traced entry."""

    id = "TRACE004"
    title = "host callback / transfer inside jit"
    rationale = ("A callback or device_put inside a steady-state jitted "
                 "step serializes the device stream against the host "
                 "every call — the round-loop transfer-guard pin "
                 "(repro.analysis.runtime) bans the same thing "
                 "dynamically; this catches it before a run.")
    hint = ("hoist the host work out of the jitted step; stage scalars "
            "as device arrays once (see core.aggregation's pre-staged "
            "weights) and keep jax.debug.* out of committed hot paths")

    def check(self, traced: TracedEntry) -> List[Finding]:
        out: List[Finding] = []
        seen: set = set()
        for eqn, _ in iter_eqns(traced.closed_jaxpr):
            name = eqn.primitive.name
            if name not in TRANSFER_PRIMITIVES or name in seen:
                continue
            bytes_ = sum(aval_bytes(v.aval) for v in
                         list(eqn.invars) + list(eqn.outvars)
                         if not isinstance(v, (int, float))
                         and hasattr(v, "aval"))
            if name == "device_put" and bytes_ < DEVICE_PUT_MIN_BYTES:
                continue          # scalar pre-staging, the endorsed idiom
            seen.add(name)
            out.append(self.finding(
                traced, f"host-boundary:{name}",
                f"'{name}' crosses the host boundary inside the "
                f"traced entry ({bytes_} B per call)"))
        return out


__all__ = ["TraceRule", "register_trace_rule", "trace_rules",
           "trace_rule_ids", "run_trace_rules"]
