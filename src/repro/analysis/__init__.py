"""repro.analysis — static analysis + runtime sanitizers for the stack.

Static half: an AST rule engine (``python -m repro.analysis``) with
JAX discipline rules (PRNG key reuse, static-arg abuse, import-time
device work, per-client Python loops) and repo invariants (kernel/ref
twins, benchmark metric specs, exact wire/token accounting), gated by
a committed suppression baseline so legacy findings don't block CI
while new code is held to zero.

Runtime half (``repro.analysis.runtime``): opt-in sanitizer contexts —
``jax.transfer_guard`` wiring and a jit recompile watcher — plus
engine ``RoundCallback``s that pin the steady-state round loop at zero
implicit transfers and zero recompiles after round 1.

Schedule half (``repro.analysis.sched``): the determinism contract for
the event-driven control plane — static SCHED rules (order-sensitive
folds, unordered iteration, untied timestamps, shared RNG), a
happens-before race checker over recorded runs, and the
``SchedulePermuter`` that replays a run under adversarial legal event
permutations (``python -m repro.analysis --sched``).
"""
from __future__ import annotations

from repro.analysis.baseline import DEFAULT_BASELINE, Baseline
from repro.analysis.engine import (Analyzer, ModuleRule, ParsedModule,
                                   ProjectRule, Rule, default_rules,
                                   rule_ids, run_analysis)
from repro.analysis.findings import AnalysisResult, Finding
from repro.analysis.runtime import (RecompileWatchCallback, RecompileWatcher,
                                    TransferGuardCallback, no_transfers,
                                    transfer_guard_supported)

__all__ = [
    "Analyzer", "AnalysisResult", "Baseline", "DEFAULT_BASELINE",
    "Finding", "ModuleRule", "ParsedModule", "ProjectRule",
    "RecompileWatchCallback", "RecompileWatcher", "Rule",
    "TransferGuardCallback", "default_rules", "no_transfers",
    "rule_ids", "run_analysis", "transfer_guard_supported",
]
