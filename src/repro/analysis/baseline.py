"""The committed suppression baseline (``ANALYSIS_BASELINE.json``).

Legacy findings live here so they don't block CI while new code is held
to zero. Entries are keyed by the finding *fingerprint* (rule + path +
source-line text + occurrence — no line numbers), so unrelated edits
that shift lines keep suppressing, but a new identical violation
elsewhere still fails.

``diff`` splits a fresh run into (new, suppressed, stale): stale
entries are baseline lines whose finding no longer exists — the CLI
reports them so the baseline can only shrink over time (run with
``--update-baseline`` to drop them).
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.analysis.findings import Finding

BASELINE_VERSION = 1
DEFAULT_BASELINE = "ANALYSIS_BASELINE.json"


@dataclass
class Baseline:
    """fingerprint -> the recorded entry (context only; the fingerprint
    is the key that matters)."""

    entries: Dict[str, Dict] = field(default_factory=dict)

    @classmethod
    def from_findings(cls, findings: List[Finding]) -> "Baseline":
        return cls(entries={f.fingerprint: f.to_json() for f in findings})

    @classmethod
    def load(cls, path: str) -> "Baseline":
        if not os.path.exists(path):
            return cls()
        with open(path, "r", encoding="utf-8") as fh:
            raw = json.load(fh)
        if raw.get("version") != BASELINE_VERSION:
            raise ValueError(
                f"{path}: unsupported baseline version {raw.get('version')!r}"
                f" (expected {BASELINE_VERSION})")
        return cls(entries={e["fingerprint"]: e for e in raw["findings"]})

    def save(self, path: str) -> None:
        rows = sorted(self.entries.values(),
                      key=lambda e: (e["path"], e["line"], e["rule"]))
        payload = {"version": BASELINE_VERSION, "findings": rows}
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")

    def diff(self, findings: List[Finding]
             ) -> Tuple[List[Finding], List[Finding], List[Dict]]:
        """-> (new, suppressed, stale_entries)."""
        fresh = {f.fingerprint: f for f in findings}
        new = [f for fp, f in fresh.items() if fp not in self.entries]
        suppressed = [f for fp, f in fresh.items() if fp in self.entries]
        stale = [e for fp, e in self.entries.items() if fp not in fresh]
        order = lambda f: (f.path, f.line, f.rule)  # noqa: E731
        return (sorted(new, key=order), sorted(suppressed, key=order),
                sorted(stale, key=lambda e: (e["path"], e["line"])))
