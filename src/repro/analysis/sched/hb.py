"""Happens-before model of one engine run.

A wall-clock run is a sequence of events — report deliveries, server
updates (applies), dual updates, round boundaries — whose *processing*
order is one of many legal linearizations: simultaneous arrivals could
have been delivered in any order. This module reconstructs the partial
order that is actually forced by the physics:

    time        e1 -> e2 when e1's clock reading is strictly earlier
    per client  a client's deliveries are sequenced (one device)
    rounds      round_start(r) -> every event of r -> round_end(r) ->
                round_start(r+1)
    causality   a delivery -> the apply that folded its report;
                an apply -> the round's dual update

Everything the partial order leaves *unordered* is schedule freedom:
the engine had to pick an order (``TimedReport.sort_key``), and any
state both events touch had better not care. ``HBGraph.races`` checks
exactly that: an unordered pair touching the same aggregator/strategy
state is benign only under the aggregator's declared ``commutativity``
certificate ("exact" / "canonical" / "tiebreak" — see
``repro.fl.aggregator``); an undeclared policy is flagged as a race.

The event stream comes from two sources merged by clock position:
``SimClock``'s event log (deliveries — the engine labels them
``deliver:c<id>``) and a ``ScheduleRecorder`` callback (round
boundaries, applies, dual updates, which the clock log does not
attribute). The ``SchedulePermuter`` (sibling module) is the dynamic
complement: it *exercises* the schedule freedom this model identifies.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.fl.callbacks import RoundCallback

#: which shared state each event kind's handler touches
_TOUCHES: Dict[str, Tuple[str, ...]] = {
    "deliver": ("aggregator",),
    "apply": ("aggregator", "params"),
    "dual": ("duals",),
    "round_start": (),
    "round_end": (),
}


@dataclass(frozen=True)
class SchedEvent:
    """One event of a recorded run, in processing order."""

    kind: str            # deliver | apply | dual | round_start | round_end
    round: int
    time: float          # clock reading when processed (monotone)
    index: int           # global processing position
    client: int = -1     # deliver: the reporting client
    clients: Tuple[int, ...] = ()   # apply: clients folded in

    @property
    def touches(self) -> Tuple[str, ...]:
        return _TOUCHES.get(self.kind, ())

    def __str__(self) -> str:
        who = (f" c{self.client}" if self.client >= 0 else
               (f" {list(self.clients)}" if self.clients else ""))
        return (f"[{self.index}] r{self.round} t={self.time:.4f} "
                f"{self.kind}{who}")


@dataclass(frozen=True)
class SchedRace:
    """Two HB-unordered events touching the same state."""

    a: SchedEvent
    b: SchedEvent
    state: Tuple[str, ...]
    certified: bool
    via: str             # the certificate (or why it is missing)

    def describe(self) -> str:
        verdict = ("certified: " + self.via if self.certified
                   else "RACE: " + self.via)
        return (f"{self.a} || {self.b} on {'/'.join(self.state)} "
                f"({verdict})")


class ScheduleRecorder(RoundCallback):
    """Records the run-side events the clock log cannot attribute.

    Each marker remembers ``clock.event_count`` at hook time, so the
    markers interleave with the clock's delivery events by position —
    not by timestamp, which would lose the processing order of
    time-equal events."""

    def __init__(self):
        self.markers: List[Tuple[int, str, int, float, Tuple[int, ...]]] = []
        self._round = 0

    def on_train_start(self, engine: Any) -> None:
        self.markers = []
        self._round = 0

    def _mark(self, engine: Any, kind: str, rnd: int,
              clients: Tuple[int, ...] = ()) -> None:
        clock = engine.clock
        self.markers.append((clock.event_count, kind, rnd,
                             float(clock.now), clients))

    def on_round_start(self, engine: Any, rnd: int) -> None:
        self._round = rnd
        self._mark(engine, "round_start", rnd)

    def on_server_update(self, engine: Any, update: Any) -> None:
        self._mark(engine, "apply", update.round,
                   tuple(r.client.client_id for r in update.reports))

    def on_dual_update(self, engine: Any, rnd: int,
                       creports: Any) -> None:
        self._mark(engine, "dual", rnd)

    def on_round_end(self, engine: Any, record: Any) -> None:
        self._mark(engine, "round_end", record.round)

    # ------------------------------------------------------------------
    def events(self, engine: Any) -> List[SchedEvent]:
        """Merge the clock's delivery log with the recorded markers
        into the full processing-ordered event stream."""
        clock = engine.clock
        if clock is None:
            return []
        if clock.event_count != len(clock.events):
            raise ValueError(
                f"SimClock log truncated ({clock.event_count} events, "
                f"{len(clock.events)} kept) — raise max_events to "
                f"analyze this run")
        out: List[SchedEvent] = []
        mi = 0
        rnd = 0

        def flush_markers(upto: int) -> None:
            nonlocal mi, rnd
            while mi < len(self.markers) and self.markers[mi][0] <= upto:
                _, kind, mrnd, mtime, clients = self.markers[mi]
                if kind == "round_start":
                    rnd = mrnd
                out.append(SchedEvent(kind=kind, round=mrnd, time=mtime,
                                      index=len(out), clients=clients))
                mi += 1

        for ci, (label, _requested, after) in enumerate(clock.events):
            flush_markers(ci)
            if label.startswith("deliver:c"):
                out.append(SchedEvent(kind="deliver", round=rnd,
                                      time=float(after), index=len(out),
                                      client=int(label[len("deliver:c"):])))
            # round_end clock ticks are covered by the recorder marker
        flush_markers(len(clock.events))
        return out


def build_hb_graph(engine: Any,
                   recorder: ScheduleRecorder) -> "HBGraph":
    return HBGraph(recorder.events(engine))


@dataclass
class HBGraph:
    """The happens-before partial order over a recorded event stream.

    Events are in processing order and times are monotone in that
    order, so every edge points forward and the closure is one
    backward sweep over successor bitsets."""

    events: List[SchedEvent]
    _closure: List[int] = field(default_factory=list, repr=False)

    def __post_init__(self):
        n = len(self.events)
        direct = [0] * n
        ev = self.events

        def edge(i: int, j: int) -> None:
            if i != j:
                direct[i] |= 1 << j

        last_by_client: Dict[int, int] = {}
        last_round_start: Dict[int, int] = {}
        for j, e in enumerate(ev):
            # strict time order: anything at an earlier clock reading
            # happened before. Times are monotone in processing order,
            # so it suffices to link j to every member of the nearest
            # strictly-earlier time plateau (that plateau links to the
            # one before it, and the closure does the rest); events on
            # j's own plateau stay unordered unless another rule
            # sequences them — that is the schedule freedom.
            i = j - 1
            while i >= 0 and ev[i].time >= e.time:
                i -= 1
            if i >= 0:
                plateau = ev[i].time
                while i >= 0 and ev[i].time == plateau:
                    edge(i, j)
                    i -= 1
            if e.kind == "round_start":
                last_round_start[e.round] = j
                # previous round's end precedes
                for i in range(j - 1, -1, -1):
                    if ev[i].kind == "round_end":
                        edge(i, j)
                        break
            else:
                if e.round in last_round_start:
                    edge(last_round_start[e.round], j)
            if e.kind == "deliver":
                if e.client in last_by_client:
                    edge(last_by_client[e.client], j)
                last_by_client[e.client] = j
            if e.kind == "apply":
                members = set(e.clients)
                for i in range(j - 1, -1, -1):
                    if ev[i].kind == "deliver" and ev[i].client in members:
                        edge(i, j)
                        members.discard(ev[i].client)
                        if not members:
                            break
            if e.kind in ("dual", "round_end"):
                for i in range(j - 1, -1, -1):
                    if ev[i].round != e.round:
                        break
                    if ev[i].kind == "apply":
                        edge(i, j)
            if e.kind == "round_end":
                for i in range(j - 1, -1, -1):
                    if ev[i].round != e.round:
                        break
                    edge(i, j)
        closure = [0] * n
        for i in range(n - 1, -1, -1):
            acc = direct[i]
            m = direct[i]
            while m:
                jbit = m & -m
                acc |= closure[jbit.bit_length() - 1]
                m ^= jbit
            closure[i] = acc
        self._closure = closure

    def happens_before(self, i: int, j: int) -> bool:
        return bool((self._closure[i] >> j) & 1)

    def unordered_pairs(self) -> List[Tuple[SchedEvent, SchedEvent]]:
        """Every pair the partial order does not sequence — the
        schedule freedom of the run."""
        out: List[Tuple[SchedEvent, SchedEvent]] = []
        for i in range(len(self.events)):
            for j in range(i + 1, len(self.events)):
                if not self.happens_before(i, j) \
                        and not self.happens_before(j, i):
                    out.append((self.events[i], self.events[j]))
        return out

    def races(self, commutativity: Optional[str],
              tie_broken: bool = True) -> List[SchedRace]:
        """Unordered pairs touching shared state, judged against the
        aggregator's commutativity certificate.

        ``tie_broken`` says the engine linearized ties through a total
        order (``TimedReport.sort_key`` — always true for
        ``FederatedEngine``); "tiebreak" certificates rely on it."""
        out: List[SchedRace] = []
        for a, b in self.unordered_pairs():
            shared = tuple(s for s in a.touches if s in b.touches)
            if not shared:
                continue
            if commutativity in ("exact", "canonical"):
                cert, via = True, (
                    f"aggregator folds are {commutativity} "
                    f"(order-free over the report set)")
            elif commutativity == "tiebreak" and tie_broken:
                cert, via = True, (
                    "buffer composition is delivery-ordered but the "
                    "engine tie-breaks into a total order "
                    "(TimedReport.sort_key)")
            elif commutativity == "tiebreak":
                cert, via = False, (
                    "tiebreak certificate requires a total event "
                    "order, but the schedule leaves ties unresolved")
            else:
                cert, via = False, (
                    "aggregator declares no commutativity certificate")
            out.append(SchedRace(a=a, b=b, state=shared,
                                 certified=cert, via=via))
        return out
