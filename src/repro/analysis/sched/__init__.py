"""``repro.analysis.sched`` — schedule-determinism analysis.

Third pillar of the analysis stack (PR 8 AST rules, PR 9 trace rules):
prove round results are invariant under the event schedule, or flag
where they are not.

    rules     static SCHED001-004 (registered into the shared engine)
    hb        happens-before model over a recorded run: partial order
              of report -> delivery -> apply -> dual events, plus the
              race checker (HB-unordered events touching shared
              aggregator/strategy state must be commutative-certified)
    permute   the runtime sanitizer: ``SchedulePermuter`` replays a
              run under adversarial legal schedule permutations and
              asserts bit-identical (or tolerance-banded) results
    gate      ``run_sched`` — the ``--sched`` CLI/CI entry point

This module keeps imports light: the static rules are importable
without jax; ``hb``/``permute``/``gate`` pull in the model stack and
are loaded lazily on first attribute access.
"""
from __future__ import annotations

from typing import List

from repro.analysis.sched.rules import (  # noqa: F401
    OrderSensitiveReportFold, SharedComponentRNG, SCHED_RULE_IDS,
    UnorderedContainerIteration, UntiedTimestampOrder,
)

_LAZY = {
    "HBGraph": "repro.analysis.sched.hb",
    "SchedEvent": "repro.analysis.sched.hb",
    "SchedRace": "repro.analysis.sched.hb",
    "ScheduleRecorder": "repro.analysis.sched.hb",
    "build_hb_graph": "repro.analysis.sched.hb",
    "AdversarialTieQueue": "repro.analysis.sched.permute",
    "PermutationReport": "repro.analysis.sched.permute",
    "SchedulePermuter": "repro.analysis.sched.permute",
    "ScheduleSanitizerCallback": "repro.analysis.sched.permute",
    "run_signature": "repro.analysis.sched.permute",
    "SchedReport": "repro.analysis.sched.gate",
    "format_sched_report": "repro.analysis.sched.gate",
    "run_sched": "repro.analysis.sched.gate",
}


def __getattr__(name: str):
    target = _LAZY.get(name)
    if target is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(target), name)


def __dir__() -> List[str]:
    return sorted(list(globals()) + list(_LAZY))
