"""SCHED rules: static schedule-determinism analysis of the fl
control plane.

The event-driven engine admits many legal delivery orders for the same
physical run (simultaneous arrivals, buffered fills, churn). The
determinism contract (``repro.fl.aggregator`` module docstring) says
round results must be a function of the report *set*, never of the
delivery schedule. These rules flag the code shapes that break it:

    SCHED001  order-sensitive float folds over client-report buffers
              (float + is not associative; fold in canonical order)
    SCHED002  iteration over unordered containers feeding round
              composition (set iteration order is salted per process;
              dict order is insertion = delivery order)
    SCHED003  event ordering on a bare timestamp (simultaneous
              arrivals compare equal -> the sort is schedule-dependent;
              tie-break like ``TimedReport.sort_key``)
    SCHED004  RNG streams owned by components instead of threaded by
              the engine (draw order then depends on the call schedule)

All four are scoped to the control-plane modules (``fl/clock.py``,
``fl/aggregator.py``, ``fl/engine.py``, ``fl/dynamics.py``) — the only
places delivery order exists. The runtime counterpart (the
happens-before checker + ``SchedulePermuter``) lives in the sibling
modules; together they are the machine-checked side of the contract.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Set

from repro.analysis.engine import (ModuleRule, ParsedModule, call_name,
                                   dotted_name, register_rule)
from repro.analysis.findings import Finding

#: where delivery order exists: the four control-plane modules
SCHED_PATHS = ("src/repro/fl/clock.py", "src/repro/fl/aggregator.py",
               "src/repro/fl/engine.py", "src/repro/fl/dynamics.py")
#: where client reports are folded into floats
FOLD_PATHS = ("src/repro/fl/aggregator.py", "src/repro/fl/engine.py")

#: names that hold buffered client reports (the things whose order is
#: a delivery schedule, not a property of the round)
_BUFFERISH = re.compile(r"^_?(reports?|buf(fer(ed)?)?|reporters|pending|"
                        r"inbox)$")
#: containers whose iteration order tracks the delivery schedule
_UNORDEREDISH = re.compile(r"pending|busy|in_flight|inbox|buf")
#: single-attribute sort keys that are timestamps (ties possible)
_TIMEISH = frozenset({"arrival", "arrival_time", "time", "timestamp",
                      "t", "t_end", "due", "finish", "finish_time"})
#: order-sensitive float reductions (math.fsum is order-robust enough
#: to exempt; np.stack/concatenate preserve order rather than fold)
_FOLDS = frozenset({"sum", "np.mean", "np.sum", "np.average",
                    "numpy.mean", "numpy.sum", "numpy.average",
                    "jnp.mean", "jnp.sum", "statistics.mean"})
_FOLD_METHODS = ("_combine", "aggregate")
#: canonicalizers: a name (re)assigned through one of these holds a
#: schedule-independent ordering
_CANONICALIZERS = frozenset({"canonical_order", "sorted"})
_RNG_CTORS = frozenset({"np.random.default_rng", "numpy.random.default_rng",
                        "np.random.RandomState", "numpy.random.RandomState",
                        "np.random.Generator", "numpy.random.Generator"})
_RNG_SINGLETON = re.compile(
    r"^(np|numpy)\.random\.(random|random_sample|rand|randn|randint|"
    r"choice|shuffle|permutation|normal|uniform|integers|standard_normal|"
    r"binomial|exponential)$")


def _terminal(node: ast.AST) -> str:
    """The rightmost name of a load: ``reports`` -> reports,
    ``self._buf`` -> _buf, anything else -> ''."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return ""


def _iter_names(node: ast.AST) -> Set[str]:
    """Terminal names iterated by an ``iter`` expression; looks through
    order-preserving wrappers (zip/enumerate/reversed/list/tuple)."""
    if isinstance(node, ast.Call) and call_name(node) in (
            "zip", "enumerate", "reversed", "list", "tuple"):
        out: Set[str] = set()
        for arg in node.args:
            out |= _iter_names(arg)
        return out
    name = _terminal(node)
    return {name} if name else set()


def _scopes(tree: ast.Module) -> Iterable[ast.AST]:
    """Module body + every function, like the JAX dataflow rules: name
    bindings are tracked per scope, not across the file."""
    yield tree
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _scope_statements(scope: ast.AST) -> List[ast.stmt]:
    return list(getattr(scope, "body", []))


def _walk_scope(scope: ast.AST) -> Iterable[ast.AST]:
    """Walk a scope without descending into nested functions (they are
    their own scopes and would otherwise be scanned twice)."""
    stack: List[ast.AST] = [
        s for s in _scope_statements(scope)
        if not isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef))]
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            stack.append(child)


def _canonical_names(scope: ast.AST) -> Set[str]:
    """Names assigned (anywhere in the scope) from ``canonical_order``
    or ``sorted`` — their iteration order is schedule-independent."""
    out: Set[str] = set()
    for node in _walk_scope(scope):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            if call_name(node.value) in _CANONICALIZERS:
                for tgt in node.targets:
                    name = _terminal(tgt)
                    if name:
                        out.add(name)
    return out


@register_rule
class OrderSensitiveReportFold(ModuleRule):
    """SCHED001: float folds over buffered client reports in delivery
    order. Float addition reassociates differently under every
    schedule permutation; the applied update / accounting then depends
    on *when* reports arrived, not just *which* arrived."""

    id = "SCHED001"
    title = "order-sensitive float fold over client reports"
    rationale = ("float folds are not associative: summing a report "
                 "buffer in delivery order makes round results a "
                 "function of the event schedule, which breaks the "
                 "determinism contract FedBuff-style async relies on")
    hint = ("fold in canonical report order (canonical_order / "
            "report_order_key) or use an exact representation (the "
            "uint64 masked sum is order-free mod 2^64)")
    paths = FOLD_PATHS

    def check_module(self, mod: ParsedModule) -> List[Finding]:
        findings: List[Finding] = []
        for scope in _scopes(mod.tree):
            findings.extend(self._check_scope(mod, scope))
        return findings

    def _is_fold(self, call: ast.Call) -> bool:
        name = call_name(call)
        if name in _FOLDS:
            return True
        return any(name == m or name.endswith("." + m)
                   for m in _FOLD_METHODS)

    def _check_scope(self, mod: ParsedModule,
                     scope: ast.AST) -> List[Finding]:
        canonical = _canonical_names(scope)
        # names assigned from a comprehension -> the buffers it iterated
        comp_sources: Dict[str, Set[str]] = {}
        for node in _walk_scope(scope):
            if isinstance(node, ast.Assign) and isinstance(
                    node.value, (ast.ListComp, ast.GeneratorExp)):
                srcs: Set[str] = set()
                for gen in node.value.generators:
                    srcs |= _iter_names(gen.iter)
                for tgt in node.targets:
                    name = _terminal(tgt)
                    if name:
                        comp_sources[name] = srcs

        def bad_buffers(names: Set[str]) -> Set[str]:
            return {n for n in names
                    if _BUFFERISH.match(n) and n not in canonical}

        findings: List[Finding] = []
        for node in _walk_scope(scope):
            if isinstance(node, ast.Call) and self._is_fold(node):
                for arg in node.args:
                    if isinstance(arg, (ast.ListComp, ast.GeneratorExp)):
                        names: Set[str] = set()
                        for gen in arg.generators:
                            names |= _iter_names(gen.iter)
                    elif isinstance(arg, ast.Name):
                        names = comp_sources.get(arg.id, set())
                    else:
                        continue
                    for buf in sorted(bad_buffers(names)):
                        findings.append(self.make_finding(
                            mod, node,
                            f"{call_name(node)}() folds report buffer "
                            f"'{buf}' in delivery order"))
            elif isinstance(node, ast.For):
                bufs = bad_buffers(_iter_names(node.iter))
                if not bufs:
                    continue
                for stmt in ast.walk(node):
                    if isinstance(stmt, ast.AugAssign):
                        findings.append(self.make_finding(
                            mod, stmt,
                            f"accumulation inside a loop over report "
                            f"buffer '{sorted(bufs)[0]}' folds in "
                            f"delivery order"))
        return findings


@register_rule
class UnorderedContainerIteration(ModuleRule):
    """SCHED002: round composition iterating a set (per-process salted
    order) or a schedule-tracking dict (insertion order = delivery
    order) without sorting first."""

    id = "SCHED002"
    title = "iteration over unordered container in round composition"
    rationale = ("set iteration order varies across processes and dict "
                 "order is insertion order — for busy/pending maps that "
                 "IS the delivery schedule, so anything composed from "
                 "such an iteration depends on it")
    hint = "iterate sorted(...) (any total order will do)"
    paths = SCHED_PATHS

    def check_module(self, mod: ParsedModule) -> List[Finding]:
        findings: List[Finding] = []
        for scope in _scopes(mod.tree):
            findings.extend(self._check_scope(mod, scope))
        return findings

    def _check_scope(self, mod: ParsedModule,
                     scope: ast.AST) -> List[Finding]:
        canonical = _canonical_names(scope)
        set_names: Set[str] = set()
        sorted_comps: Set[int] = set()
        for node in _walk_scope(scope):
            value = None
            if isinstance(node, ast.Assign):
                value, targets = node.value, node.targets
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                value, targets = node.value, [node.target]
            if value is not None:
                is_set = isinstance(value, (ast.Set, ast.SetComp)) or (
                    isinstance(value, ast.Call)
                    and call_name(value) in ("set", "frozenset"))
                if is_set:
                    for tgt in targets:
                        name = _terminal(tgt)
                        if name:
                            set_names.add(name)
            if isinstance(node, ast.Call) and call_name(node) == "sorted":
                for arg in node.args:
                    if isinstance(arg, (ast.GeneratorExp, ast.ListComp,
                                        ast.SetComp)):
                        sorted_comps.add(id(arg))

        def check_iter(it: ast.AST, where: ast.AST) -> None:
            name = _terminal(it)
            if (isinstance(it, ast.Name) and name in set_names
                    and name not in canonical):
                findings.append(self.make_finding(
                    mod, where,
                    f"iteration over set '{name}' (per-process order)"))
            elif (isinstance(it, ast.Call)
                  and isinstance(it.func, ast.Attribute)
                  and it.func.attr in ("keys", "values", "items")):
                owner = _terminal(it.func.value)
                if _UNORDEREDISH.search(owner) and owner not in canonical:
                    findings.append(self.make_finding(
                        mod, where,
                        f"iteration over {owner}.{it.func.attr}() "
                        f"(insertion order = delivery order)"))

        findings: List[Finding] = []
        for node in _walk_scope(scope):
            if isinstance(node, ast.For):
                check_iter(node.iter, node)
            elif isinstance(node, (ast.GeneratorExp, ast.ListComp,
                                   ast.SetComp, ast.DictComp)):
                if id(node) in sorted_comps:
                    continue
                for gen in node.generators:
                    check_iter(gen.iter, node)
        return findings


@register_rule
class UntiedTimestampOrder(ModuleRule):
    """SCHED003: ordering events by a bare timestamp. Simultaneous
    arrivals compare equal, so the resulting order is whatever the
    input order was — i.e. the schedule leaks through the sort."""

    id = "SCHED003"
    title = "timestamp ordering without a total-order tie-break"
    rationale = ("a key like `lambda e: e.arrival` leaves simultaneous "
                 "events tied; stable sorts then preserve delivery "
                 "order, making downstream folds schedule-dependent")
    hint = ("tie-break into a total order, like TimedReport.sort_key's "
            "(arrival, tie, seq) or report_order_key's "
            "(round, arrival, client_id)")
    paths = SCHED_PATHS

    def check_module(self, mod: ParsedModule) -> List[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            is_order = name in ("sorted", "min", "max") or (
                isinstance(node.func, ast.Attribute)
                and node.func.attr == "sort")
            if not is_order:
                continue
            for kw in node.keywords:
                if kw.arg != "key":
                    continue
                attr = self._single_time_attr(kw.value)
                if attr:
                    findings.append(self.make_finding(
                        mod, node,
                        f"{name or 'sort'}() orders by bare timestamp "
                        f"'.{attr}' — simultaneous events stay tied"))
        return findings

    @staticmethod
    def _single_time_attr(key: ast.AST) -> str:
        if isinstance(key, ast.Lambda) and isinstance(key.body,
                                                      ast.Attribute):
            if key.body.attr in _TIMEISH:
                return key.body.attr
        if isinstance(key, ast.Call) and dotted_name(key.func) in (
                "attrgetter", "operator.attrgetter"):
            if len(key.args) == 1 and isinstance(key.args[0], ast.Constant):
                val = key.args[0].value
                if isinstance(val, str) and val in _TIMEISH:
                    return val
        return ""


@register_rule
class SharedComponentRNG(ModuleRule):
    """SCHED004: RNG streams owned by control-plane components. The
    engine threads ONE generator through the loop in a fixed call
    order; a component that keeps its own stream (or draws from the
    numpy global singleton, or seeds from entropy) makes draw order —
    and therefore sampling — depend on the event schedule."""

    id = "SCHED004"
    title = "component-owned / unseeded RNG stream"
    rationale = ("the engine's determinism rests on one rng threaded "
                 "in a fixed order; component-held generators and "
                 "global-singleton draws resequence under schedule "
                 "permutation, and unseeded generators differ per run")
    hint = ("accept the engine's rng as a parameter, or derive a "
            "per-call generator from explicit keys "
            "(np.random.default_rng([seed, round, ...]))")
    paths = SCHED_PATHS

    def check_module(self, mod: ParsedModule) -> List[Finding]:
        findings: List[Finding] = []
        for stmt in mod.tree.body:
            if isinstance(stmt, ast.Assign) and isinstance(
                    stmt.value, ast.Call) and \
                    call_name(stmt.value) in _RNG_CTORS:
                findings.append(self.make_finding(
                    mod, stmt,
                    "module-level RNG is shared by every component "
                    "that imports it"))
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if _RNG_SINGLETON.match(name):
                findings.append(self.make_finding(
                    mod, node,
                    f"{name}() draws from the process-global RNG "
                    f"singleton"))
            if name in _RNG_CTORS and not node.args and not node.keywords:
                findings.append(self.make_finding(
                    mod, node,
                    f"{name}() without a seed draws entropy — runs "
                    f"are not replayable"))
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign) and isinstance(
                    node.value, ast.Call) and \
                    call_name(node.value) in _RNG_CTORS:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Attribute) and isinstance(
                            tgt.value, ast.Name) and tgt.value.id == "self":
                        findings.append(self.make_finding(
                            mod, node,
                            f"RNG stored on component state "
                            f"(self.{tgt.attr}); draw order then "
                            f"depends on the call schedule"))
        return findings


SCHED_RULE_IDS = ("SCHED001", "SCHED002", "SCHED003", "SCHED004")
