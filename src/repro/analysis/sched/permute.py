"""The runtime schedule sanitizer: replay a run under adversarial
legal schedules and prove the results don't move.

``TimedReport.sort_key`` is ``(arrival, tie, seq)`` with ``tie = 0.0``
in production, so the engine resolves simultaneous arrivals by
stamping order. ``AdversarialTieQueue`` stamps seeded pseudo-random
ties instead: every ordering it produces still respects every arrival
time — it is a *legal* schedule — but simultaneous arrivals deliver in
a different order each seed. ``SchedulePermuter`` replays one engine
configuration under K such schedules and compares ``RoundRecord``
streams, dual trajectories and final params against the production
schedule:

    mode="exact"      bit-for-bit (deterministic aggregators: the
                      "exact"/"canonical" certificates, and FedBuff
                      scenarios whose tie groups align with its fills)
    mode="tolerance"  within declared bands (staleness-weighted paths
                      where a permutation legitimately changes *which*
                      round a tied report lands in)

``ScheduleSanitizerCallback`` is the always-on flavour: it records the
run (``ScheduleRecorder``), builds the happens-before graph at
``on_train_end`` and raises on any uncertified race — wire it like
PR 8's runtime guards:

    engine = FederatedEngine(..., callbacks=[ScheduleSanitizerCallback()])
"""
from __future__ import annotations

import copy
import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.analysis.sched.hb import (HBGraph, SchedRace, ScheduleRecorder,
                                     build_hb_graph)
from repro.fl.clock import EventQueue, TimedReport

#: stream domain separator for tie draws (vs every other seeded rng)
_TIE_STREAM = 0x5CED


@dataclass
class AdversarialTieQueue(EventQueue):
    """An ``EventQueue`` that stamps seeded pseudo-random tie-breaks.

    The tie only reorders *equal-arrival* events (it sits between
    ``arrival`` and ``seq`` in the sort key), so every schedule this
    queue produces is a legal linearization of the same physical run.
    Draws key on ``(stream, seed, event seq)`` — no state is shared
    with any other rng, and the schedule is replayable per seed."""

    seed: int = 0

    def stamp(self, arrival: float, report: Any) -> TimedReport:
        ev = super().stamp(arrival, report)
        rng = np.random.default_rng([_TIE_STREAM, self.seed, ev.seq])
        return dataclasses.replace(ev, tie=float(rng.random()))


# ---------------------------------------------------------------------------
# run signatures
# ---------------------------------------------------------------------------

#: RoundRecord fields compared bit-for-bit (or within bands): the
#: accounting the determinism contract covers
_ROUND_FLOATS = ("val_loss", "train_loss", "wire_mb_actual", "energy_true",
                 "mean_staleness", "sim_time", "round_seconds")
_ROUND_INTS = ("updates_applied", "reports_applied", "num_available")


def run_signature(result: Any) -> Dict[str, Any]:
    """Everything a schedule permutation must leave invariant, pulled
    from one ``FLResult``. ``participant_order`` is delivery-order
    telemetry — excluded from comparison, but used to prove a
    permutation actually reordered something."""
    rounds: List[Dict[str, Any]] = []
    for r in result.history:
        rounds.append({
            "round": int(r.round),
            **{k: float(getattr(r, k)) for k in _ROUND_FLOATS},
            **{k: int(getattr(r, k)) for k in _ROUND_INTS},
            "usage": {k: float(v) for k, v in r.usage.items()},
            "ratios": {k: float(v) for k, v in r.ratios.items()},
            "duals": {k: float(v) for k, v in r.duals.items()},
            "knobs": dict(r.knobs),
            "participants": frozenset(r.participants),
            "participant_order": tuple(r.participants),
            "dropped": frozenset(r.dropped),
        })
    leaves = [np.asarray(leaf) for leaf in
              jax.tree.leaves(result.final_params)]
    return {"rounds": rounds, "final": leaves}


def _cmp_float(key: str, a: float, b: float, exact: bool,
               rtol: float, atol: float) -> Optional[str]:
    if exact:
        if not (a == b or (np.isnan(a) and np.isnan(b))):
            return f"{key}: {a!r} != {b!r} (bit-exact required)"
    elif not np.isclose(a, b, rtol=rtol, atol=atol, equal_nan=True):
        return f"{key}: {a!r} vs {b!r} outside rtol={rtol} atol={atol}"
    return None


def compare_signatures(base: Dict[str, Any], other: Dict[str, Any],
                       mode: str = "exact", rtol: float = 1e-6,
                       atol: float = 1e-8) -> List[str]:
    """Mismatch descriptions between two run signatures ([] = match).
    Integers, sets and knob dicts are compared exactly in every mode;
    ``mode`` only relaxes the float fields and the final params."""
    assert mode in ("exact", "tolerance"), mode
    exact = mode == "exact"
    out: List[str] = []
    if len(base["rounds"]) != len(other["rounds"]):
        return [f"round count: {len(base['rounds'])} != "
                f"{len(other['rounds'])}"]
    for ra, rb in zip(base["rounds"], other["rounds"]):
        where = f"round {ra['round']}"
        for k in ("round",) + _ROUND_INTS:
            if ra[k] != rb[k]:
                out.append(f"{where}.{k}: {ra[k]} != {rb[k]}")
        for k in ("participants", "dropped", "knobs"):
            if ra[k] != rb[k]:
                out.append(f"{where}.{k}: {ra[k]!r} != {rb[k]!r}")
        for k in _ROUND_FLOATS:
            bad = _cmp_float(f"{where}.{k}", ra[k], rb[k], exact,
                             rtol, atol)
            if bad:
                out.append(bad)
        for grp in ("usage", "ratios", "duals"):
            if set(ra[grp]) != set(rb[grp]):
                out.append(f"{where}.{grp} keys: {sorted(ra[grp])} != "
                           f"{sorted(rb[grp])}")
                continue
            for k in ra[grp]:
                bad = _cmp_float(f"{where}.{grp}[{k}]", ra[grp][k],
                                 rb[grp][k], exact, rtol, atol)
                if bad:
                    out.append(bad)
    if len(base["final"]) != len(other["final"]):
        out.append(f"final params: {len(base['final'])} leaves != "
                   f"{len(other['final'])}")
        return out
    for i, (la, lb) in enumerate(zip(base["final"], other["final"])):
        if la.shape != lb.shape or la.dtype != lb.dtype:
            out.append(f"final leaf {i}: shape/dtype "
                       f"{la.shape}/{la.dtype} != {lb.shape}/{lb.dtype}")
        elif exact and la.tobytes() != lb.tobytes():
            out.append(f"final leaf {i}: bits differ "
                       f"(max abs diff {np.max(np.abs(la - lb)):g})")
        elif not exact and not np.allclose(la, lb, rtol=rtol, atol=atol):
            out.append(f"final leaf {i}: max abs diff "
                       f"{np.max(np.abs(la - lb)):g} outside bands")
    return out


# ---------------------------------------------------------------------------
# the permuter
# ---------------------------------------------------------------------------


@dataclass
class PermutationReport:
    """What ``SchedulePermuter.run`` proved (or failed to)."""

    permutations: int
    mode: str
    #: rounds whose delivery order actually changed, per permutation —
    #: all zeros means the test was vacuous (no ties to permute)
    swapped: List[int] = field(default_factory=list)
    mismatches: List[str] = field(default_factory=list)
    problems: List[str] = field(default_factory=list)

    @property
    def total_swapped(self) -> int:
        return sum(self.swapped)

    def ok(self) -> bool:
        return not self.mismatches and not self.problems

    def to_json(self) -> Dict[str, Any]:
        return {"permutations": self.permutations, "mode": self.mode,
                "swapped": list(self.swapped),
                "total_swapped": self.total_swapped,
                "mismatches": list(self.mismatches),
                "problems": list(self.problems), "ok": self.ok()}


class SchedulePermuter:
    """Replay one engine under K adversarial legal schedules.

    The engine is reused across replays — the runner/executor cache
    means every replay after the first pays zero jit compilation — but
    two pieces of engine state deliberately *continue* across ``run()``
    calls and must be rewound per replay: the per-client batch streams
    (``FederatedData.reset_rngs``) and the strategy's dual multipliers
    (``init_duals`` warm continuation — replays run a deepcopy of the
    pristine strategy; the caller's strategy object is restored
    untouched). A production-schedule double run guards the comparison
    first: if two identical replays differ, the nondeterminism is not
    the schedule's fault and every permutation verdict would be noise.

    ``mode`` defaults from the aggregator's commutativity certificate:
    "exact"/"canonical" compare bit-for-bit, "tiebreak" within bands
    (a permutation may legally move a tied report across a buffer
    fill). Pass ``mode="exact"`` explicitly for tiebreak scenarios
    constructed so tie groups align with fills. ``run_kwargs`` must
    select ``time_mode="wall_clock"`` — ties only exist on the event
    queue."""

    def __init__(self, engine: Any, permutations: int = 8,
                 seed: int = 0,
                 mode: Optional[str] = None, rtol: float = 1e-6,
                 atol: float = 1e-8,
                 run_kwargs: Optional[Dict[str, Any]] = None):
        assert permutations >= 1
        self.engine = engine
        self.permutations = permutations
        self.seed = seed
        cert = engine.aggregator.commutativity
        self.mode = mode if mode is not None else (
            "tolerance" if cert == "tiebreak" else "exact")
        self.rtol, self.atol = rtol, atol
        self.run_kwargs = dict(run_kwargs or {})
        self.run_kwargs.setdefault("time_mode", "wall_clock")

    def _signature(self, pristine: Any) -> Dict[str, Any]:
        # rewind the run state that intentionally continues across
        # run() calls, so every replay is the same physical run and any
        # difference is the schedule's
        self.engine.data.reset_rngs()
        self.engine.strategy = copy.deepcopy(pristine)
        return run_signature(self.engine.run(**self.run_kwargs))

    def run(self) -> PermutationReport:
        eng = self.engine
        report = PermutationReport(permutations=self.permutations,
                                   mode=self.mode)
        prev_factory = eng.event_queue_factory
        prev_strategy = eng.strategy
        pristine = copy.deepcopy(eng.strategy)
        try:
            eng.event_queue_factory = None
            base = self._signature(pristine)
            for bad in compare_signatures(base, self._signature(pristine),
                                          "exact"):
                report.problems.append(f"rerun nondeterminism: {bad}")
            if report.problems:
                return report          # permutation verdicts would be noise
            for k in range(self.permutations):
                tie_seed = self.seed * 7919 + k + 1
                eng.event_queue_factory = (
                    lambda s=tie_seed: AdversarialTieQueue(seed=s))
                sig = self._signature(pristine)
                report.swapped.append(sum(
                    ra["participant_order"] != rb["participant_order"]
                    for ra, rb in zip(base["rounds"], sig["rounds"])))
                report.mismatches.extend(
                    f"perm {k}: {bad}" for bad in compare_signatures(
                        base, sig, self.mode, self.rtol, self.atol))
        finally:
            eng.event_queue_factory = prev_factory
            eng.strategy = prev_strategy
        return report


# ---------------------------------------------------------------------------
# the always-on sanitizer callback
# ---------------------------------------------------------------------------


class ScheduleRaceError(AssertionError):
    """An HB-unordered event pair touched shared state without a
    commutativity certificate."""


class ScheduleSanitizerCallback(ScheduleRecorder):
    """Record the run, build the happens-before graph at train end and
    check every unordered pair against the aggregator's commutativity
    certificate. ``strict=True`` (default) raises ``ScheduleRaceError``
    on an uncertified race; either way ``races`` / ``certified`` /
    ``graph`` stay inspectable after the run."""

    def __init__(self, strict: bool = True):
        super().__init__()
        self.strict = strict
        self.graph: Optional[HBGraph] = None
        self.races: List[SchedRace] = []
        self.certified: List[SchedRace] = []

    def on_train_end(self, engine: Any, result: Any) -> None:
        self.graph = build_hb_graph(engine, self)
        verdicts = self.graph.races(engine.aggregator.commutativity)
        self.races = [r for r in verdicts if not r.certified]
        self.certified = [r for r in verdicts if r.certified]
        if self.strict and self.races:
            lines = "\n  ".join(r.describe() for r in self.races[:8])
            raise ScheduleRaceError(
                f"{len(self.races)} schedule race(s): HB-unordered "
                f"events touch shared state without a commutativity "
                f"certificate\n  {lines}")


__all__: Sequence[str] = (
    "AdversarialTieQueue", "PermutationReport", "SchedulePermuter",
    "ScheduleRaceError", "ScheduleSanitizerCallback",
    "compare_signatures", "run_signature",
)
