"""The ``--sched`` gate: run the schedule sanitizer scenarios and
report races/mismatches as analysis findings.

Three scenarios exercise the determinism contract end to end on a
tiny model (one engine each, reused across replays so jit compiles
once):

    sync_ties       homogeneous cohort under the sync barrier — every
                    survivor arrives at the same instant, so the whole
                    cohort is one tie group; results must be
                    bit-identical under any tie resolution
    masked_shuffle  the same cohort shuffle through
                    ``MaskedSumAggregator(path="kernel")`` — the
                    uint64 masked fold is exact mod 2^64, so this must
                    be bit-identical *by construction*
    fedbuff_wall    3 rounds of wall-clock FedBuff over three device
                    classes (jitter 0): each class is a tie pair and
                    ``buffer_size=2`` aligns fills with tie groups, so
                    even the "tiebreak"-certified policy must hold
                    bit-for-bit under ≥8 adversarial permutations

Every replay runs under a ``ScheduleSanitizerCallback`` (strict=False)
so the happens-before race check rides along: an uncertified race
becomes a SCHED005 finding in the normal baseline stream; permutation
mismatches and vacuous permutations (nothing actually reordered — the
scenario stopped proving anything) are hard problems, like trace
problems: never baselinable."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.analysis.findings import Finding
from repro.analysis.sched.permute import (PermutationReport,
                                          SchedulePermuter,
                                          ScheduleSanitizerCallback)

#: rule id for runtime happens-before races (static rules own 001-004)
HB_RULE_ID = "SCHED005"
_HB_HINT = ("declare the aggregator's commutativity certificate "
            "(exact/canonical/tiebreak) and make it true — fold in "
            "canonical report order or an exact representation")


def _tiny_stack():
    """The shared scenario substrate: the same tiny charlm the fl
    integration tests use (2 layers, d_model 32, 6 clients)."""
    from repro.configs import get_config, get_fl_config
    from repro.data import load_corpus
    from repro.models import build

    ds = load_corpus(target_bytes=60_000)
    cfg = get_config("charlm-shakespeare").replace(
        vocab_size=max(ds.vocab_size, 64), num_layers=2, d_model=32,
        num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64)
    fl = get_fl_config().replace(
        rounds=3, num_clients=6, clients_per_round=3, s_base=3, b_base=8,
        seq_len=16, eval_batches=1, eval_batch_size=8)
    fl = fl.replace(duals=dataclasses.replace(fl.duals, s_min=2, b_min=4))
    return build(cfg), fl, ds


def _sync_ties(model, fl, ds, sanitizer):
    from repro.fl import FederatedEngine
    eng = FederatedEngine(model, fl, ds, strategy="cafl",
                          aggregator="sync", callbacks=[sanitizer])
    return eng, dict(permutations=4, mode="exact")


def _masked_shuffle(model, fl, ds, sanitizer):
    from repro.fl import FederatedEngine, MaskedSumAggregator
    eng = FederatedEngine(model, fl, ds, strategy="fedavg",
                          aggregator=MaskedSumAggregator(path="kernel"),
                          callbacks=[sanitizer])
    return eng, dict(permutations=4, mode="exact")


def _fedbuff_wall(model, fl, ds, sanitizer):
    from repro.fl import (DeadlineStragglers, FedBuffAggregator,
                          FederatedEngine, FleetClass, FleetDynamics,
                          UniformSampler, make_fleet)
    fl = fl.replace(clients_per_round=fl.num_clients)
    profiles, cp = make_fleet(fl, [
        FleetClass("fast", 1 / 3),
        FleetClass("mid", 1 / 3, compute_scale=1.5),
        FleetClass("slow", 1 / 3, compute_scale=2.0)])
    dyn = FleetDynamics(
        sampler=UniformSampler(fl.clients_per_round),
        stragglers=DeadlineStragglers.for_config(fl, deadline=10.0,
                                                 jitter=0.0))
    eng = FederatedEngine(model, fl, ds, strategy="cafl",
                          profiles=profiles, client_profiles=cp,
                          dynamics=dyn,
                          aggregator=FedBuffAggregator(buffer_size=2),
                          callbacks=[sanitizer])
    return eng, dict(permutations=8, mode="exact")


#: name -> builder(model, fl, ds, sanitizer) -> (engine, permuter kw)
SCENARIOS: Dict[str, Callable] = {
    "sync_ties": _sync_ties,
    "masked_shuffle": _masked_shuffle,
    "fedbuff_wall": _fedbuff_wall,
}


@dataclass
class SchedReport:
    """Everything one --sched run produced (mirrors TraceReport)."""

    scenarios: List[Dict[str, Any]] = field(default_factory=list)
    findings: List[Finding] = field(default_factory=list)
    problems: List[str] = field(default_factory=list)
    rules_run: List[str] = field(default_factory=list)

    def rows_json(self) -> List[Dict[str, Any]]:
        return list(self.scenarios)


def _race_finding(scenario: str, race: Any) -> Finding:
    return Finding(
        rule=HB_RULE_ID, path="src/repro/fl/aggregator.py", line=1,
        message=f"[{scenario}] schedule race: {race.describe()}",
        hint=_HB_HINT,
        snippet=f"{scenario}:{race.a.kind}|{race.b.kind}:"
                f"{'/'.join(race.state)}")


def run_scenario(name: str, model: Any, fl: Any, ds: Any,
                 permutations: Optional[int] = None
                 ) -> Tuple[Dict[str, Any], List[Finding], List[str]]:
    """Run one scenario; returns (json row, findings, problems)."""
    sanitizer = ScheduleSanitizerCallback(strict=False)
    eng, kw = SCENARIOS[name](model, fl, ds, sanitizer)
    if permutations is not None:
        kw["permutations"] = permutations
    permuter = SchedulePermuter(eng, run_kwargs={"time_mode": "wall_clock"},
                                **kw)
    perm: PermutationReport = permuter.run()
    races = list(sanitizer.races)           # from the last replay
    unordered = (len(sanitizer.graph.unordered_pairs())
                 if sanitizer.graph is not None else 0)
    row = {"scenario": name, "aggregator": eng.aggregator.name,
           "commutativity": eng.aggregator.commutativity,
           "unordered_pairs": unordered,
           "races_certified": len(sanitizer.certified),
           "races": len(races), **perm.to_json()}
    findings = [_race_finding(name, r) for r in races]
    problems = [f"[{name}] {p}" for p in perm.problems]
    problems += [f"[{name}] {m}" for m in perm.mismatches]
    if perm.total_swapped == 0:
        problems.append(
            f"[{name}] vacuous permutation: no round's delivery order "
            f"changed under {perm.permutations} adversarial tie "
            f"seeds — the scenario no longer exercises any schedule "
            f"freedom")
    return row, findings, problems


def run_sched(root: str, update: bool = False) -> SchedReport:
    """Run every scenario. ``root``/``update`` keep the ``run_trace``
    signature — the sched gate has no recorded table to re-write (the
    contract is bit-identity, not a budget), so ``update`` is a no-op
    beyond letting ``--sched --update-baseline`` own new SCHED005
    findings like any other finding."""
    del root, update
    report = SchedReport(rules_run=[HB_RULE_ID])
    try:
        model, fl, ds = _tiny_stack()
    except Exception as e:          # pragma: no cover - env trouble
        report.problems.append(f"sched scenarios unavailable: {e!r}")
        return report
    for name in SCENARIOS:
        try:
            row, findings, problems = run_scenario(name, model, fl, ds)
        except Exception as e:
            report.problems.append(f"[{name}] scenario crashed: {e!r}")
            continue
        report.scenarios.append(row)
        report.findings.extend(findings)
        report.problems.extend(problems)
    return report


def format_sched_report(report: SchedReport) -> str:
    lines = ["schedule sanitizer:"]
    for row in report.scenarios:
        verdict = "ok" if row["ok"] and not row["races"] else "FAIL"
        lines.append(
            f"  {row['scenario']:<16} {row['aggregator']:<8} "
            f"cert={row['commutativity'] or '-':<9} "
            f"perms={row['permutations']} mode={row['mode']:<9} "
            f"swapped={row['total_swapped']:<3} "
            f"unordered={row['unordered_pairs']:<4} "
            f"races={row['races']} {verdict}")
    if not report.scenarios:
        lines.append("  (no scenarios ran)")
    return "\n".join(lines)
