"""Repro-specific rules: kernel/ref twins, benchmark metric specs, and
exact-integer wire/token accounting.

These guard the paper's core claim — exact constraint accounting — and
the PR-7 contract that every Pallas kernel has a bit-exact pure-jnp
twin behind one dispatch point.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set

from repro.analysis.engine import (ModuleRule, ParsedModule, ProjectRule,
                                   call_name, register_rule)

# ---------------------------------------------------------------------------
# REPRO001 — every public kernel has a ref twin, an ops dispatch, and a
# test referencing it
# ---------------------------------------------------------------------------

KERNEL_MODULES = ("src/repro/kernels/wire.py",
                  "src/repro/kernels/quantize.py",
                  "src/repro/kernels/flash_attention.py")
REF_MODULE = "src/repro/kernels/ref.py"
OPS_MODULE = "src/repro/kernels/ops.py"


def _public_functions(mod: ParsedModule) -> List[ast.FunctionDef]:
    return [n for n in mod.tree.body
            if isinstance(n, ast.FunctionDef)
            and not n.name.startswith("_")]


@register_rule
class KernelRefTwin(ProjectRule):
    """REPRO001 — kernels need a ref twin, ops dispatch, and a test."""

    id = "REPRO001"
    title = "Pallas kernel without ref twin / dispatch / bit-equality test"
    rationale = ("The wire path's correctness story is the bit-exact "
                 "pure-jnp twin: every public kernel must have a "
                 "kernels/ref.py counterpart, be dispatched through "
                 "kernels/ops.py, and be pinned by a test.")
    hint = ("add `<name>_ref` to kernels/ref.py, dispatch both paths in "
            "kernels/ops.py, and pin kernel-vs-ref bit equality in tests/")

    def check_project(self, modules: Dict[str, ParsedModule],
                      context: Dict[str, ParsedModule]) -> List:
        findings: List = []
        ref = modules.get(REF_MODULE)
        ops = modules.get(OPS_MODULE)
        ref_bases = ([f.name[:-4] for f in _public_functions(ref)
                      if f.name.endswith("_ref")] if ref else [])
        ops_src = ops.source if ops else ""
        test_src = "\n".join(m.source for p, m in context.items()
                             if "test" in p)
        for path in KERNEL_MODULES:
            mod = modules.get(path)
            if mod is None:
                continue
            for fn in _public_functions(mod):
                name = fn.name
                twin = next((b for b in ref_bases
                             if name == b or name.startswith(b)
                             or b.startswith(name)), None)
                if twin is None:
                    findings.append(self.make_finding(
                        mod, fn,
                        f"kernel '{name}' has no pure-jnp twin in "
                        f"kernels/ref.py"))
                    continue
                if name not in ops_src:
                    findings.append(self.make_finding(
                        mod, fn,
                        f"kernel '{name}' is not dispatched in "
                        f"kernels/ops.py"))
                if f"{twin}_ref" not in ops_src:
                    findings.append(self.make_finding(
                        mod, fn,
                        f"kernel '{name}': its twin '{twin}_ref' is not "
                        f"dispatched in kernels/ops.py"))
                # a test may pin the kernel directly, its ref twin, or
                # the ops-level dispatch wrapper (the twin's base name)
                referenced = any(
                    re.search(rf"\b{re.escape(pat)}\b", test_src)
                    for pat in (name, f"{twin}_ref", twin))
                if not referenced:
                    findings.append(self.make_finding(
                        mod, fn,
                        f"kernel '{name}' has no test referencing it or "
                        f"its twin (bit-equality pin required)"))
        return findings


# ---------------------------------------------------------------------------
# REPRO002 — every emitted benchmark metric has a MetricSpec
# ---------------------------------------------------------------------------

_VALID_DIRECTIONS = {"higher", "lower"}
#: non-metric keys the runner strips before validation (descriptive
#: context strings; see repro.bench.runner)
_NON_METRIC_KEYS = {"context"}


def _decl_metric_names(dec: ast.Call) -> Optional[Set[str]]:
    """Metric names a @benchmark(...) decorator declares; None when any
    spec name is dynamic (f-string / comprehension) — the set is then
    open and emitted keys cannot be checked statically."""
    metrics_node = None
    for kw in dec.keywords:
        if kw.arg == "metrics":
            metrics_node = kw.value
    if metrics_node is None and len(dec.args) >= 3:  # positional form
        metrics_node = dec.args[2]
    if metrics_node is None:
        return set()
    if not isinstance(metrics_node, (ast.List, ast.Tuple)):
        return None
    names: Set[str] = set()
    for el in metrics_node.elts:
        if not (isinstance(el, ast.Call)
                and call_name(el).endswith("MetricSpec")):
            return None
        name_node = el.args[0] if el.args else next(
            (kw.value for kw in el.keywords if kw.arg == "name"), None)
        if isinstance(name_node, ast.Constant) and isinstance(
                name_node.value, str):
            names.add(name_node.value)
        else:
            return None          # dynamic name: open set
    return names


@register_rule
class BenchMetricSpec(ProjectRule):
    """REPRO002 — benchmark return keys must be declared MetricSpecs."""

    id = "REPRO002"
    title = "benchmark emits a metric without a MetricSpec"
    rationale = ("The perf ratchet is direction-aware: a metric without "
                 "a declared MetricSpec (unit + better-direction) cannot "
                 "be compared and silently escapes the CI ratchet.")
    hint = ("declare the metric in the @benchmark(metrics=[...]) list "
            "with its unit and direction")
    paths = ("benchmarks/*.py",)

    def check_project(self, modules: Dict[str, ParsedModule],
                      context: Dict[str, ParsedModule]) -> List:
        findings: List = []
        for path, mod in modules.items():
            if not self.applies_to(path):
                continue
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.FunctionDef):
                    continue
                for dec in node.decorator_list:
                    if not (isinstance(dec, ast.Call)
                            and call_name(dec).endswith("benchmark")):
                        continue
                    declared = _decl_metric_names(dec)
                    self._check_direction_literals(mod, dec, findings)
                    if declared is None:
                        continue     # dynamic spec list: runner validates
                    for ret in [n for n in ast.walk(node)
                                if isinstance(n, ast.Return)]:
                        if not isinstance(ret.value, ast.Dict):
                            continue
                        for key in ret.value.keys:
                            if (isinstance(key, ast.Constant)
                                    and isinstance(key.value, str)
                                    and key.value not in _NON_METRIC_KEYS
                                    and key.value not in declared):
                                findings.append(self.make_finding(
                                    mod, key,
                                    f"metric '{key.value}' is returned "
                                    f"but has no MetricSpec in the "
                                    f"@benchmark declaration"))
        return findings

    def _check_direction_literals(self, mod: ParsedModule, dec: ast.Call,
                                  findings: List) -> None:
        for call in [n for n in ast.walk(dec) if isinstance(n, ast.Call)
                     and call_name(n).endswith("MetricSpec")]:
            for kw in call.keywords:
                if (kw.arg == "direction"
                        and isinstance(kw.value, ast.Constant)
                        and kw.value.value not in _VALID_DIRECTIONS):
                    findings.append(self.make_finding(
                        mod, kw.value,
                        f"MetricSpec direction {kw.value.value!r} is not "
                        f"one of {sorted(_VALID_DIRECTIONS)}"))


# ---------------------------------------------------------------------------
# REPRO003 — wire/token accounting must stay exact-integer
# ---------------------------------------------------------------------------

_WIRE_FN = re.compile(r"wire_bytes|wire_mb")
_TOKEN_TARGET = re.compile(
    r"(^|_)(debt|token_budget|token_debt|tokens_owed|wire_bytes)s?$")


def _target_root_name(node: ast.AST) -> str:
    """Innermost identifier of an assignment target: ``self._debt[cid]``
    -> '_debt', ``wire_bytes`` -> 'wire_bytes'."""
    while isinstance(node, (ast.Subscript, ast.Starred)):
        node = node.value
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _float_ops(value: ast.AST) -> List[ast.AST]:
    """Div nodes / float constants / float() casts, one per line."""
    out: List[ast.AST] = []
    seen_lines: Set[int] = set()
    for n in ast.walk(value):
        hit = ((isinstance(n, ast.BinOp) and isinstance(n.op, ast.Div))
               or (isinstance(n, ast.Constant)
                   and isinstance(n.value, float))
               or (isinstance(n, ast.Call) and call_name(n) == "float"))
        line = getattr(n, "lineno", 0)
        if hit and line not in seen_lines:
            seen_lines.add(line)
            out.append(n)
    return out


@register_rule
class ExactWireAccounting(ModuleRule):
    """REPRO003 — float arithmetic flowing into exact accounting."""

    id = "REPRO003"
    title = "float arithmetic in wire-bytes / token-budget accounting"
    rationale = ("Wire bytes and token budgets are the paper's exact "
                 "constraint ledgers (Eq. 5-8): true division or float "
                 "constants make them drift; PR 7 fixed one such bug by "
                 "hand and this rule keeps it fixed.")
    hint = ("count with integer arithmetic (`*`, `//`, `-(-n // b)` for "
            "ceil-div); convert to float only at the MB reporting edge")

    def check_module(self, mod: ParsedModule) -> List:
        raw: List = []
        findings = raw
        # (a) any function whose name smells like wire accounting
        for node in ast.walk(mod.tree):
            if (isinstance(node, ast.FunctionDef)
                    and _WIRE_FN.search(node.name)):
                for bad in self._body_float_ops(node):
                    findings.append(self.make_finding(
                        mod, bad,
                        f"float arithmetic in wire accounting "
                        f"function '{node.name}'"))
        # (b) assignments to token/debt-ish names anywhere
        for node in ast.walk(mod.tree):
            targets: List[ast.AST] = []
            value: Optional[ast.AST] = None
            if isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            elif isinstance(node, ast.AugAssign):
                targets, value = [node.target], node.value
            if value is None:
                continue
            for tgt in targets:
                name = _target_root_name(tgt)
                if _TOKEN_TARGET.search(name):
                    for bad in _float_ops(value):
                        findings.append(self.make_finding(
                            mod, bad,
                            f"float arithmetic assigned to exact "
                            f"accounting name '{name}'"))
        seen: Set[int] = set()
        out: List = []
        for f in raw:
            if f.line not in seen:
                seen.add(f.line)
                out.append(f)
        return out

    @staticmethod
    def _body_float_ops(fn: ast.FunctionDef) -> List[ast.AST]:
        out: List[ast.AST] = []
        seen: Set[int] = set()
        for stmt in fn.body:
            for bad in _float_ops(stmt):
                line = getattr(bad, "lineno", 0)
                if line not in seen:
                    seen.add(line)
                    out.append(bad)
        return out
