"""The rule engine: parse once, run every rule, collect findings.

Two rule kinds:

``ModuleRule``   — sees one parsed module at a time (an AST + source).
                   Most JAX discipline rules are per-module.
``ProjectRule``  — sees the whole parsed file set at once, for
                   cross-file invariants (kernel/ref twins, benchmark
                   metric specs). Project rules also get read-only
                   access to *context* files (the test tree) that
                   module rules never scan — so a rule can require "a
                   test references this kernel" without the test files
                   themselves being linted.

Rules self-register via the ``@register_rule`` decorator at import
time; ``default_rules()`` imports the two rule modules and returns the
registry. Every rule carries metadata (id, title, rationale, hint) the
CLI surfaces in ``--list-rules``.
"""
from __future__ import annotations

import ast
import fnmatch
import os
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Type

from repro.analysis.findings import AnalysisResult, Finding, assign_occurrences

#: Directories scanned by default (repo-relative), and the context set
#: project rules may read but module rules never lint.
DEFAULT_CODE_PATHS = ("src", "benchmarks", "examples")
DEFAULT_CONTEXT_PATHS = ("tests",)
#: Never scanned, even when explicitly under a scanned directory —
#: the seeded-violation fixtures live here.
EXCLUDE_GLOBS = ("tests/fixtures/*", "*/__pycache__/*", "*/.git/*")


@dataclass
class ParsedModule:
    """One parsed source file, shared by every rule."""

    path: str                 # repo-relative, forward slashes
    source: str
    tree: ast.Module
    lines: List[str] = field(default_factory=list)

    def __post_init__(self):
        if not self.lines:
            self.lines = self.source.splitlines()

    def line(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""


class Rule:
    """Base rule: metadata only. Subclass ``ModuleRule`` or
    ``ProjectRule`` and register with ``@register_rule``."""

    id: str = ""
    title: str = ""
    rationale: str = ""
    hint: str = ""
    #: fnmatch patterns over repo-relative paths; empty = every module.
    paths: Sequence[str] = ()

    def applies_to(self, path: str) -> bool:
        if not self.paths:
            return True
        return any(fnmatch.fnmatch(path, pat) for pat in self.paths)

    def make_finding(self, mod: ParsedModule, node: ast.AST,
                     message: str, hint: Optional[str] = None) -> Finding:
        line = getattr(node, "lineno", 1)
        return Finding(rule=self.id, path=mod.path, line=line,
                       message=message,
                       hint=self.hint if hint is None else hint,
                       snippet=mod.line(line))


class ModuleRule(Rule):
    def check_module(self, mod: ParsedModule) -> List[Finding]:
        raise NotImplementedError


class ProjectRule(Rule):
    def check_project(self, modules: Dict[str, ParsedModule],
                      context: Dict[str, ParsedModule]) -> List[Finding]:
        raise NotImplementedError


_RULES: Dict[str, Type[Rule]] = {}


def register_rule(cls: Type[Rule]) -> Type[Rule]:
    assert cls.id, f"{cls.__name__} needs a rule id"
    _RULES[cls.id] = cls
    return cls


def default_rules() -> List[Rule]:
    """Instantiate every registered rule (importing the rule modules
    the first time so their ``@register_rule`` decorators run)."""
    from repro.analysis import rules_jax, rules_repro  # noqa: F401
    from repro.analysis.sched import rules as rules_sched  # noqa: F401
    return [cls() for _, cls in sorted(_RULES.items())]


def rule_ids() -> List[str]:
    from repro.analysis import rules_jax, rules_repro  # noqa: F401
    from repro.analysis.sched import rules as rules_sched  # noqa: F401
    return sorted(_RULES)


# ---------------------------------------------------------------------------
# file collection + the analyzer
# ---------------------------------------------------------------------------


def _excluded(rel: str) -> bool:
    return any(fnmatch.fnmatch(rel, pat) or
               fnmatch.fnmatch(rel, pat.rstrip("*") + "**")
               for pat in EXCLUDE_GLOBS)


def collect_files(root: str, paths: Sequence[str]) -> List[str]:
    """Repo-relative .py files under ``paths`` (files or directories),
    minus the exclude globs, sorted for deterministic output."""
    out = []
    for p in paths:
        full = os.path.join(root, p)
        if os.path.isfile(full) and full.endswith(".py"):
            rel = os.path.relpath(full, root).replace(os.sep, "/")
            if not _excluded(rel):
                out.append(rel)
            continue
        for dirpath, dirnames, filenames in os.walk(full):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                rel = os.path.relpath(os.path.join(dirpath, fn),
                                      root).replace(os.sep, "/")
                if not _excluded(rel):
                    out.append(rel)
    return sorted(set(out))


def parse_files(root: str, rels: Iterable[str]) -> Dict[str, ParsedModule]:
    out: Dict[str, ParsedModule] = {}
    for rel in rels:
        full = os.path.join(root, rel)
        try:
            with open(full, "r", encoding="utf-8") as f:
                src = f.read()
            tree = ast.parse(src, filename=rel)
        except (OSError, SyntaxError):
            continue            # unparseable files are not this tool's job
        out[rel] = ParsedModule(path=rel, source=src, tree=tree)
    return out


class Analyzer:
    """Run a rule set over a file tree and collect findings."""

    def __init__(self, root: str,
                 code_paths: Sequence[str] = DEFAULT_CODE_PATHS,
                 context_paths: Sequence[str] = DEFAULT_CONTEXT_PATHS,
                 rules: Optional[Sequence[Rule]] = None):
        self.root = os.path.abspath(root)
        self.code_paths = tuple(code_paths)
        self.context_paths = tuple(context_paths)
        self.rules = list(rules) if rules is not None else default_rules()

    def run(self) -> AnalysisResult:
        code = parse_files(self.root,
                           collect_files(self.root, self.code_paths))
        context = parse_files(self.root,
                              collect_files(self.root, self.context_paths))
        findings: List[Finding] = []
        for rule in self.rules:
            if isinstance(rule, ProjectRule):
                findings.extend(rule.check_project(code, context))
            elif isinstance(rule, ModuleRule):
                for mod in code.values():
                    if rule.applies_to(mod.path):
                        findings.extend(rule.check_module(mod))
        findings = assign_occurrences(findings)
        return AnalysisResult(findings=findings, files_scanned=len(code),
                              rules_run=[r.id for r in self.rules])


def run_analysis(root: str, paths: Optional[Sequence[str]] = None,
                 rules: Optional[Sequence[Rule]] = None) -> AnalysisResult:
    """One-call entry point (the CLI and tests both use it)."""
    kwargs = {}
    if paths is not None:
        kwargs["code_paths"] = paths
    return Analyzer(root, rules=rules, **kwargs).run()


# ---------------------------------------------------------------------------
# small AST helpers shared by the rule modules
# ---------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> str:
    """'jax.random.split' for the func of a Call, '' when not a plain
    dotted path."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def call_name(call: ast.Call) -> str:
    return dotted_name(call.func)


def is_main_guard(node: ast.stmt) -> bool:
    """``if __name__ == "__main__":`` (either comparison order)."""
    if not isinstance(node, ast.If):
        return False
    t = node.test
    if not (isinstance(t, ast.Compare) and len(t.ops) == 1
            and isinstance(t.ops[0], ast.Eq)):
        return False
    sides = [t.left] + list(t.comparators)
    names = [s.id for s in sides if isinstance(s, ast.Name)]
    consts = [s.value for s in sides if isinstance(s, ast.Constant)]
    return "__name__" in names and "__main__" in consts


def is_type_checking_guard(node: ast.stmt) -> bool:
    if not isinstance(node, ast.If):
        return False
    t = node.test
    name = dotted_name(t) if isinstance(t, (ast.Name, ast.Attribute)) else ""
    return name in ("TYPE_CHECKING", "typing.TYPE_CHECKING")
