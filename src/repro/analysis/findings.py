"""Findings: what a rule reports, and how a finding is fingerprinted.

A ``Finding`` pins a rule violation to ``path:line`` with a message and
a fix hint. The *fingerprint* deliberately excludes the line number —
it hashes (rule, path, normalized source line text, occurrence index)
— so a committed baseline keeps suppressing a legacy finding when
unrelated edits shift it up or down the file, but a *new* identical
violation on a second line still surfaces.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List


@dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str            # e.g. "JAX001"
    path: str            # repo-relative, forward slashes
    line: int            # 1-indexed
    message: str
    hint: str = ""       # how to fix it
    snippet: str = ""    # the stripped source line (fingerprint input)
    occurrence: int = 0  # nth identical (rule, path, snippet) triple

    @property
    def fingerprint(self) -> str:
        key = f"{self.rule}\x00{self.path}\x00{self.snippet}\x00{self.occurrence}"
        return hashlib.sha1(key.encode("utf-8")).hexdigest()[:16]

    def format(self) -> str:
        out = f"{self.path}:{self.line}: {self.rule} {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out

    def to_json(self) -> Dict[str, object]:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "message": self.message,
            "hint": self.hint,
            "fingerprint": self.fingerprint,
        }


def assign_occurrences(findings: List[Finding]) -> List[Finding]:
    """Stamp each finding's ``occurrence`` index among identical
    (rule, path, snippet) triples, in line order, so two textually
    identical violations in one file get distinct fingerprints."""
    counts: Dict[tuple, int] = {}
    out = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
        key = (f.rule, f.path, f.snippet)
        idx = counts.get(key, 0)
        counts[key] = idx + 1
        out.append(Finding(rule=f.rule, path=f.path, line=f.line,
                           message=f.message, hint=f.hint,
                           snippet=f.snippet, occurrence=idx))
    return out


@dataclass
class AnalysisResult:
    """Everything one analyzer run produced."""

    findings: List[Finding] = field(default_factory=list)
    files_scanned: int = 0
    rules_run: List[str] = field(default_factory=list)

    def by_rule(self) -> Dict[str, List[Finding]]:
        out: Dict[str, List[Finding]] = {}
        for f in self.findings:
            out.setdefault(f.rule, []).append(f)
        return out
