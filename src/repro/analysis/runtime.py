"""Runtime sanitizers: transfer guards and a jit recompile watcher.

The static rules can't see dynamic behavior: a round loop that silently
bounces arrays host<->device, or a jit cache that misses every round
because a shape or static argument drifts. These opt-in contexts pin
both at test time:

``no_transfers()``            — ``jax.transfer_guard("disallow")`` as a
                                context manager: any *implicit* host
                                transfer inside raises (explicit
                                ``device_put`` / numpy-array ingestion
                                stays allowed).
``RecompileWatcher``          — counts XLA backend compiles via
                                ``jax.monitoring`` events; ``mark()``
                                buckets them (e.g. per round) so a test
                                can assert "zero after round 1".
``TransferGuardCallback``     — engine ``RoundCallback`` entering the
                                guard from ``from_round`` on (round 1
                                warms jit caches, masks and constants —
                                the steady state must be transfer-free).
``RecompileWatchCallback``    — engine ``RoundCallback`` recording the
                                compile count of every round.

Both watchers degrade gracefully: ``supported`` flags whether the jax
build exposes the hooks, and tests skip when it doesn't.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Iterator, List, Optional

import jax

from repro.fl.callbacks import RoundCallback

#: the jax.monitoring duration event XLA emits once per backend compile
COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


def transfer_guard_supported() -> bool:
    return hasattr(jax, "transfer_guard")


@contextlib.contextmanager
def no_transfers(level: str = "disallow") -> Iterator[None]:
    """Disallow implicit host<->device transfers inside the block.

    Raises ``RuntimeError`` at enter when this jax build has no
    ``transfer_guard`` (callers gate on ``transfer_guard_supported``).
    """
    if not transfer_guard_supported():
        raise RuntimeError("jax.transfer_guard is not available in this "
                           "jax build")
    with jax.transfer_guard(level):
        yield


# ---------------------------------------------------------------------------
# recompile watching
# ---------------------------------------------------------------------------

_COMPILES = 0
_LISTENER_INSTALLED = False


def _on_duration_event(name: str, *args, **kwargs) -> None:
    global _COMPILES
    if name == COMPILE_EVENT:
        _COMPILES += 1


def _install_listener() -> bool:
    """Register the global compile listener once; False when the jax
    build has no monitoring hooks."""
    global _LISTENER_INSTALLED
    if _LISTENER_INSTALLED:
        return True
    mon = getattr(jax, "monitoring", None)
    reg = getattr(mon, "register_event_duration_secs_listener", None)
    if reg is None:
        return False
    reg(_on_duration_event)
    _LISTENER_INSTALLED = True
    return True


def compile_count() -> int:
    """Process-wide backend compiles observed so far (0 until a
    watcher installs the listener)."""
    return _COMPILES


class RecompileWatcher:
    """Counts jit cache misses (backend compiles) between marks.

    >>> w = RecompileWatcher()
    >>> with w:                     # doctest: +SKIP
    ...     step()                  # round 1: compiles
    ...     w.mark("round1")
    ...     step()                  # round 2: cache hit expected
    ...     w.mark("round2")
    >>> w.buckets                   # doctest: +SKIP
    {'round1': 2, 'round2': 0}
    """

    def __init__(self):
        self.supported = _install_listener()
        self.buckets: Dict[str, int] = {}
        self._start: Optional[int] = None
        self._last: int = 0

    def __enter__(self) -> "RecompileWatcher":
        self._start = self._last = compile_count()
        return self

    def __exit__(self, *exc) -> None:
        pass

    def mark(self, label: str) -> int:
        """Close a bucket: compiles since the previous mark (or enter)."""
        now = compile_count()
        delta = now - self._last
        self._last = now
        self.buckets[label] = self.buckets.get(label, 0) + delta
        return delta

    @property
    def total(self) -> int:
        base = self._start if self._start is not None else 0
        return compile_count() - base


# ---------------------------------------------------------------------------
# engine callbacks
# ---------------------------------------------------------------------------


class RecompileWatchCallback(RoundCallback):
    """Records per-round backend-compile counts during an engine run.

    ``per_round[t]`` = compiles observed while round ``t`` executed
    (including its evaluation step). The steady-state pin asserts
    ``all(c == 0 for c in per_round values after round 1)``.
    """

    def __init__(self):
        self.watcher = RecompileWatcher()
        self.supported = self.watcher.supported
        self.per_round: Dict[int, int] = {}
        self._round: Optional[int] = None

    def on_train_start(self, engine) -> None:
        self.watcher.__enter__()
        self._round = None

    def on_round_start(self, engine, rnd: int) -> None:
        if self._round is not None:
            self.per_round[self._round] = self.watcher.mark(
                f"round{self._round}")
        else:
            self.watcher.mark("setup")
        self._round = rnd

    def on_train_end(self, engine, result) -> None:
        if self._round is not None:
            self.per_round[self._round] = self.watcher.mark(
                f"round{self._round}")
            self._round = None

    def steady_state_compiles(self, first_steady_round: int = 2) -> int:
        return sum(c for t, c in self.per_round.items()
                   if t >= first_steady_round)


class TransferGuardCallback(RoundCallback):
    """Runs engine rounds >= ``from_round`` under the transfer guard.

    Round 1 stays unguarded: it legitimately materializes constants,
    freezing masks and jit executables. From ``from_round`` on, any
    implicit host<->device transfer raises — the steady-state round
    loop must live entirely on device + pre-staged host buffers.

    The guard is released at ``on_train_end``; ``close()`` is
    idempotent and should sit in a ``finally`` in tests so an engine
    exception can't leak the guard into later tests.
    """

    def __init__(self, from_round: int = 2, level: str = "disallow"):
        self.from_round = from_round
        self.level = level
        self.supported = transfer_guard_supported()
        self.guarded_rounds: List[int] = []
        self._stack: Optional[contextlib.ExitStack] = None

    def on_round_start(self, engine, rnd: int) -> None:
        if (self.supported and self._stack is None
                and rnd >= self.from_round):
            self._stack = contextlib.ExitStack()
            self._stack.enter_context(jax.transfer_guard(self.level))
        if self._stack is not None:
            self.guarded_rounds.append(rnd)

    def on_train_end(self, engine, result) -> None:
        self.close()

    def close(self) -> None:
        if self._stack is not None:
            self._stack.close()
            self._stack = None
