from repro.configs.base import (  # noqa: F401
    Budgets, DualConfig, FLConfig, FrontendConfig, InputShape, INPUT_SHAPES,
    MLAConfig, MoEConfig, ModelConfig, RGLRUConfig, XLSTMConfig,
)
from repro.configs.registry import (  # noqa: F401
    ARCH_IDS, get_config, get_fl_config, get_smoke_config,
)
