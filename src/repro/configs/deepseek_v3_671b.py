"""DeepSeek-V3 (671B total / 37B active) [arXiv:2412.19437].

MLA (multi-head latent attention, kv_lora_rank 512 + 64-dim shared rope
key), 1 shared + 256 routed experts top-8, first 3 layers dense
(d_ff 18432). Decode uses the absorbed-matmul MLA path, so the per-token
cache is 512+64 floats/layer regardless of head count.

MTP (multi-token prediction) is a training-objective add-on and is not
reproduced here — noted in DESIGN.md; the backbone, MLA and MoE routing
are complete.
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=2048,
    vocab_size=129_280,
    mlp_type="swiglu",
    norm_type="rms",
    tie_embeddings=False,
    rope_theta=10_000.0,
    decode_window=8192,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1, capacity_factor=1.25,
                  first_dense_layers=3, d_ff_dense=18432, group_size=1024),
    source="arXiv:2412.19437 (DeepSeek-V3)",
)

SMOKE = CONFIG.replace(num_layers=3, d_model=128, num_heads=4, num_kv_heads=4,
                       head_dim=32, d_ff=64, vocab_size=512,
                       mla=MLAConfig(q_lora_rank=48, kv_lora_rank=32,
                                     qk_nope_head_dim=16, qk_rope_head_dim=8,
                                     v_head_dim=16),
                       moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                                     num_shared_experts=1, first_dense_layers=1,
                                     d_ff_dense=128, group_size=64),
                       param_dtype="float32", compute_dtype="float32")
