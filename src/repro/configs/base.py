"""Config dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; the CAFL-L
federated-learning experiment (the paper's own setting) is a ``FLConfig``
wrapping a small ``ModelConfig``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0       # leading layers that use a dense MLP
    d_ff_dense: int = 0               # d_ff of those dense layers / shared expert
    group_size: int = 2048            # tokens per dispatch group (GShard-style)
    router_noise: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class RGLRUConfig:
    """Griffin/RecurrentGemma recurrent block."""
    lru_width: int = 0                # 0 -> d_model
    conv_width: int = 4
    c_const: float = 8.0              # the fixed `c` in a_t = exp(-c softplus(Λ) σ(r))


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block stack (mLSTM-dominant with interleaved sLSTM)."""
    mlstm_per_unit: int = 7           # xLSTM[7:1]
    slstm_per_unit: int = 1
    chunk_size: int = 64              # chunkwise-parallel mLSTM chunk
    proj_factor_mlstm: float = 2.0    # up-projection factor (pre-LSTM)
    proj_factor_slstm: float = 1.3334
    conv_width: int = 4


@dataclass(frozen=True)
class FrontendConfig:
    """Stub modality frontend: input_specs() provides precomputed embeddings."""
    kind: str                         # "vision" | "audio"
    embed_dim: int                    # SigLIP 1152 / speech-encoder 1024
    num_prefix_tokens: int = 256      # vision: patch tokens prepended


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- attention ---
    attn_pattern: Tuple[str, ...] = ("global",)   # per-layer unit, cycled
    window: int = 4096                # local-attention window
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # decode-time sliding window for long-context shapes (sub-quadratic
    # variant; None -> full cache)
    decode_window: Optional[int] = 8192
    # --- specials ---
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    rglru: Optional[RGLRUConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # block pattern for hybrid/ssm, cycled over layers: "attn"|"rec"|"mlstm"|"slstm"
    block_pattern: Tuple[str, ...] = ()
    # --- enc-dec ---
    encdec: bool = False
    enc_layers: int = 0
    # --- frontend stub ---
    frontend: Optional[FrontendConfig] = None
    # --- misc ---
    mlp_type: str = "swiglu"          # swiglu | geglu | gelu | none
    norm_type: str = "rms"            # rms | layer
    post_norms: bool = False          # gemma2-style post-attn/post-ffn norms
    tie_embeddings: bool = True
    embed_scale: bool = False         # gemma multiplies embeddings by sqrt(d)
    learned_pos_emb: int = 0          # >0: use learned positions (charlm)
    max_seq_len: int = 524_288
    param_dtype: jnp.dtype = jnp.bfloat16
    compute_dtype: jnp.dtype = jnp.bfloat16
    # attention chunking for the pure-JAX blockwise implementation
    q_chunk: int = 2048
    source: str = ""                  # citation

    @property
    def d_head_total(self) -> int:
        return self.num_heads * self.head_dim

    def layer_kind(self, i: int) -> str:
        if self.block_pattern:
            return self.block_pattern[i % len(self.block_pattern)]
        return "attn"

    def attn_type(self, i: int) -> str:
        return self.attn_pattern[i % len(self.attn_pattern)]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class Budgets:
    """Per-round resource budgets  B = (E_b, C_b, M_b, T_b)  (paper Eq. 2)."""
    energy: float = 1.2e6
    comm_mb: float = 0.60
    memory: float = 0.26
    temp: float = 1.00

    def scaled(self, factor: float = 1.0, *, energy: float = 1.0,
               comm: float = 1.0, memory: float = 1.0, temp: float = 1.0
               ) -> "Budgets":
        """Device-class budgets: ``scaled(0.5)`` is a fleet tier with half
        the allowance on every resource; keyword factors scale one axis."""
        return Budgets(energy=self.energy * factor * energy,
                       comm_mb=self.comm_mb * factor * comm,
                       memory=self.memory * factor * memory,
                       temp=self.temp * factor * temp)


@dataclass(frozen=True)
class DualConfig:
    """Lagrangian dual optimization (paper Eq. 4)."""
    eta: float = 0.35                 # dual learning rate
    deadzone: float = 0.05            # |u/b - 1| <= dz  ->  no update
    lambda_max: float = 10.0
    # policy coefficients (paper Eq. 5-7)
    alpha_k: float = 1.0
    beta_s: float = 0.12
    gamma_b: float = 0.25
    # floors (paper: k>=1, s>=10, b>=8)
    k_min: int = 1
    s_min: int = 10
    b_min: int = 8


@dataclass(frozen=True)
class FLConfig:
    """Federated-learning experiment configuration (paper §5)."""
    num_clients: int = 16
    clients_per_round: int = 6
    rounds: int = 60
    # baseline knobs (k_base, s_base, b_base) — paper does not publish these;
    # chosen so FedAvg violates comm ~5x and memory ~1.1x as in Fig. 2.
    k_base: int = 6                   # all layers unfrozen
    s_base: int = 40
    b_base: int = 32
    seq_len: int = 128
    lr: float = 1e-3
    optimizer: str = "adamw"
    weight_decay: float = 0.01
    seed: int = 0
    method: str = "cafl"              # cafl | fedavg
    budgets: Budgets = field(default_factory=Budgets)
    duals: DualConfig = field(default_factory=DualConfig)
    eval_batches: int = 8
    eval_batch_size: int = 64
    # non-IID partition strength (0 = IID shards)
    noniid_alpha: float = 0.0
    # ablation: disable Eq. 8 token-budget preservation (grad_accum = 1)
    token_budget: bool = True
    # Eq. 8 rounding: "ceil" (paper; grad_accum may overshoot the token
    # target by up to s*b-1 tokens and inflate round time past a
    # straggler deadline) | "clamped" (floor, >=1; never trains longer
    # than the baseline round, at the cost of undershooting the target)
    token_preservation: str = "ceil"
    # --- engine (repro.fl) ---
    # client execution backend: "sequential" | "batched" (vmapped clients)
    executor: str = "sequential"
    # server-update policy: "sync" (round barrier) | "fedbuff" (buffered
    # async) | "staleness" (late reports discounted, not discarded) |
    # "masked" (secure-aggregation simulation)
    aggregator: str = "sync"
    # server-side optimizer on the aggregated pseudo-gradient
    # ("" = plain averaging; "adam" / "momentum" = FedAdam / FedAvgM)
    server_opt: str = ""
    server_lr: float = 0.1
    # sparse wire format: keep only the k largest-magnitude codes per
    # 256-value quantization block (None = dense; only active at q > 0).
    # Extra knob surface for the wire_mb constraint.
    wire_topk: Any = None
    # --- constraint stack (repro.constraints), CAFLL strategies only ---
    # which resources are budgeted: "paper" (the four Appendix-A.1
    # proxies) | "paper+wire_mb" style registry specs | a sequence of
    # names / Constraint instances | a ConstraintSet
    constraints: Any = "paper"
    # dual-ascent law per constraint: "deadzone" (paper Eq. 4) |
    # "adaptive" (violation-scaled step) | "pi" | a DualController
    dual_controller: Any = "deadzone"
    # duals -> knobs mapping: "paper" (Eq. 5-7) | "deadline_aware"
    # (widens the straggler deadline when drops starve the dual update,
    # and tightens/widens it from the latency constraint's dual when
    # one is registered)
    # | a KnobPolicy instance
    knob_policy: Any = "paper"
    # per-constraint DualConfig overrides: {"latency": {"eta": 1.0}}
    # runs the latency dual at its own learning rate / deadzone without
    # touching the shared ``duals`` config the paper's four proxies use
    # (None / {} = every constraint shares ``duals``)
    dual_overrides: Any = None
    # --- virtual wall clock (repro.fl.clock) ---
    # "rounds": the engine advances in abstract rounds (seed semantics,
    # golden-pinned bit-for-bit). "wall_clock": rounds begin when the
    # previous barrier/buffer event completes, late async reports land
    # at their simulated *arrival time*, and ``run(horizon_seconds=)``
    # replaces a fixed round count.
    time_mode: str = "rounds"
    # simulated-seconds budget for wall-clock runs (None = round count)
    horizon_seconds: Optional[float] = None

    def replace(self, **kw) -> "FLConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}
