"""Phi-3.5-MoE (42B total / 6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct].

16 experts, top-2 routing, SwiGLU experts with d_ff 6400, GQA kv=8.
"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32_064,
    mlp_type="swiglu",
    norm_type="layer",
    tie_embeddings=False,
    rope_theta=10_000.0,
    decode_window=8192,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=6400,
                  capacity_factor=1.25, group_size=2048),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)

SMOKE = CONFIG.replace(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                       head_dim=32, d_ff=256, vocab_size=512,
                       moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=256,
                                     group_size=64),
                       param_dtype="float32", compute_dtype="float32")
