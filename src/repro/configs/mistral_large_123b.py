"""Mistral-Large-2407 (123B) [hf:mistralai/Mistral-Large-Instruct-2407]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32_768,
    mlp_type="swiglu",
    norm_type="rms",
    tie_embeddings=False,
    rope_theta=1_000_000.0,
    decode_window=8192,
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)

SMOKE = CONFIG.replace(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                       head_dim=32, d_ff=256, vocab_size=512,
                       param_dtype="float32", compute_dtype="float32")
