"""PaliGemma-3B language backbone [arXiv:2407.07726].

SigLIP vision tower is a stub frontend (assignment carve-out):
``input_specs`` provides (B, 256, 1152) patch embeddings; the model owns
only the linear projector + the 18L Gemma decoder.
"""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257_216,
    attn_pattern=("global",),
    mlp_type="geglu",
    norm_type="rms",
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=10_000.0,
    decode_window=8192,     # sub-quadratic long_500k variant (sliding window)
    frontend=FrontendConfig(kind="vision", embed_dim=1152, num_prefix_tokens=256),
    source="arXiv:2407.07726 (SigLIP + Gemma)",
)

SMOKE = CONFIG.replace(num_layers=2, d_model=128, num_heads=4, num_kv_heads=1,
                       head_dim=32, d_ff=256, vocab_size=512,
                       frontend=FrontendConfig(kind="vision", embed_dim=64,
                                               num_prefix_tokens=8),
                       param_dtype="float32", compute_dtype="float32")
