"""The paper's own evaluation model (§5): GPT-style char-level transformer,
6 layers, 8 heads, learned positions, on Tiny Shakespeare.

Dims note: the paper states "6 layers, 8 heads, 256-dim, ~1.5M params" —
those dims give ~3-5M with any standard MLP width, so the two numbers are
inconsistent *in the paper*. We match the parameter count (~1.9M at
d=192, d_ff=2d), which the resource proxies actually depend on, and keep
6L/8H; recorded in EXPERIMENTS.md §Paper. seq_len=32 keeps the 16-client
x 60-round simulation tractable on this container's single CPU core
(the paper never states its block size).
"""
from repro.configs.base import Budgets, DualConfig, FLConfig, ModelConfig

CONFIG = ModelConfig(
    name="charlm-shakespeare",
    family="dense",
    num_layers=6,
    d_model=192,
    num_heads=8,
    num_kv_heads=8,
    head_dim=24,
    d_ff=384,
    vocab_size=128,          # rounded up; actual char vocab set by the dataset
    mlp_type="gelu",
    norm_type="layer",
    tie_embeddings=True,
    learned_pos_emb=512,
    decode_window=None,
    max_seq_len=512,
    param_dtype="float32",
    compute_dtype="float32",
    q_chunk=512,
    source="paper §5 (Karpathy char-LM setting)",
)

SMOKE = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                       head_dim=16, d_ff=128)

# Paper §5 federated setting: N=16 clients, 6 per round; k/s/b baselines
# 6/40/32 preserve the policy floors' (k>=1, s>=10, b>=8) dynamic range.
# Budgets are the paper's Table 1 "Budget Limit" row; proxy constants are
# calibrated so FedAvg reproduces Table 1's FedAvg row exactly.
FL = FLConfig(
    num_clients=16,
    clients_per_round=6,
    rounds=25,
    k_base=6,
    s_base=40,
    b_base=32,
    seq_len=32,
    lr=1e-3,
    eval_batches=4,
    eval_batch_size=64,
    budgets=Budgets(energy=1.2e6, comm_mb=0.60, memory=0.26, temp=1.00),
    duals=DualConfig(),
)
