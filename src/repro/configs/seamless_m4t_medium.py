"""SeamlessM4T-medium [arXiv:2308.11596] — enc-dec multimodal backbone.

The speech frontend (mel + conformer feature extractor) is a stub per the
assignment carve-out: ``input_specs`` supplies (B, S_src, 1024) frame
embeddings. We implement the 12L encoder + 12L decoder transformer with
cross-attention. Positional encoding adapted to RoPE (TPU-idiomatic;
original uses sinusoidal) — recorded as a changed assumption in DESIGN.md.
"""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,          # decoder layers
    enc_layers=12,
    encdec=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256_206,
    mlp_type="gelu",
    norm_type="layer",
    tie_embeddings=True,
    rope_theta=10_000.0,
    decode_window=8192,
    frontend=FrontendConfig(kind="audio", embed_dim=1024, num_prefix_tokens=0),
    source="arXiv:2308.11596 (SeamlessM4T)",
)

SMOKE = CONFIG.replace(num_layers=2, enc_layers=2, d_model=128, num_heads=4,
                       num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
                       frontend=FrontendConfig(kind="audio", embed_dim=64,
                                               num_prefix_tokens=0),
                       param_dtype="float32", compute_dtype="float32")
