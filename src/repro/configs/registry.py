"""Architecture registry: ``--arch <id>`` -> ModelConfig."""
from __future__ import annotations

import importlib

_MODULES = {
    "paligemma-3b": "paligemma_3b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "minitron-8b": "minitron_8b",
    "gemma2-9b": "gemma2_9b",
    "xlstm-1.3b": "xlstm_1_3b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "qwen2-72b": "qwen2_72b",
    "mistral-large-123b": "mistral_large_123b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "charlm-shakespeare": "charlm_shakespeare",
}

ARCH_IDS = [a for a in _MODULES if a != "charlm-shakespeare"]


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str):
    return _module(arch).CONFIG


def get_smoke_config(arch: str):
    return _module(arch).SMOKE


def get_fl_config(arch: str = "charlm-shakespeare"):
    return _module(arch).FL
