"""Gemma2-9B [arXiv:2408.00118] — local/global alternating attention,
logit softcapping, post-norms, GeGLU.

long_500k runs the arch's own sliding-window mechanism: local layers keep
window 4096; global layers are windowed by ``decode_window`` (the
documented sub-quadratic degradation for 512k decode).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256_000,
    attn_pattern=("local", "global"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norms=True,
    mlp_type="geglu",
    norm_type="rms",
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=10_000.0,
    decode_window=8192,
    source="arXiv:2408.00118 (Gemma 2)",
)

SMOKE = CONFIG.replace(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                       head_dim=32, d_ff=256, vocab_size=512, window=32,
                       param_dtype="float32", compute_dtype="float32")
