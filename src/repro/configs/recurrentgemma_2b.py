"""RecurrentGemma-2B [arXiv:2402.19427] — Griffin: RG-LRU + local attention 1:2.

26 layers with repeating (rec, rec, attn) pattern: 8 scanned units + a
(rec, rec) suffix. Local attention window 2048.
"""
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    block_pattern=("rec", "rec", "attn"),
    attn_pattern=("local",),
    window=2048,
    mlp_type="geglu",
    norm_type="rms",
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=10_000.0,
    decode_window=None,     # local attn + recurrence already sub-quadratic
    rglru=RGLRUConfig(lru_width=2560, conv_width=4, c_const=8.0),
    source="arXiv:2402.19427 (Griffin / RecurrentGemma)",
)

SMOKE = CONFIG.replace(num_layers=5, d_model=128, num_heads=4, num_kv_heads=1,
                       head_dim=32, d_ff=256, vocab_size=512, window=32,
                       rglru=RGLRUConfig(lru_width=128, conv_width=4),
                       param_dtype="float32", compute_dtype="float32")
