"""xLSTM-1.3B [arXiv:2405.04517] — mLSTM + sLSTM blocks at 7:1.

48 blocks = 6 scanned units of (7x mLSTM, 1x sLSTM). No FFN (d_ff=0):
xLSTM blocks carry their own up/down projections. No KV cache — decode
state is O(1) per block, so long_500k is natively sub-quadratic.
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50_304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    mlp_type="none",
    norm_type="layer",
    tie_embeddings=False,
    decode_window=None,
    xlstm=XLSTMConfig(mlstm_per_unit=7, slstm_per_unit=1, chunk_size=64,
                      proj_factor_mlstm=2.0, proj_factor_slstm=1.3334),
    source="arXiv:2405.04517 (xLSTM)",
)

SMOKE = CONFIG.replace(num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
                       head_dim=32, vocab_size=512,
                       block_pattern=("mlstm", "slstm"),
                       xlstm=XLSTMConfig(chunk_size=16),
                       param_dtype="float32", compute_dtype="float32")
