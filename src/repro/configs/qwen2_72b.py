"""Qwen2-72B [arXiv:2407.10671] — dense GQA with QKV bias."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152_064,
    qkv_bias=True,
    mlp_type="swiglu",
    norm_type="rms",
    tie_embeddings=False,
    rope_theta=1_000_000.0,
    decode_window=8192,
    source="arXiv:2407.10671 (Qwen2)",
)

SMOKE = CONFIG.replace(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                       head_dim=32, d_ff=256, vocab_size=512,
                       param_dtype="float32", compute_dtype="float32")
