"""Minitron-8B [arXiv:2407.14679] — width/depth-pruned Nemotron-4.

Squared-ReLU MLP (nemotron family), GQA kv=8, untied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256_000,
    mlp_type="relu2",
    norm_type="layer",
    tie_embeddings=False,
    rope_theta=10_000.0,
    decode_window=8192,
    source="arXiv:2407.14679 (Minitron, pruned Nemotron)",
)

SMOKE = CONFIG.replace(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                       head_dim=32, d_ff=256, vocab_size=512,
                       param_dtype="float32", compute_dtype="float32")
