"""Tiny-Shakespeare-style char-level corpus.

The container is offline, so ``load_corpus`` prefers a real
``data/input.txt`` (the Karpathy file) if present and otherwise expands an
embedded set of public-domain Shakespeare passages into a deterministic
~600 KB corpus with the same dramatic-dialogue structure (speaker tags,
blank lines, Early-Modern-English vocabulary). The paper's claims are
about *resource-constraint satisfaction* — proxy-model-driven and
corpus-independent — plus a relative val-loss gap, which survives the swap.
"""
from __future__ import annotations

import os
from dataclasses import dataclass

import numpy as np

_PASSAGES = [
    """To be, or not to be, that is the question:
Whether 'tis nobler in the mind to suffer
The slings and arrows of outrageous fortune,
Or to take arms against a sea of troubles
And by opposing end them. To die: to sleep;
No more; and by a sleep to say we end
The heart-ache and the thousand natural shocks
That flesh is heir to, 'tis a consummation
Devoutly to be wish'd. To die, to sleep;
To sleep: perchance to dream: ay, there's the rub.""",
    """Shall I compare thee to a summer's day?
Thou art more lovely and more temperate:
Rough winds do shake the darling buds of May,
And summer's lease hath all too short a date.""",
    """Tomorrow, and tomorrow, and tomorrow,
Creeps in this petty pace from day to day
To the last syllable of recorded time,
And all our yesterdays have lighted fools
The way to dusty death. Out, out, brief candle!
Life's but a walking shadow, a poor player
That struts and frets his hour upon the stage
And then is heard no more.""",
    """Now is the winter of our discontent
Made glorious summer by this sun of York;
And all the clouds that lour'd upon our house
In the deep bosom of the ocean buried.""",
    """Friends, Romans, countrymen, lend me your ears;
I come to bury Caesar, not to praise him.
The evil that men do lives after them;
The good is oft interred with their bones.""",
    """All the world's a stage,
And all the men and women merely players:
They have their exits and their entrances;
And one man in his time plays many parts.""",
    """If music be the food of love, play on;
Give me excess of it, that, surfeiting,
The appetite may sicken, and so die.""",
    """The quality of mercy is not strain'd,
It droppeth as the gentle rain from heaven
Upon the place beneath: it is twice blest;
It blesseth him that gives and him that takes.""",
    """O Romeo, Romeo! wherefore art thou Romeo?
Deny thy father and refuse thy name;
Or, if thou wilt not, be but sworn my love,
And I'll no longer be a Capulet.""",
    """Once more unto the breach, dear friends, once more;
Or close the wall up with our English dead.
In peace there's nothing so becomes a man
As modest stillness and humility.""",
]

_SPEAKERS = ["HAMLET", "MACBETH", "PORTIA", "BRUTUS", "ROSALIND", "HENRY",
             "JULIET", "VIOLA", "PROSPERO", "OTHELLO", "KING LEAR", "PUCK"]


def _expand(target_bytes: int, seed: int = 1337) -> str:
    rng = np.random.default_rng(seed)
    parts = []
    size = 0
    while size < target_bytes:
        sp = _SPEAKERS[int(rng.integers(len(_SPEAKERS)))]
        ps = _PASSAGES[int(rng.integers(len(_PASSAGES)))]
        # vary passages by dropping a random suffix of lines
        lines = ps.split("\n")
        keep = int(rng.integers(2, len(lines) + 1))
        block = f"{sp}:\n" + "\n".join(lines[:keep]) + "\n\n"
        parts.append(block)
        size += len(block)
    return "".join(parts)[:target_bytes]


@dataclass(frozen=True)
class CharDataset:
    train: np.ndarray            # int32 token ids
    val: np.ndarray
    vocab_size: int
    stoi: dict
    itos: dict

    def encode(self, s: str) -> np.ndarray:
        return np.array([self.stoi[c] for c in s], np.int32)

    def decode(self, ids) -> str:
        return "".join(self.itos[int(i)] for i in ids)


def load_corpus(path: str | None = None, target_bytes: int = 600_000,
                val_frac: float = 0.1) -> CharDataset:
    text = None
    for cand in ([path] if path else []) + [
            os.path.join(os.path.dirname(__file__), "input.txt"),
            "/root/repo/data/input.txt"]:
        if cand and os.path.exists(cand):
            with open(cand, "r", encoding="utf-8") as f:
                text = f.read()
            break
    if text is None:
        text = _expand(target_bytes)
    chars = sorted(set(text))
    stoi = {c: i for i, c in enumerate(chars)}
    itos = {i: c for c, i in stoi.items()}
    data = np.array([stoi[c] for c in text], np.int32)
    n_val = int(len(data) * val_frac)
    return CharDataset(train=data[:-n_val], val=data[-n_val:],
                       vocab_size=len(chars), stoi=stoi, itos=itos)


def sample_batch(data: np.ndarray, rng: np.random.Generator, batch: int,
                 seq: int):
    """-> dict(tokens (B,S), targets (B,S)) int32."""
    ix = rng.integers(0, len(data) - seq - 1, size=batch)
    toks = np.stack([data[i:i + seq] for i in ix])
    targs = np.stack([data[i + 1:i + seq + 1] for i in ix])
    return {"tokens": toks, "targets": targs}
