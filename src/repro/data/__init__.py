from repro.data.shakespeare import CharDataset, load_corpus, sample_batch  # noqa: F401
from repro.data.federated import FederatedData  # noqa: F401
from repro.data.synthetic import synthetic_batch  # noqa: F401
