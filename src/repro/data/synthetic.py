"""Synthetic token/embedding batches for smoke tests and benchmarks."""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = {}
    if cfg.encdec:
        s_src, s_tgt = seq // 2, seq - seq // 2
        out["src_embeds"] = rng.normal(size=(batch, s_src, cfg.frontend.embed_dim)
                                       ).astype(np.float32)
        out["tokens"] = rng.integers(0, cfg.vocab_size, (batch, s_tgt)).astype(np.int32)
        out["targets"] = rng.integers(0, cfg.vocab_size, (batch, s_tgt)).astype(np.int32)
        return out
    n_text = seq
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        n_prefix = cfg.frontend.num_prefix_tokens
        n_text = seq - n_prefix
        out["patch_embeds"] = rng.normal(size=(batch, n_prefix, cfg.frontend.embed_dim)
                                         ).astype(np.float32)
    out["tokens"] = rng.integers(0, cfg.vocab_size, (batch, n_text)).astype(np.int32)
    out["targets"] = rng.integers(0, cfg.vocab_size, (batch, n_text)).astype(np.int32)
    return out
