"""Federated partition of the char corpus across N clients.

Contiguous shards give mild natural non-IIDness (different plays /
speakers dominate different shards); ``noniid_alpha > 0`` additionally
skews shard sizes with a Dirichlet draw, the standard FL heterogeneity
knob.

Two invariants the fleet-dynamics layer relies on:

* **Non-empty shards.** Extreme Dirichlet draws can push a weight so
  low that ``int(w_i * len)`` truncates to zero; the partition guard
  below steals the deficit from the largest shard so every client owns
  at least one byte (and ``batch`` can always index it).
* **Per-client RNG isolation.** Each client draws batches from its own
  generator stream, so the batches a client sees depend only on how
  many times *that client* trained — never on which other clients were
  sampled, dropped, or reordered around it.
"""
from __future__ import annotations

import numpy as np

from repro.data.shakespeare import sample_batch


def _shard_sizes(w: np.ndarray, total: int) -> np.ndarray:
    """Integer shard sizes summing to ``total``, every shard >= 1.

    Truncate each weight, give the rounding remainder to the last shard
    (the seed behavior), then repair any zero-length shard by taking
    from the currently largest one.
    """
    sizes = (w * total).astype(int)
    sizes[-1] += total - sizes.sum()
    for i in range(len(sizes)):
        if sizes[i] < 1:
            j = int(np.argmax(sizes))
            take = 1 - sizes[i]
            assert sizes[j] - take >= 1, "corpus too small for num_clients"
            sizes[j] -= take
            sizes[i] = 1
    return sizes


class FederatedData:
    def __init__(self, data: np.ndarray, num_clients: int, seed: int = 0,
                 noniid_alpha: float = 0.0):
        assert len(data) >= num_clients, "corpus smaller than the fleet"
        self.num_clients = num_clients
        rng = np.random.default_rng(seed)
        if noniid_alpha > 0:
            w = rng.dirichlet([noniid_alpha] * num_clients)
            w = np.maximum(w, 2.0 / num_clients)  # every client gets data
            w = w / w.sum()
        else:
            w = np.full(num_clients, 1.0 / num_clients)
        bounds = np.concatenate([[0], np.cumsum(_shard_sizes(w, len(data)))])
        self.shards = [data[bounds[i]:bounds[i + 1]]
                       for i in range(num_clients)]
        self.seed = seed
        self._rngs: list = []
        self.reset_rngs()

    def reset_rngs(self) -> None:
        """Rewind every client's batch stream to its seeded origin.

        The generators are mutable run state: a second ``run()`` on the
        same engine continues the streams (fresh batches — the warm-
        continuation behaviour). Replay tooling (``repro.analysis.sched``)
        calls this so a re-run draws the exact same batches and any
        result difference is attributable to the schedule alone."""
        self._rngs = [np.random.default_rng(self.seed + 1000 + i)
                      for i in range(self.num_clients)]

    def shard_size(self, i: int) -> int:
        return len(self.shards[i])

    def batch(self, client: int, batch_size: int, seq: int):
        return sample_batch(self.shards[client], self._rngs[client],
                            batch_size, seq)
