"""Federated partition of the char corpus across N clients.

Contiguous shards give mild natural non-IIDness (different plays /
speakers dominate different shards); ``noniid_alpha > 0`` additionally
skews shard sizes with a Dirichlet draw, the standard FL heterogeneity
knob.
"""
from __future__ import annotations

import numpy as np

from repro.data.shakespeare import sample_batch


class FederatedData:
    def __init__(self, data: np.ndarray, num_clients: int, seed: int = 0,
                 noniid_alpha: float = 0.0):
        self.num_clients = num_clients
        rng = np.random.default_rng(seed)
        if noniid_alpha > 0:
            w = rng.dirichlet([noniid_alpha] * num_clients)
            w = np.maximum(w, 2.0 / num_clients)  # every client gets data
            w = w / w.sum()
        else:
            w = np.full(num_clients, 1.0 / num_clients)
        bounds = np.concatenate([[0], np.cumsum((w * len(data)).astype(int))])
        bounds[-1] = len(data)
        self.shards = [data[bounds[i]:bounds[i + 1]]
                       for i in range(num_clients)]
        self._rngs = [np.random.default_rng(seed + 1000 + i)
                      for i in range(num_clients)]

    def shard_size(self, i: int) -> int:
        return len(self.shards[i])

    def batch(self, client: int, batch_size: int, seq: int):
        return sample_batch(self.shards[client], self._rngs[client],
                            batch_size, seq)
