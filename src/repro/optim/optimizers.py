"""Pure-JAX optimizers (no optax in this container): SGD / Momentum /
Adam / AdamW, all pytree-based (init_fn, update_fn) pairs.

``update_fn(grads, state, params) -> (updates, state)`` follows the optax
convention so the FL client and the big-model train driver share code.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: callable
    update: callable


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params=None):
        return jax.tree.map(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params=None):
        new_m = jax.tree.map(lambda m, g: beta * m + g.astype(jnp.float32),
                             state, grads)
        ups = jax.tree.map(lambda m: -lr * m, new_m)
        return ups, new_m

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: object
    nu: object
    count: jnp.ndarray


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0, moment_dtype=jnp.float32) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, moment_dtype)
        return AdamState(mu=jax.tree.map(zeros, params),
                         nu=jax.tree.map(zeros, params),
                         count=jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        count = state.count + 1
        mu = jax.tree.map(lambda m, g: (b1 * m.astype(jnp.float32)
                                        + (1 - b1) * g.astype(jnp.float32)
                                        ).astype(moment_dtype),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: (b2 * v.astype(jnp.float32) + (1 - b2)
                          * jnp.square(g.astype(jnp.float32))
                          ).astype(moment_dtype), state.nu, grads)
        bc1 = 1 - b1 ** count.astype(jnp.float32)
        bc2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(m, v, p):
            m = m.astype(jnp.float32)
            v = v.astype(jnp.float32)
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p.ndim >= 2:   # decay matrices only
                step = step + weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype)

        ups = jax.tree.map(upd, mu, nu, params)
        return ups, AdamState(mu=mu, nu=nu, count=count)

    return Optimizer(init, update)


def adam(lr: float, **kw) -> Optimizer:
    return adamw(lr, weight_decay=0.0, **kw)


def make_optimizer(name: str, lr: float, weight_decay: float = 0.0) -> Optimizer:
    if name == "sgd":
        return sgd(lr)
    if name == "momentum":
        return momentum(lr)
    if name == "adam":
        return adam(lr)
    if name == "adamw":
        return adamw(lr, weight_decay=weight_decay)
    if name == "adamw_bf16":
        # half-width moments: halves optimizer HBM traffic + state bytes
        # (beyond-paper §Perf lever; real TPU systems pair this with
        # stochastic rounding)
        return adamw(lr, weight_decay=weight_decay, moment_dtype=jnp.bfloat16)
    raise ValueError(name)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm
