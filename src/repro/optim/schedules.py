"""Learning-rate schedules (pure functions step -> lr)."""
from __future__ import annotations

import math


def constant(lr: float):
    return lambda step: lr


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    """Linear warmup to ``peak_lr`` then cosine decay to
    ``final_frac * peak_lr`` at ``total_steps``."""
    def f(step):
        s = float(step)
        if warmup_steps and s < warmup_steps:
            return peak_lr * (s + 1) / warmup_steps
        t = min(1.0, (s - warmup_steps) / max(1, total_steps - warmup_steps))
        cos = 0.5 * (1 + math.cos(math.pi * t))
        return peak_lr * (final_frac + (1 - final_frac) * cos)

    return f


def inverse_sqrt(peak_lr: float, warmup_steps: int):
    def f(step):
        s = float(step)
        if warmup_steps and s < warmup_steps:
            return peak_lr * (s + 1) / warmup_steps
        return peak_lr * math.sqrt(warmup_steps / max(s, 1.0))

    return f


def scale_lr_for_accum(lr: float, grad_accum: int, rule: str = "linear"):
    """LR scaling when Eq. 8 enlarges the effective batch via accumulation
    — the refinement measured in EXPERIMENTS.md §Perf (token-budget
    ablation): without it, accumulation slows per-round convergence."""
    if rule == "linear":
        return lr * grad_accum
    if rule == "sqrt":
        return lr * math.sqrt(grad_accum)
    return lr
