from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adam, adamw, apply_updates, clip_by_global_norm, global_norm,
    make_optimizer, momentum, sgd,
)
