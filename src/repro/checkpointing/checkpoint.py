"""msgpack + raw-numpy checkpointing (no orbax in this container).

Stores an arbitrary pytree of arrays: structure is flattened to
path-keyed entries; each leaf is (dtype, shape, bytes). Works for params,
optimizer state, FL server state (duals, history) alike.
"""
from __future__ import annotations

import os
from typing import Any

import msgpack
import numpy as np


def _paths(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _paths(tree[k], f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _paths(v, f"{prefix}/{i}")
    else:
        yield prefix, tree


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    entries = {}
    for key, leaf in _paths(tree):
        arr = np.asarray(leaf)
        entries[key] = {"dtype": str(arr.dtype), "shape": list(arr.shape),
                        "data": arr.tobytes()}
    with open(path, "wb") as f:
        f.write(msgpack.packb(entries))


def load(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (a pytree template)."""
    with open(path, "rb") as f:
        entries = msgpack.unpackb(f.read())
    leaves = {}
    for key, ent in entries.items():
        dt = ent["dtype"]
        arr = np.frombuffer(ent["data"], dtype=dt).reshape(ent["shape"])
        leaves[key] = arr
    flat_keys = [k for k, _ in _paths(like)]
    missing = [k for k in flat_keys if k not in leaves]
    if missing:
        raise KeyError(f"checkpoint missing keys: {missing[:5]} ...")

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(tree[k], f"{prefix}/{k}") for k in tree}
        if isinstance(tree, (list, tuple)):
            typ = type(tree)
            vals = [rebuild(v, f"{prefix}/{i}") for i, v in enumerate(tree)]
            return typ(vals) if typ is not tuple else tuple(vals)
        arr = leaves[prefix]
        like_leaf = np.asarray(tree)
        return np.asarray(arr, dtype=like_leaf.dtype)

    return rebuild(like)
