from repro.checkpointing.checkpoint import load, save  # noqa: F401
