"""``repro.bench`` — the perf-trajectory regression harness.

Perf claims live in committed ``BENCH_<area>.json`` baselines instead
of commit messages: a declarative benchmark registry, a runner with
warmup/repeat median+IQR statistics and an environment fingerprint, a
typed record schema, and a direction-aware compare that fails on
regressions beyond each metric's noise band (the CI ratchet).

Benchmark definitions live next to the workloads in ``benchmarks/``;
``python -m benchmarks.run --record / --check`` is the entry point.
"""
from repro.bench.compare import (FAILING, IMPROVEMENT, MISSING, NEW,
                                 REGRESSION, WITHIN_NOISE, CompareReport,
                                 MetricDiff, compare_metric,
                                 compare_snapshots)
from repro.bench.registry import (Benchmark, MetricSpec, all_benchmarks,
                                  areas, benchmark, get, register)
from repro.bench.runner import (TimingStats, run_area, run_benchmark,
                                time_callable)
from repro.bench.schema import (SCHEMA_VERSION, BenchmarkRecord, Fingerprint,
                                MetricRecord, Snapshot, snapshot_filename)

__all__ = [
    "Benchmark", "MetricSpec", "register", "benchmark", "get",
    "all_benchmarks", "areas",
    "TimingStats", "time_callable", "run_benchmark", "run_area",
    "SCHEMA_VERSION", "Fingerprint", "MetricRecord", "BenchmarkRecord",
    "Snapshot", "snapshot_filename",
    "CompareReport", "MetricDiff", "compare_metric", "compare_snapshots",
    "REGRESSION", "IMPROVEMENT", "WITHIN_NOISE", "MISSING", "NEW", "FAILING",
]
