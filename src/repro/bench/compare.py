"""Direction-aware snapshot comparison — the ratchet.

``compare_snapshots(baseline, fresh)`` diffs every metric the baseline
pins against the fresh run. A metric regresses when it moves in its
*worse* direction (``lower``-is-better regresses upward, e.g.
``rounds_to_target``; ``higher``-is-better regresses downward, e.g.
``batched_speedup``) beyond its noise band
``max(atol, rtol * |baseline|)``. Moves beyond the band in the better
direction are improvements (reported, not failed — re-record to bank
them); anything inside the band is within-noise.

A baseline metric absent from the fresh run is a failure (a benchmark
that stops reporting a ratcheted number has rotted); a fresh metric
absent from the baseline is merely new. Fingerprint or scale
mismatches are notes, not failures — timed metrics move across
machines, which is what their generous tolerances are for.

CLI::

    PYTHONPATH=src python -m repro.bench.compare BENCH_kernels.json fresh.json

exits non-zero on any regression or missing metric.
"""
from __future__ import annotations

import argparse
import sys
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from repro.bench.schema import MetricRecord, Snapshot

REGRESSION = "REGRESSION"
IMPROVEMENT = "improvement"
WITHIN_NOISE = "within-noise"
MISSING = "MISSING"
NEW = "new"

#: Verdicts that fail the ratchet.
FAILING = (REGRESSION, MISSING)


@dataclass(frozen=True)
class MetricDiff:
    benchmark: str
    metric: str
    verdict: str
    baseline: Optional[float] = None
    fresh: Optional[float] = None
    limit: Optional[float] = None   # worse-direction bound fresh had to hold
    unit: str = ""

    @property
    def failed(self) -> bool:
        return self.verdict in FAILING

    def render(self) -> str:
        if self.baseline is None or self.fresh is None:
            return (f"  {self.verdict:12s} {self.benchmark}.{self.metric}")
        delta = self.fresh - self.baseline
        pct = (f" ({100.0 * delta / abs(self.baseline):+.1f}%)"
               if self.baseline else "")
        lim = f" limit={self.limit:.4g}" if self.limit is not None else ""
        return (f"  {self.verdict:12s} {self.benchmark}.{self.metric}: "
                f"{self.baseline:.4g} -> {self.fresh:.4g}{self.unit}"
                f"{pct}{lim}")


@dataclass
class CompareReport:
    area: str
    diffs: List[MetricDiff] = field(default_factory=list)
    notes: List[str] = field(default_factory=list)

    @property
    def regressions(self) -> List[MetricDiff]:
        return [d for d in self.diffs if d.failed]

    @property
    def ok(self) -> bool:
        return not self.regressions

    def render(self) -> str:
        lines = [f"[{self.area}] {len(self.diffs)} metrics vs baseline: "
                 f"{len(self.regressions)} failing"]
        lines += [f"  note: {n}" for n in self.notes]
        order = {REGRESSION: 0, MISSING: 1, IMPROVEMENT: 2, NEW: 3,
                 WITHIN_NOISE: 4}
        for d in sorted(self.diffs, key=lambda d: (order[d.verdict],
                                                   d.benchmark, d.metric)):
            lines.append(d.render())
        return "\n".join(lines)


def compare_metric(base: MetricRecord, fresh: MetricRecord,
                   tol_scale: float = 1.0) -> Tuple[str, float]:
    """Verdict for one metric plus the worse-direction limit it had to
    hold. Tolerances come from the *baseline* record — the committed
    file is the contract — scaled by ``tol_scale``."""
    band = max(base.atol, base.rtol * abs(base.value)) * tol_scale
    if base.direction == "lower":
        limit = base.value + band
        if fresh.value > limit:
            return REGRESSION, limit
        if fresh.value < base.value - band:
            return IMPROVEMENT, limit
    else:
        limit = base.value - band
        if fresh.value < limit:
            return REGRESSION, limit
        if fresh.value > base.value + band:
            return IMPROVEMENT, limit
    return WITHIN_NOISE, limit


def compare_snapshots(baseline: Snapshot, fresh: Snapshot,
                      tol_scale: float = 1.0) -> CompareReport:
    report = CompareReport(area=baseline.area)
    if baseline.scale != fresh.scale:
        report.notes.append(
            f"scale mismatch: baseline @{baseline.scale}, fresh "
            f"@{fresh.scale} — values are not comparable; re-record")
    if baseline.fingerprint != fresh.fingerprint:
        report.notes.append(
            f"fingerprint differs (baseline {baseline.fingerprint.to_dict()} "
            f"vs fresh {fresh.fingerprint.to_dict()}): timed metrics may "
            f"shift; derived/simulated metrics must not")
    for brec in baseline.records:
        frec = fresh.record(brec.benchmark)
        for bm in brec.metrics:
            fm = frec.metric(bm.name) if frec else None
            if fm is None:
                report.diffs.append(MetricDiff(
                    benchmark=brec.benchmark, metric=bm.name,
                    verdict=MISSING, baseline=bm.value, unit=bm.unit))
                continue
            verdict, limit = compare_metric(bm, fm, tol_scale)
            report.diffs.append(MetricDiff(
                benchmark=brec.benchmark, metric=bm.name, verdict=verdict,
                baseline=bm.value, fresh=fm.value, limit=limit,
                unit=bm.unit))
    for frec in fresh.records:
        brec = baseline.record(frec.benchmark)
        for fm in frec.metrics:
            if brec is None or brec.metric(fm.name) is None:
                report.diffs.append(MetricDiff(
                    benchmark=frec.benchmark, metric=fm.name, verdict=NEW,
                    fresh=fm.value, unit=fm.unit))
    return report


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Diff a fresh benchmark snapshot against a committed "
                    "BENCH_<area>.json baseline; exit 1 on regressions.")
    ap.add_argument("baseline", help="committed BENCH_<area>.json")
    ap.add_argument("fresh", help="freshly recorded snapshot")
    ap.add_argument("--tol-scale", type=float, default=1.0,
                    help="multiply every noise band (e.g. 1.5 on a very "
                         "different machine)")
    args = ap.parse_args(argv)
    report = compare_snapshots(Snapshot.load(args.baseline),
                               Snapshot.load(args.fresh),
                               tol_scale=args.tol_scale)
    print(report.render())
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
