"""Declarative benchmark registry.

A ``Benchmark`` is a record: a name, an area (one ``BENCH_<area>.json``
snapshot per area), the metric specs it promises to produce (unit,
better-direction, noise tolerance), scale presets (``smoke`` for CI,
``full`` for local perf work, ``tiny`` for the test suite), and the
function that runs it. Benchmark functions receive the chosen preset's
parameter dict and return ``{metric_name: float | TimingStats}`` — the
runner validates the returned keys against the declared specs, so a
benchmark cannot silently drop a ratcheted metric.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Tuple

from repro.bench.schema import DIRECTIONS

#: Scales every registered benchmark must provide a preset for.
REQUIRED_SCALES = ("tiny", "smoke", "full")


@dataclass(frozen=True)
class MetricSpec:
    """Declares one metric a benchmark produces.

    ``rtol``/``atol`` set the ratchet's noise band (see
    ``repro.bench.compare``). Timed wall-clock metrics should carry a
    generous ``rtol`` — they move across machines — while derived and
    simulated metrics (speedups, rounds-to-target, simulated seconds)
    are deterministic given the seed and can be held tight.
    """

    name: str
    unit: str
    direction: str = "lower"
    rtol: float = 0.25
    atol: float = 0.0

    def __post_init__(self) -> None:
        if self.direction not in DIRECTIONS:
            raise ValueError(f"direction must be one of {DIRECTIONS}, "
                             f"got {self.direction!r}")


@dataclass(frozen=True)
class Benchmark:
    """One registered benchmark."""

    name: str
    area: str
    fn: Callable[[Mapping], Dict]
    metrics: Tuple[MetricSpec, ...]
    presets: Mapping[str, Mapping]
    description: str = ""

    def __post_init__(self) -> None:
        names = [m.name for m in self.metrics]
        if len(set(names)) != len(names):
            raise ValueError(f"{self.name}: duplicate metric names {names}")
        missing = [s for s in REQUIRED_SCALES if s not in self.presets]
        if missing:
            raise ValueError(f"{self.name}: missing presets {missing}")

    def spec(self, metric: str) -> Optional[MetricSpec]:
        for m in self.metrics:
            if m.name == metric:
                return m
        return None


_REGISTRY: Dict[str, Benchmark] = {}


def register(bench: Benchmark) -> Benchmark:
    """Add a benchmark to the global registry (idempotent re-register
    of the same name replaces — module reimports must not error)."""
    _REGISTRY[bench.name] = bench
    return bench


def benchmark(name: str, area: str, metrics: Iterable[MetricSpec],
              presets: Mapping[str, Mapping],
              description: str = "") -> Callable[[Callable], Callable]:
    """Decorator form: ``@benchmark("fl.executor", "fl_engine", ...)``."""
    def deco(fn: Callable) -> Callable:
        register(Benchmark(name=name, area=area, fn=fn,
                           metrics=tuple(metrics), presets=dict(presets),
                           description=description))
        return fn
    return deco


def get(name: str) -> Benchmark:
    if name not in _REGISTRY:
        raise KeyError(f"no benchmark named {name!r}; registered: "
                       f"{sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_benchmarks(area: Optional[str] = None) -> List[Benchmark]:
    out = [b for b in _REGISTRY.values() if area is None or b.area == area]
    return sorted(out, key=lambda b: (b.area, b.name))


def areas() -> List[str]:
    return sorted({b.area for b in _REGISTRY.values()})


def clear() -> None:
    """Reset the registry (tests only)."""
    _REGISTRY.clear()
