"""Typed records for the perf-trajectory harness.

A benchmark run serializes to one ``BENCH_<area>.json`` snapshot per
area: an environment fingerprint plus a list of per-benchmark records,
each metric carrying its value, unit, better-direction and noise
tolerance. The schema round-trips bit-for-bit through JSON
(``tests/test_bench.py`` pins that), so committed baselines stay
machine-readable across PRs — the whole point of the ratchet.
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass
from typing import Dict, Mapping, Optional, Tuple

SCHEMA_VERSION = 1

#: Valid better-directions. ``lower`` regresses upward (times, rounds,
#: simulated seconds); ``higher`` regresses downward (speedups,
#: throughput).
DIRECTIONS = ("lower", "higher")


@dataclass(frozen=True)
class Fingerprint:
    """Where a snapshot was measured — recorded, never ratcheted.

    Compare flags a mismatch as a note (timed metrics move across
    machines; simulated/derived metrics must not), it does not fail on
    one.
    """

    jax_version: str
    backend: str
    device_kind: str
    cpu_count: int
    python_version: str

    @classmethod
    def capture(cls) -> "Fingerprint":
        import platform

        import jax

        return cls(jax_version=jax.__version__,
                   backend=jax.default_backend(),
                   device_kind=jax.devices()[0].device_kind,
                   cpu_count=os.cpu_count() or 1,
                   python_version=platform.python_version())

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "Fingerprint":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)})


@dataclass(frozen=True)
class MetricRecord:
    """One measured metric: a typed number, not a formatted string.

    ``rtol``/``atol`` define the noise band the ratchet tolerates: a
    fresh value is a regression when it moves in the *worse* direction
    by more than ``max(atol, rtol * |baseline|)``.  ``n``/``iqr``
    carry repeat statistics for timed metrics (1/0.0 for derived
    single-shot values).
    """

    name: str
    value: float
    unit: str
    direction: str = "lower"
    rtol: float = 0.25
    atol: float = 0.0
    n: int = 1
    iqr: float = 0.0

    def __post_init__(self):
        if self.direction not in DIRECTIONS:
            raise ValueError(f"direction must be one of {DIRECTIONS}, "
                             f"got {self.direction!r}")

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "MetricRecord":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)})


@dataclass(frozen=True)
class BenchmarkRecord:
    """All metrics one registered benchmark produced at one scale.

    ``context`` holds descriptive strings (cohort sizes, targets, knob
    shapes) that used to live embedded in the CSV ``derived`` column —
    kept for humans, never compared.
    """

    benchmark: str
    scale: str
    metrics: Tuple[MetricRecord, ...]
    context: Dict[str, str] = dataclasses.field(default_factory=dict)

    def metric(self, name: str) -> Optional[MetricRecord]:
        for m in self.metrics:
            if m.name == name:
                return m
        return None

    def to_dict(self) -> Dict:
        return {"benchmark": self.benchmark, "scale": self.scale,
                "metrics": [m.to_dict() for m in self.metrics],
                "context": dict(self.context)}

    @classmethod
    def from_dict(cls, d: Mapping) -> "BenchmarkRecord":
        return cls(benchmark=d["benchmark"], scale=d["scale"],
                   metrics=tuple(MetricRecord.from_dict(m)
                                 for m in d["metrics"]),
                   context=dict(d.get("context", {})))


@dataclass(frozen=True)
class Snapshot:
    """One ``BENCH_<area>.json`` file: fingerprint + benchmark records."""

    area: str
    scale: str
    fingerprint: Fingerprint
    records: Tuple[BenchmarkRecord, ...]
    schema_version: int = SCHEMA_VERSION

    def record(self, benchmark: str) -> Optional[BenchmarkRecord]:
        for r in self.records:
            if r.benchmark == benchmark:
                return r
        return None

    def to_dict(self) -> Dict:
        return {"schema_version": self.schema_version, "area": self.area,
                "scale": self.scale,
                "fingerprint": self.fingerprint.to_dict(),
                "records": [r.to_dict() for r in self.records]}

    @classmethod
    def from_dict(cls, d: Mapping) -> "Snapshot":
        version = d.get("schema_version", 0)
        if version > SCHEMA_VERSION:
            raise ValueError(
                f"snapshot schema v{version} is newer than this harness "
                f"(v{SCHEMA_VERSION}) — update the code, don't guess")
        return cls(area=d["area"], scale=d["scale"],
                   fingerprint=Fingerprint.from_dict(d["fingerprint"]),
                   records=tuple(BenchmarkRecord.from_dict(r)
                                 for r in d["records"]),
                   schema_version=version)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=False) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "Snapshot":
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "Snapshot":
        with open(path) as f:
            return cls.from_json(f.read())


def snapshot_filename(area: str) -> str:
    """Canonical baseline filename for an area (``BENCH_<area>.json``)."""
    return f"BENCH_{area}.json"
