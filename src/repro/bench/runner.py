"""Benchmark runner: warmup/repeat timing with median+IQR statistics,
metric validation against the declared specs, and snapshot assembly
with an environment fingerprint.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Union

from repro.bench.registry import Benchmark, all_benchmarks
from repro.bench.schema import (BenchmarkRecord, Fingerprint, MetricRecord,
                                Snapshot)


@dataclass(frozen=True)
class TimingStats:
    """Median and interquartile range over post-warmup repeats, in
    microseconds."""

    median_us: float
    iqr_us: float
    n: int


def time_callable(fn: Callable, *args: object, warmup: int = 2,
                  repeats: int = 10,
                  block: Union[Callable, bool, None] = None) -> TimingStats:
    """Time ``fn(*args)`` with warmup calls excluded.

    ``block`` defaults to ``jax.block_until_ready`` so asynchronous
    dispatch doesn't make kernels look free; pass ``block=False`` for
    host-side functions.
    """
    if block is None:
        import jax
        block = jax.block_until_ready
    elif block is False:
        block = lambda x: x
    for _ in range(max(0, warmup)):
        block(fn(*args))
    times: List[float] = []
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        block(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    n = len(times)
    median = times[n // 2] if n % 2 else 0.5 * (times[n // 2 - 1]
                                                + times[n // 2])
    iqr = times[(3 * n) // 4] - times[n // 4] if n >= 4 else 0.0
    return TimingStats(median_us=median * 1e6, iqr_us=iqr * 1e6, n=n)


def run_benchmark(bench: Benchmark, scale: str = "smoke",
                  params: Optional[Dict] = None) -> BenchmarkRecord:
    """Run one benchmark at a scale and type-check its output.

    The function must return exactly the declared metric names — a
    missing metric is an error (it would silently fall out of the
    ratchet), as is an undeclared one (it would never be ratcheted).
    Values may be plain numbers or ``TimingStats``. A ``"context"``
    key, if returned, becomes the record's descriptive-string dict.
    """
    if params is None:
        if scale not in bench.presets:
            raise KeyError(f"{bench.name}: no preset for scale {scale!r} "
                           f"(have {sorted(bench.presets)})")
        params = dict(bench.presets[scale])
    result = bench.fn(params)
    context = {k: str(v) for k, v in result.pop("context", {}).items()}
    declared = {m.name for m in bench.metrics}
    got = set(result)
    if got != declared:
        raise ValueError(
            f"{bench.name}: metric mismatch — missing "
            f"{sorted(declared - got)}, undeclared {sorted(got - declared)}")
    metrics: List[MetricRecord] = []
    for spec in bench.metrics:
        v = result[spec.name]
        if isinstance(v, TimingStats):
            value, n, iqr = v.median_us, v.n, v.iqr_us
        else:
            value, n, iqr = float(v), 1, 0.0
        metrics.append(MetricRecord(name=spec.name, value=value,
                                    unit=spec.unit, direction=spec.direction,
                                    rtol=spec.rtol, atol=spec.atol,
                                    n=n, iqr=iqr))
    return BenchmarkRecord(benchmark=bench.name, scale=scale,
                           metrics=tuple(metrics), context=context)


def run_area(area: str, scale: str = "smoke",
             log: Optional[Callable[[str], None]] = None) -> Snapshot:
    """Run every registered benchmark in an area into one snapshot."""
    benches = all_benchmarks(area)
    if not benches:
        raise KeyError(f"no benchmarks registered for area {area!r}")
    records: List[BenchmarkRecord] = []
    for bench in benches:
        if log:
            log(f"[bench] {area}/{bench.name} @{scale} ...")
        records.append(run_benchmark(bench, scale))
    return Snapshot(area=area, scale=scale,
                    fingerprint=Fingerprint.capture(),
                    records=tuple(records))
